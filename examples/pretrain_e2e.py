"""End-to-end driver (deliverable (b)): federated pre-training with checkpointing,
auto-resume, partial participation, DP post-processing hooks, and CSV metric logging —
the production workflow at CPU demo scale. Scale knobs are CLI flags; on a real mesh
the identical round step pjit-shards per sharding/specs.py.

  PYTHONPATH=src python examples/pretrain_e2e.py             # demo scale
  PYTHONPATH=src python examples/pretrain_e2e.py --full      # ~100M-class run
"""
import argparse
import sys

from repro.launch.train import parse_args, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="photon-125m (~124M params), a few hundred local steps")
    args, _ = ap.parse_known_args()

    if args.full:
        argv = [
            "--arch", "photon-125m", "--rounds", "4", "--local-steps", "100",
            "--clients", "4", "--population", "8", "--batch", "4", "--seq-len", "512",
            "--heterogeneous", "--ckpt-dir", "results/e2e_ckpt", "--resume",
            "--log", "results/e2e_metrics.csv",
        ]
    else:
        argv = [
            "--arch", "photon-75m", "--reduced", "--rounds", "5", "--local-steps", "12",
            "--clients", "3", "--population", "6", "--batch", "2", "--seq-len", "128",
            "--heterogeneous", "--dp-clip", "10.0",
            "--ckpt-dir", "results/e2e_ckpt_demo", "--resume",
            "--log", "results/e2e_metrics_demo.csv",
        ]
    out = run(parse_args(argv))
    final = out["history"][-1] if out["history"] else {}
    print(f"final: {final.get('train_loss', 'resumed-complete')}")


if __name__ == "__main__":
    main()
