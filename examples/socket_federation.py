"""A real 3-process federated round on localhost — 1 aggregation server + 2
client workers, each a separate ``repro.launch.train`` process speaking the
length-prefixed socket protocol (docs/runtime.md).

Four demos, each an end-to-end assertion the CI fast lane runs:

  --demo round        1 server + 2 workers run a top-k-compressed async round
                      to completion, then the SAME configuration runs in-process
                      (``--runtime inproc``) and the final server.npz checkpoints
                      are compared BITWISE — the socket deployment is the
                      simulator, byte for byte.
  --demo kill-resume  the server is SIGKILLed after its first completed
                      checkpoint; a fresh server process resumes from disk and
                      finishes the run. The final checkpoint must match an
                      uninterrupted in-process run bitwise — crash recovery
                      loses nothing, replays nothing.
  --demo chaos        workers roll seeded dice that drop/delay frames and
                      hard-kill the process mid-protocol (``--chaos-*``); the
                      supervisor respawns killed workers (exit code 137) and the
                      run must still complete with a finite loss — leases,
                      retries and idempotent redispatch absorb the faults.
  --demo corrupt      one worker poisons most of its delta payloads with
                      NaN/Inf (``--chaos-corrupt`` — frames stay CRC-valid, so
                      only the server's ``--screen`` door stands); the run must
                      converge on the honest worker's pushes and the merged
                      trace must show a ``screen_reject`` for the poison
                      (``report --check --expect-faults`` audits coverage,
                      docs/robustness.md).

  PYTHONPATH=src python examples/socket_federation.py --demo round
  PYTHONPATH=src python examples/socket_federation.py --demo kill-resume
  PYTHONPATH=src python examples/socket_federation.py --demo chaos
  PYTHONPATH=src python examples/socket_federation.py --demo corrupt

With ``--trace-dir DIR`` the chaos demo runs fully observed: every process
writes ``--trace`` JSONL there, the server serves live ``/metrics`` (probed),
and the merged trace must pass ``python -m repro.obs.report DIR --check
--expect-faults`` — all spans closed or excused by a recorded kill, no orphan
dispatch ids, the injected faults present in the audit
(docs/observability.md).
"""
import argparse
import json
import os
import re
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

KILL_EXIT_CODE = 137  # chaos kill / SIGKILL — supervisors respawn on it
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _base_cmd(args):
    return [
        sys.executable, "-m", "repro.launch.train",
        "--reduced", "--local-steps", "4", "--clients", "2",
        "--population", "4", "--seq-len", "64", "--batch", "2",
        "--aggregation", "async", "--buffer-size", "2",
        "--straggler-profile", "heavy", "--uplink", "topk",
        "--topk-fraction", "0.1", "--seed", str(args.seed),
        "--eval-batches", "1",
    ]


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    env["PYTHONUNBUFFERED"] = "1"
    return env


def _spawn(cmd, logpath):
    log = open(logpath, "ab")
    return subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT, env=_env())


def _wait_for_port(logpath, proc, timeout=120.0):
    """The server prints 'server listening on host:port' at startup."""
    deadline = time.time() + timeout
    while time.time() < deadline:
        if os.path.exists(logpath):
            m = re.search(
                rb"server listening on [\d.]+:(\d+)", open(logpath, "rb").read()
            )
            if m:
                return int(m.group(1))
        if proc.poll() is not None:
            sys.exit(f"server died before listening:\n{open(logpath).read()}")
        time.sleep(0.2)
    sys.exit("server never started listening")


def _start_server(args, rounds, ckpt, logpath, resume=False, port=0, extra=None):
    cmd = _base_cmd(args) + [
        "--rounds", str(rounds), "--runtime", "sockets", "--role", "server",
        "--port", str(port), "--ckpt-dir", ckpt,
        "--lease-timeout", "15", "--io-timeout", "30",
    ] + (extra or [])
    if args.trace_dir:
        cmd += ["--trace", os.path.join(args.trace_dir, "server.jsonl"),
                "--metrics-port", "0"]
    if resume:
        cmd.append("--resume")
    proc = _spawn(cmd, logpath)
    return proc, _wait_for_port(logpath, proc)


def _worker_cmd(args, rounds, port, wid, chaos=None):
    cmd = _base_cmd(args) + [
        "--rounds", str(rounds), "--runtime", "sockets", "--role", "client",
        "--port", str(port), "--worker-id", wid, "--io-timeout", "30",
    ]
    if args.trace_dir:
        # respawned incarnations append to the same file; events are keyed by
        # (proc, pid) so the report tells the incarnations apart
        cmd += ["--trace", os.path.join(args.trace_dir, f"{wid}.jsonl")]
    if chaos:
        cmd += [
            "--chaos-drop", str(chaos.get("drop", 0)),
            "--chaos-delay", str(chaos.get("delay", 0)),
            "--chaos-kill", str(chaos.get("kill", 0)),
            "--chaos-seed", str(chaos.get("seed", 0)),
        ]
        if chaos.get("corrupt"):
            cmd += [
                "--chaos-corrupt", str(chaos["corrupt"]),
                "--chaos-corrupt-kinds", chaos.get("corrupt_kinds", "nan,inf"),
            ]
    return cmd


def _supervise_workers(workers, server, logdir, respawn=True):
    """Babysit worker processes until the server exits; respawn any worker that
    dies while the run is still going (chaos kill exits with 137)."""
    respawns = 0
    while server.poll() is None:
        for i, (proc, cmd) in enumerate(workers):
            rc = proc.poll()
            if rc is not None and respawn and server.poll() is None:
                respawns += 1
                print(f"[supervisor] worker {i} exited rc={rc}; respawning "
                      f"(#{respawns})")
                workers[i] = (
                    _spawn(cmd, os.path.join(logdir, f"worker{i}.log")), cmd
                )
        time.sleep(0.3)
    for proc, _ in workers:  # server done: workers drain the "done" answer
        try:
            proc.wait(timeout=60)
        except subprocess.TimeoutExpired:
            proc.kill()
    return respawns


def _run_inproc(args, rounds, ckpt):
    cmd = _base_cmd(args) + ["--rounds", str(rounds), "--ckpt-dir", ckpt]
    subprocess.run(cmd, check=True, env=_env(), stdout=subprocess.DEVNULL)


def _assert_same_npz(a_path, b_path):
    a, b = np.load(a_path), np.load(b_path)
    assert set(a.files) == set(b.files), set(a.files) ^ set(b.files)
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    print(f"PASS: {len(a.files)} arrays bitwise-equal "
          f"({os.path.basename(os.path.dirname(a_path))})")


def _round_dir(ckpt, rnd):
    return os.path.join(ckpt, f"round_{rnd:06d}")


def _round_complete(ckpt, rnd):
    d = _round_dir(ckpt, rnd)
    try:
        json.load(open(os.path.join(d, "manifest.json")))
        return os.path.exists(os.path.join(d, "server.npz"))
    except (OSError, json.JSONDecodeError):
        return False


def demo_round(args, tmp):
    rounds, ckpt = 2, os.path.join(tmp, "sock_ck")
    server, port = _start_server(
        args, rounds, ckpt, os.path.join(tmp, "server.log")
    )
    workers = []
    for i in range(2):
        cmd = _worker_cmd(args, rounds, port, f"w{i}")
        workers.append((_spawn(cmd, os.path.join(tmp, f"worker{i}.log")), cmd))
    _supervise_workers(workers, server, tmp, respawn=False)
    assert server.returncode == 0, open(os.path.join(tmp, "server.log")).read()
    ref = os.path.join(tmp, "inproc_ck")
    _run_inproc(args, rounds, ref)
    _assert_same_npz(
        os.path.join(_round_dir(ckpt, rounds - 1), "server.npz"),
        os.path.join(_round_dir(ref, rounds - 1), "server.npz"),
    )


def demo_kill_resume(args, tmp):
    rounds, ckpt = 3, os.path.join(tmp, "sock_ck")
    server, port = _start_server(
        args, rounds, ckpt, os.path.join(tmp, "server.log")
    )
    workers = []
    for i in range(2):
        cmd = _worker_cmd(args, rounds, port, f"w{i}")
        workers.append((_spawn(cmd, os.path.join(tmp, f"worker{i}.log")), cmd))
    # SIGKILL the server the moment its first checkpoint is complete: no
    # shutdown hooks run, the socket vanishes under the workers mid-protocol
    while not _round_complete(ckpt, 0):
        assert server.poll() is None, "server died before its first checkpoint"
        time.sleep(0.2)
    server.send_signal(signal.SIGKILL)
    server.wait()
    print(f"[supervisor] server SIGKILLed after round 0 (rc={server.returncode})")
    # workers are now retrying against a dead port under backoff; a fresh
    # server process resumes from the checkpoint on a NEW port — rebind the
    # workers by respawning them (their backoff would otherwise spin on the
    # old port until give-up)
    for proc, _ in workers:
        proc.kill()
    server2, port2 = _start_server(
        args, rounds, ckpt, os.path.join(tmp, "server2.log"), resume=True
    )
    workers = []
    for i in range(2):
        cmd = _worker_cmd(args, rounds, port2, f"w{i}")
        workers.append((_spawn(cmd, os.path.join(tmp, f"worker{i}.log")), cmd))
    _supervise_workers(workers, server2, tmp, respawn=False)
    assert server2.returncode == 0, open(os.path.join(tmp, "server2.log")).read()
    ref = os.path.join(tmp, "inproc_ck")
    _run_inproc(args, rounds, ref)
    _assert_same_npz(
        os.path.join(_round_dir(ckpt, rounds - 1), "server.npz"),
        os.path.join(_round_dir(ref, rounds - 1), "server.npz"),
    )


def _probe_metrics(server, logpath, timeout=60.0):
    """GET the server's live /metrics endpoint once it announces its port."""
    import urllib.request

    deadline = time.time() + timeout
    while time.time() < deadline and server.poll() is None:
        m = re.search(
            rb"metrics serving on [\d.]+:(\d+)", open(logpath, "rb").read()
        )
        if m:
            url = f"http://127.0.0.1:{int(m.group(1))}/metrics"
            try:
                body = urllib.request.urlopen(url, timeout=5).read().decode()
            except OSError:
                time.sleep(0.5)
                continue
            assert "fed_" in body, f"metrics endpoint served no fed_ series:\n{body}"
            print(f"PASS: live metrics endpoint "
                  f"({sum(1 for l in body.splitlines() if l and l[0] != '#')} series)")
            return
        time.sleep(0.2)
    sys.exit("metrics endpoint never came up")


def _check_trace(args, expect_faults):
    """Validate the merged trace with the report CLI: every span accounted
    for, no orphan dispatch ids, injected faults present in the audit."""
    cmd = [sys.executable, "-m", "repro.obs.report", args.trace_dir, "--check",
           "--chrome", os.path.join(args.trace_dir, "trace.json")]
    if expect_faults:
        cmd.append("--expect-faults")
    subprocess.run(cmd, check=True, env=_env())
    print(f"PASS: trace check ({args.trace_dir})")


def demo_chaos(args, tmp):
    rounds, ckpt = 2, os.path.join(tmp, "sock_ck")
    server, port = _start_server(
        args, rounds, ckpt, os.path.join(tmp, "server.log")
    )
    workers = []
    for i in range(2):
        cmd = _worker_cmd(
            args, rounds, port, f"w{i}",
            chaos={"drop": 0.10, "delay": 0.15, "kill": 0.04, "seed": 7 + i},
        )
        workers.append((_spawn(cmd, os.path.join(tmp, f"worker{i}.log")), cmd))
    if args.trace_dir:
        _probe_metrics(server, os.path.join(tmp, "server.log"))
    respawns = _supervise_workers(workers, server, tmp, respawn=True)
    assert server.returncode == 0, open(os.path.join(tmp, "server.log")).read()
    assert _round_complete(ckpt, rounds - 1), "chaos run never finished"
    log = open(os.path.join(tmp, "server.log")).read()
    losses = [float(m) for m in re.findall(r"loss=([\d.]+)", log)]
    assert losses and all(np.isfinite(losses)), "non-finite loss under chaos"
    print(f"PASS: chaos run converged (final loss {losses[-1]:.4f}, "
          f"{respawns} worker respawns absorbed)")
    if args.trace_dir:
        _check_trace(args, expect_faults=True)


def demo_corrupt(args, tmp):
    """Payload-level Byzantine chaos against the defended server: one worker
    corrupts most of its pushes (NaN/Inf deltas — the frames themselves stay
    CRC-valid, so only the server's delta screen stands between the poison and
    the model), the other stays honest. The screened door must reject every
    poisoned push, the run must converge on the honest ones, and the merged
    trace must carry ``screen_reject`` instants covering each ``corrupt_*``
    fault (``report --check --expect-faults`` audits exactly that)."""
    if not args.trace_dir:  # the audit IS the demo — always trace
        args.trace_dir = os.path.join(tmp, "trace")
        os.makedirs(args.trace_dir, exist_ok=True)
    rounds, ckpt = 2, os.path.join(tmp, "sock_ck")
    server, port = _start_server(
        args, rounds, ckpt, os.path.join(tmp, "server.log"),
        extra=["--screen", "--screen-warmup", "2", "--quarantine-rounds", "1"],
    )
    workers = []
    for i in range(2):
        cmd = _worker_cmd(
            args, rounds, port, f"w{i}",
            chaos={"corrupt": 0.9 if i == 0 else 0.0,
                   "corrupt_kinds": "nan,inf", "seed": 11 + i},
        )
        workers.append((_spawn(cmd, os.path.join(tmp, f"worker{i}.log")), cmd))
    _supervise_workers(workers, server, tmp, respawn=True)
    assert server.returncode == 0, open(os.path.join(tmp, "server.log")).read()
    assert _round_complete(ckpt, rounds - 1), "corrupted run never finished"
    log = open(os.path.join(tmp, "server.log")).read()
    losses = [float(m) for m in re.findall(r"loss=([\d.]+)", log)]
    assert losses and all(np.isfinite(losses)), "non-finite loss under corruption"

    merged = "".join(
        open(os.path.join(args.trace_dir, f)).read()
        for f in os.listdir(args.trace_dir) if f.endswith(".jsonl")
    )
    n_corrupt = merged.count('"corrupt_')
    n_screen = merged.count('"screen_reject"')
    assert n_corrupt > 0, "chaos never corrupted a payload (dice too kind?)"
    assert n_screen > 0, "delta screen never fired on a poisoned push"
    print(f"PASS: corrupt run converged (final loss {losses[-1]:.4f}, "
          f"{n_corrupt} corruptions injected, {n_screen} screen rejections)")
    _check_trace(args, expect_faults=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", default="round",
                    choices=["round", "kill-resume", "chaos", "corrupt"])
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--trace-dir", default=None,
                    help="write per-process --trace JSONL here, probe the "
                         "live /metrics endpoint, and validate the merged "
                         "trace with repro.obs.report (chaos demo)")
    ap.add_argument("--keep-tmp", action="store_true")
    args = ap.parse_args()
    if args.trace_dir:
        os.makedirs(args.trace_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=f"socket_fed_{args.demo.replace('-', '_')}_")
    print(f"workdir: {tmp}")
    {"round": demo_round, "kill-resume": demo_kill_resume,
     "chaos": demo_chaos, "corrupt": demo_corrupt}[args.demo](args, tmp)
    if not args.keep_tmp:
        import shutil

        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
