"""Batched serving example: prefill + greedy decode with a KV/SSM cache across three
architecture families (dense GQA, Mamba2/SSD, sliding-window) — the request path that
decode_32k / long_500k lower on the production mesh.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import build_model

ARCHS = ["qwen3-1.7b", "mamba2-1.3b", "gemma3-4b"]


def main():
    rng = np.random.RandomState(0)
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompt = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 24)), jnp.int32)
        t0 = time.time()
        out = generate(model, params, prompt, max_new=8)
        dt = time.time() - t0
        print(f"{arch:14s} [{cfg.family:6s}] generated {out.shape[1]-24} tokens/seq "
              f"x{out.shape[0]} in {dt:.1f}s -> {np.asarray(out[0, -8:]).tolist()}")


if __name__ == "__main__":
    main()
