"""Heterogeneous federation (paper §6.3 + Fig 4 + §7): eight institutions with
different text domains (the Pile categories) collaborate; no bucket is ever shared
between two clients (§6.2.1). On top of the statistical heterogeneity this run layers
the paper's §7 *systems* heterogeneity: clients churn on/off (Markov availability),
fail mid-round (seeded dropout), run on unequal hardware (heavy straggler profile),
and hold unequal corpora (FedAvg data-size weighting) — all inside one jitted round,
with the per-round weight vector carrying the elasticity. Tracks the consensus metric
through the initial disagreement phase plus the effective cohort per round.

  PYTHONPATH=src python examples/heterogeneous_federation.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    STRAGGLER_PROFILES,
    FederatedConfig,
    InnerOptConfig,
    OuterOptConfig,
    ParticipationConfig,
    federated_round,
    init_federated_state,
    plan_round,
)
from repro.data import PILE_CATEGORIES, build_client_streams, round_batches, validation_stream
from repro.metrics import evaluate_perplexity
from repro.models import build_model

ROUNDS, TAU, CLIENTS, BATCH, SEQ, SEED = 5, 8, 8, 2, 64, 0


def main():
    cfg = get_config("photon-75m").reduced()
    model = build_model(cfg)
    fed = FederatedConfig(
        clients_per_round=CLIENTS,
        local_steps=TAU,
        inner=InnerOptConfig(lr_max=1e-3, warmup_steps=4, total_steps=ROUNDS * TAU),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    state = init_federated_state(fed, model.init(jax.random.PRNGKey(0)))

    # one client per Pile category — publishers from different domains (Fig 1)
    streams = build_client_streams(
        CLIENTS, SEQ, cfg.vocab_size, heterogeneous=True,
        n_categories=len(PILE_CATEGORIES), j_max=1,
    )
    print("clients:", ", ".join(PILE_CATEGORIES[:CLIENTS]))
    val = validation_stream(SEQ, cfg.vocab_size, heterogeneous=True)

    # systems heterogeneity on top of the statistical kind
    pcfg = ParticipationConfig(
        population=CLIENTS,
        clients_per_round=CLIENTS,
        model="markov",
        dropout_rate=0.15,
        straggler=STRAGGLER_PROFILES["heavy"],
        weighting="examples",
    )

    round_fn = jax.jit(
        lambda s, b, w: federated_round(model.loss, fed, s, b, client_weights=w)
    )
    for rnd in range(ROUNDS):
        plan = plan_round(pcfg, SEED, rnd)
        # bind streams by the plan's slot ids so weights stay aligned with data
        # even when population > clients_per_round
        batches = round_batches([streams[i] for i in plan.selected], TAU, BATCH)
        state, m = round_fn(
            state,
            {k: jnp.asarray(v) for k, v in batches.items()},
            jnp.asarray(plan.weights),
        )
        ppl = evaluate_perplexity(model, state["params"], val, batches=2, batch_size=BATCH)
        print(
            f"round {rnd}: loss={float(m['train_loss']):.3f} val_ppl={ppl:.1f} "
            f"consensus={float(m['client_consensus']):.3f} "
            f"pg_norm={float(m['pseudo_grad_norm']):.4f} "
            f"eff_K={plan.effective_k}/{CLIENTS} "
            f"stragglers={plan.n_stragglers} dropped={plan.n_dropped} "
            f"w_entropy={float(m['weight_entropy']):.2f}"
        )
    print("heterogeneous federation converged under churn (paper claims C3 + §7).")


if __name__ == "__main__":
    main()
