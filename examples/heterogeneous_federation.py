"""Heterogeneous federation (paper §6.3 + Fig 4 + §7): eight institutions with
different text domains (the Pile categories) collaborate; no bucket is ever shared
between two clients (§6.2.1). On top of the statistical heterogeneity this run layers
the paper's §7 *systems* heterogeneity: clients churn on/off (Markov availability),
fail mid-round (seeded dropout), run on unequal hardware (heavy straggler profile),
and hold unequal corpora (FedAvg data-size weighting) — all inside one jitted round,
with the per-round weight vector carrying the elasticity. Tracks the consensus metric
through the initial disagreement phase plus the effective cohort per round.

``--aggregation async`` swaps the deadline-masking synchronous round for Photon's
FedBuff-style buffered aggregator (``core/async_agg``): the same heterogeneous
clients run on an event-driven timeline, slow institutions finish late and land in
later buffers with staleness-discounted weights, and the server applies one outer
update per ``--buffer-size`` admitted deltas — no straggler's work is discarded.

``--uplink`` compresses each institution's pseudo-gradient before it crosses the
wire (``core/compression`` codecs); with ``topk``, every client carries its own
error-feedback residual — under async dispatch the residuals stay keyed by client
id across interleaved completions and buffer flushes.

``--partial-progress`` swaps the deadline CUT for straggler partial progress
(the ``core/aggregator`` seam): a slow institution contributes the τ_i steps it
actually finished, down-weighted by τ_i/τ, instead of losing its whole round.

``--control`` closes the loop between the observed telemetry and the knobs
(``repro.control``, docs/control.md): ``staleness`` (async) governs the buffer
size and staleness discount toward a ``--control-target`` admitted-staleness
quantile; ``cohort`` (sync) tunes the straggler deadline from the effective-K
fraction. Applied knob updates print per round and, with ``--trace``, land as
``knob_update`` events (with evidence) in the JSONL.

  PYTHONPATH=src python examples/heterogeneous_federation.py
  PYTHONPATH=src python examples/heterogeneous_federation.py --aggregation async --rounds 2
  PYTHONPATH=src python examples/heterogeneous_federation.py --aggregation async \
      --uplink topk --rounds 2
  PYTHONPATH=src python examples/heterogeneous_federation.py --partial-progress \
      --straggler-profile heavy --rounds 2
  PYTHONPATH=src python examples/heterogeneous_federation.py --aggregation async \
      --control staleness --control-target 3 --rounds 2 --trace /tmp/hetero.jsonl
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.control import CohortTuner, FederationController, StalenessGovernor
from repro.core import (
    STRAGGLER_PROFILES,
    UPLINK_SCHEMES,
    AsyncAggConfig,
    AsyncFederationDriver,
    FederatedConfig,
    InnerOptConfig,
    OuterOptConfig,
    ParticipationConfig,
    SyncAggregator,
    get_codec,
    uplink_bytes,
)
from repro.data import PILE_CATEGORIES, build_client_streams, round_batches, validation_stream
from repro.metrics import evaluate_perplexity, participation_metrics, partial_progress_metrics
from repro.models import build_model
from repro.obs import JsonlSink, Tracer

TAU, CLIENTS, BATCH, SEQ, SEED = 8, 8, 2, 64, 0


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--aggregation", default="sync", choices=["sync", "async"])
    ap.add_argument("--rounds", type=int, default=5,
                    help="sync rounds, or async outer updates")
    ap.add_argument("--buffer-size", type=int, default=4,
                    help="async: deltas per outer update")
    ap.add_argument("--staleness-alpha", type=float, default=0.5)
    ap.add_argument("--uplink", default="float32", choices=list(UPLINK_SCHEMES),
                    help="pseudo-gradient uplink codec")
    ap.add_argument("--topk-fraction", type=float, default=0.05)
    ap.add_argument("--straggler-profile", default="heavy",
                    choices=sorted(STRAGGLER_PROFILES),
                    help="hardware-heterogeneity preset")
    ap.add_argument("--partial-progress", action="store_true",
                    help="credit stragglers their realized τ_i steps at weight "
                         "τ_i/τ instead of cutting them at the deadline")
    ap.add_argument("--control", default="static",
                    choices=["static", "staleness", "cohort"],
                    help="closed-loop knob control (docs/control.md): "
                         "staleness needs --aggregation async, cohort sync")
    ap.add_argument("--control-target", type=float, default=None,
                    help="policy setpoint: staleness-quantile value (async) "
                         "or effective-K fraction (sync)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="append structured events (incl. knob_update) to "
                         "this JSONL file")
    return ap.parse_args()


def build_controller(args):
    """``--control`` → controller (or None). Mirrors train.py's pairing rules
    at example scale: the governor owns async knobs, the tuner sync ones."""
    if args.control == "static":
        return None
    if args.control == "staleness":
        if args.aggregation != "async":
            raise SystemExit("--control staleness requires --aggregation async")
        policy = StalenessGovernor(
            staleness_alpha=args.staleness_alpha,
            buffer_size=args.buffer_size,
            target=args.control_target if args.control_target is not None else 1.0,
            buffer_max=max(args.buffer_size, CLIENTS),
        )
    else:
        if args.aggregation != "sync":
            raise SystemExit("--control cohort requires --aggregation sync")
        policy = CohortTuner(
            clients_per_round=CLIENTS,
            deadline=STRAGGLER_PROFILES[args.straggler_profile].deadline,
            population=CLIENTS,
            target=args.control_target if args.control_target is not None else 0.9,
        )
    # window=2: the example runs only a handful of updates, so decisions must
    # fire off early evidence
    return FederationController(policy, window=2)


def main():
    args = parse_args()
    cfg = get_config("photon-75m").reduced()
    model = build_model(cfg)
    fed = FederatedConfig(
        clients_per_round=CLIENTS,
        local_steps=TAU,
        inner=InnerOptConfig(lr_max=1e-3, warmup_steps=4, total_steps=args.rounds * TAU),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )

    # one client per Pile category — publishers from different domains (Fig 1)
    streams = build_client_streams(
        CLIENTS, SEQ, cfg.vocab_size, heterogeneous=True,
        n_categories=len(PILE_CATEGORIES), j_max=1,
    )
    print("clients:", ", ".join(PILE_CATEGORIES[:CLIENTS]))
    val = validation_stream(SEQ, cfg.vocab_size, heterogeneous=True)

    # systems heterogeneity on top of the statistical kind
    pcfg = ParticipationConfig(
        population=CLIENTS,
        clients_per_round=CLIENTS,
        model="markov",
        dropout_rate=0.15,
        straggler=STRAGGLER_PROFILES[args.straggler_profile],
        weighting="examples",
        partial_progress=args.partial_progress,
        local_steps=TAU if args.partial_progress else 0,
    )

    codec = (
        get_codec(args.uplink, args.topk_fraction)
        if args.uplink != "float32" else None
    )
    tracer = (
        Tracer(sink=JsonlSink(args.trace), proc="example", trace_id="hetero")
        if args.trace else None
    )
    controller = build_controller(args)
    if args.aggregation == "async":
        try:
            run_async(args, cfg, model, fed, pcfg, streams, val, codec,
                      tracer=tracer, controller=controller)
        finally:
            if tracer is not None:
                tracer.close()
        return

    params = model.init(jax.random.PRNGKey(0))
    if codec is not None:
        print(f"uplink codec: {codec!r} "
              f"({uplink_bytes(params, 'float32') / codec.nbytes(params):.1f}x "
              f"fewer bytes per upload)")
    # the Aggregator seam owns admission (the plan's mask / partial τ_i), the
    # weight policy (n_k·τ_i/τ) and the checkpoint schema; the example only
    # moves batches
    agg = SyncAggregator(model.loss, fed, pcfg, codec=codec, seed=SEED,
                         params=params, tracer=tracer, controller=controller)
    for rnd in range(args.rounds):
        plan = agg.plan(rnd)
        # bind streams by the plan's slot ids so weights stay aligned with data
        # even when population > clients_per_round
        batches = round_batches([streams[i] for i in plan.selected], TAU, BATCH)
        m = agg.run_round({k: jnp.asarray(v) for k, v in batches.items()}, plan)
        ppl = evaluate_perplexity(model, agg.state["params"], val, batches=2, batch_size=BATCH)
        partial = ""
        if args.partial_progress:
            pm = partial_progress_metrics(plan, TAU)
            partial = (f" tau={pm['partial_tau_mean']:.2f} "
                       f"rescued={pm['partial_rescued_clients']:.0f}")
        print(
            f"round {rnd}: loss={float(m['train_loss']):.3f} val_ppl={ppl:.1f} "
            f"consensus={float(m['client_consensus']):.3f} "
            f"pg_norm={float(m['pseudo_grad_norm']):.4f} "
            f"eff_K={plan.effective_k}/{CLIENTS} "
            f"stragglers={plan.n_stragglers} dropped={plan.n_dropped} "
            f"w_entropy={float(m['weight_entropy']):.2f}{partial}"
        )
        # round-boundary control point: the cohort tuner may retune the
        # deadline/cohort for the next round from this round's participation
        update = agg.control_step({
            **participation_metrics(plan),
            **partial_progress_metrics(plan, TAU),
        })
        if update is not None:
            print("  control: " + ", ".join(
                f"{k}={v:g}" for k, v in update.knob_dict().items()
            ))
    if tracer is not None:
        tracer.close()
    print("heterogeneous federation converged under churn (paper claims C3 + §7).")


def run_async(args, cfg, model, fed, pcfg, streams, val, codec=None,
              tracer=None, controller=None):
    """The same federation, asynchronously: slow institutions finish late and are
    buffered with staleness discounts instead of being cut at the deadline."""
    acfg = AsyncAggConfig(
        buffer_size=args.buffer_size, staleness_alpha=args.staleness_alpha
    )

    def make_batches(cid):
        b = round_batches([streams[cid]], TAU, BATCH)
        return {k: jnp.asarray(v) for k, v in b.items()}

    params = model.init(jax.random.PRNGKey(0))
    if codec is not None:
        print(f"uplink codec: {codec!r} "
              f"({uplink_bytes(params, 'float32') / codec.nbytes(params):.1f}x "
              f"fewer bytes per upload)")
    driver = AsyncFederationDriver(
        model.loss, fed, acfg, pcfg, make_batches,
        seed=SEED, params=params, codec=codec,
        tracer=tracer, controller=controller,
    )

    def on_update(i, row):
        ppl = evaluate_perplexity(
            model, driver.state["params"], val, batches=2, batch_size=BATCH
        )
        print(
            f"update {i}: loss={row['train_loss_mean']:.3f} val_ppl={ppl:.1f} "
            f"consensus={row['client_consensus']:.3f} "
            f"pg_norm={row['pseudo_grad_norm']:.4f} "
            f"staleness={row['staleness_mean']:.2f}/{row['staleness_max']:.0f} "
            f"buf={row['buffer_fill']:.0f}/{driver.acfg.buffer_size} "
            f"t_sim={row['sim_time']:.2f}"
        )
        knobs = {k[len("knob_"):]: v for k, v in row.items()
                 if k.startswith("knob_")}
        if knobs:
            print("  control: " + ", ".join(
                f"{k}={v:g}" for k, v in knobs.items()
            ))

    driver.run_updates(args.rounds, on_update=on_update)
    driver.finalize_trace()
    uplink = (
        f", uplink: {driver.uplink_bytes_total / 1e6:.1f} MB" if codec else ""
    )
    print(
        f"async federation applied {args.rounds} buffered updates in "
        f"{driver.sim_time:.2f} simulated median-rounds "
        f"(client work aggregated: {driver.work_completed:.1f}, "
        f"wasted: {driver.work_wasted:.1f}{uplink}) — no straggler discarded."
    )


if __name__ == "__main__":
    main()
