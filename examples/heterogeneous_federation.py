"""Heterogeneous federation (paper §6.3 + Fig 4): eight institutions with different
text domains (the Pile categories) collaborate; no bucket is ever shared between two
clients (§6.2.1). Tracks the consensus metric through the initial disagreement phase.

  PYTHONPATH=src python examples/heterogeneous_federation.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import FederatedConfig, InnerOptConfig, OuterOptConfig, federated_round, init_federated_state
from repro.data import PILE_CATEGORIES, build_client_streams, round_batches, validation_stream
from repro.metrics import evaluate_perplexity
from repro.models import build_model

ROUNDS, TAU, CLIENTS, BATCH, SEQ = 5, 8, 8, 2, 64


def main():
    cfg = get_config("photon-75m").reduced()
    model = build_model(cfg)
    fed = FederatedConfig(
        clients_per_round=CLIENTS,
        local_steps=TAU,
        inner=InnerOptConfig(lr_max=1e-3, warmup_steps=4, total_steps=ROUNDS * TAU),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    state = init_federated_state(fed, model.init(jax.random.PRNGKey(0)))

    # one client per Pile category — publishers from different domains (Fig 1)
    streams = build_client_streams(
        CLIENTS, SEQ, cfg.vocab_size, heterogeneous=True,
        n_categories=len(PILE_CATEGORIES), j_max=1,
    )
    print("clients:", ", ".join(PILE_CATEGORIES[:CLIENTS]))
    val = validation_stream(SEQ, cfg.vocab_size, heterogeneous=True)

    round_fn = jax.jit(lambda s, b: federated_round(model.loss, fed, s, b))
    for rnd in range(ROUNDS):
        batches = round_batches(streams, TAU, BATCH)
        state, m = round_fn(state, {k: jnp.asarray(v) for k, v in batches.items()})
        ppl = evaluate_perplexity(model, state["params"], val, batches=2, batch_size=BATCH)
        print(
            f"round {rnd}: loss={float(m['train_loss']):.3f} val_ppl={ppl:.1f} "
            f"consensus={float(m['client_consensus']):.3f} "
            f"pg_norm={float(m['pseudo_grad_norm']):.4f}"
        )
    print("heterogeneous federation converged (paper claim C3).")


if __name__ == "__main__":
    main()
