"""Quickstart: federated pre-training of a tiny Photon model in ~a minute on CPU.

Demonstrates the smallest end-to-end loop: config -> model -> data sources ->
synchronous federated rounds -> held-out evaluation. This is deliberately the
BOTTOM of the stack (docs/architecture.md) — the pure jitted `federated_round`
driven by hand. Everything layered above it is opt-in elsewhere:

- `--aggregation {sync,async}` — deadline-cut rounds vs the FedBuff buffer
  (examples/heterogeneous_federation.py, docs/aggregation.md)
- `--uplink {float32,bf16,int8,topk}` — compressed pseudo-gradient uploads
  with per-client error feedback (docs/uplink.md)
- `--runtime {inproc,sockets}` — the same aggregator across real server/worker
  processes (examples/socket_federation.py, docs/runtime.md)
- `--control {static,staleness,cohort}` — closed-loop knob tuning from live
  telemetry (docs/control.md)

All four compose in `launch/train.py` (`--help` is the full flag reference).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    FederatedConfig,
    InnerOptConfig,
    OuterOptConfig,
    federated_round,
    init_federated_state,
    sample_round,
)
from repro.data import build_client_streams, round_batches, validation_stream
from repro.metrics import evaluate_perplexity
from repro.models import build_model

ROUNDS, TAU, CLIENTS, POP, BATCH, SEQ = 4, 8, 4, 8, 2, 64


def main():
    # 1. model: the paper's smallest MPT-style config, reduced for CPU
    cfg = get_config("photon-75m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} reduced -> {sum(x.size for x in jax.tree_util.tree_leaves(params)):,} params")

    # 2. federated configuration (Algorithm 1)
    fed = FederatedConfig(
        clients_per_round=CLIENTS,
        local_steps=TAU,
        inner=InnerOptConfig(lr_max=1e-3, warmup_steps=4, total_steps=ROUNDS * TAU),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    state = init_federated_state(fed, params)

    # 3. Photon Data Sources: one private stream per population member
    streams = build_client_streams(POP, SEQ, cfg.vocab_size, heterogeneous=False)
    val = validation_stream(SEQ, cfg.vocab_size, heterogeneous=False)

    # 4. rounds: sample K clients, run tau local steps each, aggregate once
    round_fn = jax.jit(lambda s, b: federated_round(model.loss, fed, s, b))
    for rnd in range(ROUNDS):
        sel = sample_round(0, rnd, POP, CLIENTS)
        batches = round_batches([streams[i] for i in sel], TAU, BATCH)
        state, metrics = round_fn(state, {k: jnp.asarray(v) for k, v in batches.items()})
        ppl = evaluate_perplexity(model, state["params"], val, batches=2, batch_size=BATCH)
        print(
            f"round {rnd}: clients={sel.tolist()} loss={float(metrics['train_loss']):.3f} "
            f"val_ppl={ppl:.1f} consensus={float(metrics['client_consensus']):.3f}"
        )

    print("done — the global model improved without any client sharing raw data.")


if __name__ == "__main__":
    main()
