"""Paper Fig 4 / Fig 5 (claim C3): convergence survives natural data heterogeneity.

Runs the same federation over the IID partition and over the Pile-style J x |C|
category partition; derived output compares final validation perplexity and the
client-consensus trajectory (heterogeneous starts lower, recovers)."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_fed, tiny_cfg


def main(quick: bool = False) -> None:
    rounds, tau = (4, 6) if quick else (7, 8)
    cfg = tiny_cfg(d_model=128)
    t0 = time.time()
    iid = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=4, heterogeneous=False)
    het = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=4, heterogeneous=True)
    dt = (time.time() - t0) * 1e6
    iid_ppl = iid["history"][-1]["val_ppl"]
    het_ppl = het["history"][-1]["val_ppl"]
    iid_first = iid["history"][0]["val_ppl"]
    het_first = het["history"][0]["val_ppl"]
    emit(
        "heterogeneity/iid",
        dt / (2 * rounds * tau),
        f"val_ppl_first={iid_first:.1f} val_ppl_final={iid_ppl:.1f} "
        f"consensus_final={iid['history'][-1]['client_consensus']:.3f}",
    )
    emit(
        "heterogeneity/pile_partition",
        dt / (2 * rounds * tau),
        f"val_ppl_first={het_first:.1f} val_ppl_final={het_ppl:.1f} "
        f"consensus_final={het['history'][-1]['client_consensus']:.3f} "
        f"converges={het_ppl < 0.8 * het_first}",
    )


if __name__ == "__main__":
    main()
