"""Paper Fig 7 / Fig 8 (+App. Fig 11-15, claim C6): norm dynamics — the pseudo-gradient
norm decays towards/below the applied local-gradient norm as clients reach consensus,
and client/global model norms converge."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_fed, tiny_cfg


def main(quick: bool = False) -> None:
    rounds, tau = (5, 6) if quick else (8, 8)
    cfg = tiny_cfg(d_model=128)
    t0 = time.time()
    r = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=4)
    dt = (time.time() - t0) * 1e6 / (rounds * tau)
    h = r["history"]
    pg_first, pg_last = h[0]["pseudo_grad_norm"], h[-1]["pseudo_grad_norm"]
    emit(
        "norm_dynamics/pseudo_gradient",
        dt,
        f"pg_norm_first={pg_first:.4f} pg_norm_last={pg_last:.4f} "
        f"decay={pg_last/max(pg_first,1e-9):.3f} (paper: decays with consensus)",
    )
    gap_first = abs(h[0]["global_model_norm"] - h[0]["client_model_norm_mean"])
    gap_last = abs(h[-1]["global_model_norm"] - h[-1]["client_model_norm_mean"])
    emit(
        "norm_dynamics/model_norm_consensus",
        dt,
        f"global_vs_client_gap_first={gap_first:.3f} gap_last={gap_last:.3f} "
        f"consensus_last={h[-1]['client_consensus']:.3f}",
    )
    emit(
        "norm_dynamics/applied_vs_pseudo",
        dt,
        f"applied_update_norm={h[-1]['applied_update_norm']:.5f} "
        f"pseudo_grad_norm={h[-1]['pseudo_grad_norm']:.5f}",
    )


if __name__ == "__main__":
    main()
