"""Paper Fig 6 (claim C4): sampling a small client cohort per round matches full
participation. Full K=P vs partial K=P/4 on the same population."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_fed, tiny_cfg


def main(quick: bool = False) -> None:
    rounds, tau, pop = (4, 6, 8) if quick else (7, 8, 8)
    cfg = tiny_cfg(d_model=128)
    t0 = time.time()
    full = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=pop, population=pop)
    part = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=pop // 4, population=pop)
    dt = (time.time() - t0) * 1e6
    f_ppl = full["history"][-1]["val_ppl"]
    p_ppl = part["history"][-1]["val_ppl"]
    emit(
        "partial_participation/full_K8",
        dt / (2 * rounds * tau),
        f"val_ppl={f_ppl:.1f} parallel_compute=1.0x",
    )
    emit(
        "partial_participation/sampled_K2",
        dt / (2 * rounds * tau),
        f"val_ppl={p_ppl:.1f} parallel_compute=0.25x rel_gap={(p_ppl-f_ppl)/f_ppl:+.3f}",
    )


if __name__ == "__main__":
    main()
