"""Paper Fig 6 (claim C4) + §7 robustness: sampling a small cohort per round matches
full participation, and convergence survives availability churn, mid-round dropout,
and straggler cuts. All elastic scenarios run through the SAME jitted round — the
weight vector, not the compiled computation, carries the per-round cohort."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_fed, tiny_cfg


def _scenario_stats(out):
    hist = out["history"]
    return {
        "val_ppl": hist[-1]["val_ppl"],
        "eff_k": float(np.mean([h["effective_k"] for h in hist])),
        "stragglers": int(sum(h["straggler_count"] for h in hist)),
        "dropped": int(sum(h["dropout_count"] for h in hist)),
        "seconds": out["seconds"],
    }


def main(quick: bool = False) -> None:
    rounds, tau, pop = (4, 6, 8) if quick else (7, 8, 8)
    cfg = tiny_cfg(d_model=128)
    scenarios = [
        ("full_K8", dict(clients=pop)),
        ("sampled_K2", dict(clients=pop // 4)),
        (
            "markov_dropout",
            dict(
                clients=pop // 2,
                extra=["--participation", "markov", "--dropout-rate", "0.25"],
            ),
        ),
        (
            "stragglers_weighted",
            dict(
                clients=pop // 2,
                extra=[
                    "--straggler-profile", "heavy", "--client-weighting", "examples",
                ],
            ),
        ),
    ]

    results = {}
    for name, kw in scenarios:
        out = run_fed(cfg=cfg, rounds=rounds, tau=tau, population=pop, **kw)
        results[name] = _scenario_stats(out)

    base_ppl = results["full_K8"]["val_ppl"]
    for name, s in results.items():
        rel = (s["val_ppl"] - base_ppl) / base_ppl
        emit(
            f"partial_participation/{name}",
            s["seconds"] * 1e6 / (rounds * tau),  # per local step, this scenario
            f"val_ppl={s['val_ppl']:.1f} rel_gap={rel:+.3f} "
            f"mean_eff_K={s['eff_k']:.1f} stragglers={s['stragglers']} "
            f"dropped={s['dropped']}",
        )


if __name__ == "__main__":
    main()
