"""Paper Fig 3 / Fig 9 (claims C1 + C2): federated matches centralized, and the gap
shrinks as the model grows.

CPU-scale instantiation: two model widths trained federated (K=4, tau=8) and
centralized on the SAME token budget from the same IID stream family; derived output
reports the fed-central perplexity gap per size."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_centralized, run_fed, tiny_cfg


def main(quick: bool = False) -> None:
    rounds, tau, clients, batch = (4, 6, 2, 2) if quick else (10, 8, 4, 2)
    gaps = {}
    for d_model in (64, 256):
        cfg = tiny_cfg(d_model=d_model)
        t0 = time.time()
        fed = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=clients,
                      extra=["--eval-batches", "8"])
        central = run_centralized(
            cfg=cfg, steps=rounds * tau, batch=clients * batch
        )
        dt = (time.time() - t0) * 1e6
        fed_ppl = fed["history"][-1]["val_ppl"]
        cen_ppl = central["val_ppl"]
        gap = (fed_ppl - cen_ppl) / cen_ppl
        gaps[d_model] = gap
        emit(
            f"fed_vs_central/d{d_model}",
            dt / (rounds * tau),
            f"fed_ppl={fed_ppl:.2f} central_ppl={cen_ppl:.2f} rel_gap={gap:+.3f}",
        )
    trend = "shrinks" if gaps[256] <= gaps[64] + 0.05 else "grows"
    emit(
        "fed_vs_central/gap_trend",
        0.0,
        f"gap_small={gaps[64]:+.3f} gap_large={gaps[256]:+.3f} trend={trend} "
        f"(paper C2: larger models close the gap)",
    )


if __name__ == "__main__":
    main()
