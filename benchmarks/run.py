"""Benchmark harness — one module per paper table/figure (deliverable (d)).

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # full set
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-speed subset
  PYTHONPATH=src python -m benchmarks.run --only heterogeneity
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_adaptive_control,
    bench_async_vs_sync,
    bench_communication,
    bench_compressed_uplink,
    bench_eval_harness,
    bench_fed_vs_central,
    bench_heterogeneity,
    bench_kernels,
    bench_norm_dynamics,
    bench_outer_optimizers,
    bench_partial_participation,
    bench_population_scale,
    bench_robust_agg,
    bench_scaling_table,
)

BENCHES = [
    ("scaling_table", bench_scaling_table),  # Tables 1-3
    ("communication", bench_communication),  # §4.3 / C7
    ("compressed_uplink", bench_compressed_uplink),  # codec bytes-vs-perplexity
    ("kernels", bench_kernels),  # kernel layer
    ("fed_vs_central", bench_fed_vs_central),  # Fig 3/9, C1-C2
    ("heterogeneity", bench_heterogeneity),  # Fig 4/5, C3
    ("partial_participation", bench_partial_participation),  # Fig 6, C4
    ("async_vs_sync", bench_async_vs_sync),  # FedBuff buffer vs deadline masking
    ("population_scale", bench_population_scale),  # flat memory in P (ISSUE 9)
    ("adaptive_control", bench_adaptive_control),  # closed-loop knob tuning
    ("robust_agg", bench_robust_agg),  # Byzantine resilience (ISSUE 10)
    ("outer_optimizers", bench_outer_optimizers),  # Fig 10, C5
    ("norm_dynamics", bench_norm_dynamics),  # Fig 7/8, C6
    ("eval_harness", bench_eval_harness),  # Tables 5/6 proxy
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod.main(quick=args.quick)
        except Exception:
            failures += 1
            print(f"{name},0,FAILED", file=sys.stdout)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
