"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional

import jax

from repro.configs import get_config
from repro.launch.train import parse_args, run

BASE_ARGS = [
    "--arch", "photon-75m", "--reduced", "--seq-len", "64", "--batch", "2",
    "--eval-batches", "2",
]


def tiny_cfg(d_model: int = 128, n_layers: int = 2, vocab: int = 512):
    cfg = get_config("photon-75m").reduced()
    return dataclasses.replace(
        cfg,
        name=f"photon-tiny-{d_model}",
        d_model=d_model,
        n_layers=n_layers,
        n_heads=max(2, d_model // 64),
        n_kv_heads=max(2, d_model // 64),
        d_ff=4 * d_model,
        vocab_size=vocab,
    )


def run_fed(
    *,
    cfg=None,
    rounds: int = 6,
    tau: int = 8,
    clients: int = 4,
    population: Optional[int] = None,
    heterogeneous: bool = False,
    outer: str = "fedavg",
    outer_lr: float = 1.0,
    keep_opt: bool = False,
    inner_lr: float = 1e-3,
    seed: int = 0,
    extra: Optional[List[str]] = None,
):
    argv = BASE_ARGS + [
        "--rounds", str(rounds), "--local-steps", str(tau), "--clients", str(clients),
        "--population", str(population or clients), "--outer", outer,
        "--outer-lr", str(outer_lr), "--inner-lr", str(inner_lr), "--seed", str(seed),
    ]
    if heterogeneous:
        argv.append("--heterogeneous")
    if keep_opt:
        argv.append("--keep-opt")
    argv += extra or []
    t0 = time.time()
    out = run(parse_args(argv), cfg=cfg)
    out["seconds"] = time.time() - t0
    return out


def run_centralized(*, cfg=None, steps: int = 48, batch: int = 8, inner_lr: float = 1e-3,
                    seed: int = 0, seq_len: int = 64):
    """Centralized baseline: same total tokens as a federated run with the same
    steps x batch, synchronizing every step."""
    import jax
    import jax.numpy as jnp

    from repro.core import InnerOptConfig, centralized_step, init_centralized_state
    from repro.data import build_client_streams, validation_stream
    from repro.metrics import evaluate_perplexity
    from repro.models import build_model

    cfg = cfg or tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    inner = InnerOptConfig(lr_max=inner_lr, warmup_steps=max(1, steps // 20),
                           total_steps=steps)
    state = init_centralized_state(inner, params)
    stream = build_client_streams(1, seq_len, cfg.vocab_size, heterogeneous=False)[0]
    loss_fn = lambda p, b: model.loss(p, b)
    step_fn = jax.jit(lambda s, b: centralized_step(loss_fn, inner, s, b))
    losses = []
    for _ in range(steps):
        batch_np = stream.next_batch(batch)
        state, m = step_fn(state, {"tokens": jnp.asarray(batch_np)})
        losses.append(float(m["ce"]))
    val = validation_stream(seq_len, cfg.vocab_size, False)
    ppl = evaluate_perplexity(model, state["params"], val, batches=2, batch_size=batch)
    return {"losses": losses, "val_ppl": ppl, "state": state, "model": model}


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


# --- memory measurement (used by bench_population_scale; available to all) ---


def rss_bytes() -> int:
    """CURRENT resident set size of this process in bytes (``VmRSS``).

    Unlike ``ru_maxrss`` (a monotonic high-water mark — useless for comparing
    phases within one process), VmRSS can go down, so sampling it around a
    phase measures THAT phase. Falls back to ru_maxrss where /proc is absent.
    """
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    import resource

    return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss) * 1024


def live_device_bytes() -> int:
    """Bytes held by live JAX device arrays (on the CPU backend this is the
    host-side arena the federation state actually occupies)."""
    import numpy as np

    total = 0
    for a in jax.live_arrays() if hasattr(jax, "live_arrays") else []:
        try:
            total += int(np.prod(a.shape)) * a.dtype.itemsize
        except Exception:
            pass
    return total


def tree_nbytes(tree) -> int:
    """Exact bytes of a pytree of arrays/ShapeDtypeStructs (no allocation)."""
    import numpy as np

    return sum(
        int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype")
    )


class PeakRss:
    """Context manager sampling VmRSS on a background thread; ``.peak`` is the
    max observed during the ``with`` block (bytes). Sampling at ~50 Hz catches
    transient buffers a before/after pair would miss."""

    def __init__(self, interval_s: float = 0.02):
        self.interval_s = interval_s
        self.peak = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.peak = max(self.peak, rss_bytes())
            self._stop.wait(self.interval_s)

    def __enter__(self) -> "PeakRss":
        self.peak = rss_bytes()
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
        self.peak = max(self.peak, rss_bytes())
