"""Paper Fig 10 (claim C5): outer-optimizer ablation — FedAvg vs SGD+Nesterov server
momentum vs FedAvg with kept local optimizer states."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_fed, tiny_cfg


def main(quick: bool = False) -> None:
    rounds, tau = (4, 6) if quick else (6, 8)
    cfg = tiny_cfg(d_model=128)
    results = {}
    t0 = time.time()
    results["fedavg"] = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=4)
    results["sgd_nesterov"] = run_fed(
        cfg=cfg, rounds=rounds, tau=tau, clients=4, outer="fedmom", outer_lr=0.7
    )
    results["fedavg_keepopt"] = run_fed(
        cfg=cfg, rounds=rounds, tau=tau, clients=4, keep_opt=True
    )
    dt = (time.time() - t0) * 1e6 / (3 * rounds * tau)
    finals = {}
    for name, r in results.items():
        h = r["history"]
        finals[name] = h[-1]["val_ppl"]
        emit(
            f"outer_opt/{name}",
            dt,
            f"val_ppl={h[-1]['val_ppl']:.1f} "
            f"model_norm={h[-1]['global_model_norm']:.1f} "
            f"train_loss={h[-1]['train_loss']:.3f}",
        )
    best = min(finals, key=finals.get)
    emit("outer_opt/winner", 0.0, f"best={best} (paper C5 recommends fedavg stateless)")


if __name__ == "__main__":
    main()
