"""Adaptive aggregation control vs a static FedBuff configuration: simulated
wall-clock-to-perplexity under heavy hardware heterogeneity (docs/control.md).

Both arms run the identical async buffered federation (same straggler
population, same seed, same client phase) with a deliberately over-provisioned
buffer: M = K, so every outer update waits for the full cohort and the admitted
staleness sits far below any reasonable target. The STATIC arm keeps those
knobs for the whole run — the PR-7 behaviour. The GOVERNED arm runs the same
launch with ``--control staleness``: the :class:`StalenessGovernor` watches the
admitted-staleness quantile from the flush telemetry, sees the headroom below
``--control-target``, and trades it away — halving the buffer (more outer
updates per simulated second) and walking the staleness discount α toward 0 —
until the observed quantile meets the setpoint.

The comparison metric is *simulated* wall-clock (median-client-round units) to
reach the static arm's final validation perplexity. The governed arm gets a
proportionally larger update budget (its flushes admit fewer deltas each, so
total admitted client work stays comparable), but the clock does not lie:
updates land when the buffer fills, and a smaller buffer fills sooner. The
acceptance criterion (asserted): the governed run reaches the static baseline's
final perplexity in STRICTLY fewer simulated seconds. Trajectories, the
governor's knob-update history (with evidence), and the summary land in
``BENCH_adaptive_control.json`` for the CI bench lane's artifact upload.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit, run_fed, tiny_cfg

CONTROL_JSON = "BENCH_adaptive_control.json"


def _time_to_target(times, ppls, target: float) -> float:
    for t, p in zip(times, ppls):
        if p <= target:
            return float(t)
    return float("inf")


def main(quick: bool = False) -> None:
    updates, tau, pop, k = (4, 6, 8, 4) if quick else (8, 8, 8, 4)
    cfg = tiny_cfg(d_model=128)

    # the misconfiguration under test: buffer as wide as the cohort, so the
    # server always waits for everyone and admitted staleness is ~0
    base = ["--aggregation", "async", "--straggler-profile", "heavy",
            "--client-weighting", "examples",
            "--buffer-size", str(k), "--staleness-alpha", "0.5"]

    static = run_fed(cfg=cfg, rounds=updates, tau=tau, clients=k,
                     population=pop, extra=base)
    # the governor shrinks the buffer toward 1, so each governed flush admits
    # fewer deltas — give it updates·K/1 worth of budget upper-bounded by 3x
    # the static count to hold total admitted client work comparable
    governed = run_fed(
        cfg=cfg, rounds=3 * updates, tau=tau, clients=k, population=pop,
        extra=base + ["--control", "staleness", "--control-target", "3",
                      "--control-window", "2"],
    )

    static_times = [h["sim_time"] for h in static["history"]]
    static_ppls = [h["val_ppl"] for h in static["history"]]
    gov_times = [h["sim_time"] for h in governed["history"]]
    gov_ppls = [h["val_ppl"] for h in governed["history"]]

    target = static_ppls[-1]  # what static achieved with its full time budget
    t_static = float(static_times[-1])
    t_gov = _time_to_target(gov_times, gov_ppls, target)
    speedup = t_static / t_gov if np.isfinite(t_gov) else 0.0

    controller = governed["driver"].controller
    knob_history = list(controller.history) if controller is not None else []
    final_knobs = dict(controller.knobs()) if controller is not None else {}

    with open(CONTROL_JSON, "w") as f:
        json.dump({
            "static": {"sim_times": [float(t) for t in static_times],
                       "val_ppls": [float(p) for p in static_ppls]},
            "governed": {"sim_times": [float(t) for t in gov_times],
                         "val_ppls": [float(p) for p in gov_ppls],
                         "knob_updates": knob_history,
                         "final_knobs": final_knobs},
            "summary": {"target_ppl": float(target),
                        "t_static": t_static,
                        "t_governed_to_target": t_gov,
                        "speedup": speedup},
        }, f, indent=2)

    emit(
        "adaptive_control/heavy",
        governed["seconds"] * 1e6 / max(1, 3 * updates * tau),
        f"static_t={t_static:.2f} governed_t_to_target={t_gov:.2f} "
        f"speedup={speedup:.2f}x target_ppl={target:.1f} "
        f"governed_final_ppl={gov_ppls[-1]:.1f} "
        f"knob_updates={len(knob_history)} final_knobs={final_knobs}",
    )
    # acceptance: at least one closed-loop decision actually fired, and the
    # governed run reaches the static baseline's final perplexity in strictly
    # fewer simulated seconds
    assert knob_history, "governor never issued a KnobUpdate"
    assert t_gov < t_static, (
        f"governed run failed to reach the static final ppl {target:.2f} "
        f"faster: {t_gov:.2f} vs {t_static:.2f} sim-rounds"
    )
    emit("adaptive_control/speedup", 0.0, f"{speedup:.2f}x>1.0 OK")


if __name__ == "__main__":
    main()
