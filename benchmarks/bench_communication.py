"""Paper §4.3 (claim C7): communication accounting — federated vs per-step DDP.

Analytic per-config table (exact, from parameter counts) plus, when dry-run artifacts
exist in results/dryrun/, the measured HLO collective bytes for federated rounds vs
centralized steps at equal tokens."""
from __future__ import annotations

import glob
import json
import os
import time

from repro.configs import ASSIGNED_ARCHS, get_config
from benchmarks.common import emit

TAU = 500  # paper §6.5


def main(quick: bool = False) -> None:
    t0 = time.time()
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        p_bytes = cfg.param_count() * 4  # fp32 pseudo-gradients / gradients
        # DDP: one gradient all-reduce per step (ring: ~2x bytes); federated: one
        # pseudo-gradient all-reduce per round of tau steps.
        ddp_per_step = 2 * p_bytes
        fed_per_step = 2 * p_bytes / TAU
        emit(
            f"communication/{arch}",
            (time.time() - t0) * 1e6 / len(ASSIGNED_ARCHS),
            f"ddp_bytes_per_step={ddp_per_step:.3e} fed_bytes_per_step={fed_per_step:.3e} "
            f"reduction={TAU}x",
        )

    # measured, if the dry-run has produced artifacts
    for fed_json in sorted(glob.glob("results/dryrun/*__federated.json")):
        cen_json = fed_json.replace("__federated", "__centralized")
        if not os.path.exists(cen_json):
            continue
        with open(fed_json) as f:
            fed = json.load(f)
        with open(cen_json) as f:
            cen = json.load(f)
        tau_l = fed["meta"]["tau_lowered"]
        fed_ar = fed["collective_detail"].get("all-reduce", 0.0)
        cen_ar = cen["collective_detail"].get("all-reduce", 0.0)
        # remove the per-step model-parallel traffic common to both; compare the
        # data-parallel sync term: centralized pays grads every step, federated
        # pays pseudo-grads once per round.
        name = os.path.basename(fed_json).split("__federated")[0]
        emit(
            f"communication_measured/{name}",
            0.0,
            f"fed_allreduce_per_step={fed_ar/tau_l:.3e} "
            f"central_allreduce_per_step={cen_ar:.3e} tau_lowered={tau_l} "
            f"(at tau=500 the fed per-step share drops another {500//tau_l}x)",
        )


if __name__ == "__main__":
    main()
