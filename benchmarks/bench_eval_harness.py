"""Paper Tables 5/6 proxy (downstream scaling): with no public eval sets offline, the
stand-in is held-out perplexity + next-token accuracy across model scales after equal
federated training — the paper's claim is monotone improvement with size."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, run_fed, tiny_cfg


def main(quick: bool = False) -> None:
    rounds, tau = (4, 6) if quick else (6, 8)
    results = {}
    t0 = time.time()
    for d_model in (64, 128, 256):
        cfg = tiny_cfg(d_model=d_model)
        r = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=4)
        results[d_model] = r["history"][-1]
    dt = (time.time() - t0) * 1e6 / (3 * rounds * tau)
    ppls = []
    for d_model, h in results.items():
        ppls.append(h["val_ppl"])
        emit(
            f"eval_harness/d{d_model}",
            dt,
            f"val_ppl={h['val_ppl']:.1f} train_loss={h['train_loss']:.3f}",
        )
    monotone = all(ppls[i] >= ppls[i + 1] * 0.95 for i in range(len(ppls) - 1))
    emit("eval_harness/scaling", 0.0,
         f"ppl_by_size={['%.1f' % p for p in ppls]} improves_with_size={monotone}")


if __name__ == "__main__":
    main()
