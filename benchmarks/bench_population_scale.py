"""Population-scale federation: memory flat in P (ISSUE 9 tentpole).

Part 1 — the memory sweep. A sync run with the topk error-feedback codec and
``cohort_tile`` streaming runs at P ∈ {1k, 10k, 100k} with everything else
fixed (same cohort K, same rounds, same per-client work). The deliberately
large quadratic model (``(256, 256)`` params → 256 KiB per residual row) makes
the dense counterfactual unmistakable: ``init_uplink_residuals`` at P = 100k
would allocate P · 256 KiB ≈ 25.6 GiB before the first round. The sweep
asserts the measured footprint is flat instead:

- exact accounting — the sparse store holds ≤ rounds·K rows at EVERY P (the
  ever-selected set), so its bytes are bounded by the sampling schedule, not
  the population; the jitted round state is byte-identical across P;
- sampled peak RSS — the spread across the whole sweep stays below the dense
  store of even the SMALLEST population (growing P 100× costs less memory
  than a single P=1k dense store would).

Part 2 — the bitwise check at P = 100k. The same schedule runs twice on the
tiny (4, 4) quadratic model: once through :class:`SyncAggregator` (sparse
store, host gather/scatter) and once through the pure dense reference
``federated_round_with_uplink`` over an ``init_uplink_residuals`` store
(6.4 MB at this scale — allocatable on purpose). Asserted bitwise equal:
the server params after every round, and every ever-selected client's
residual row. Results land in ``BENCH_population_scale.json`` for the CI
bench lane's artifact upload.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PeakRss, emit, live_device_bytes, tree_nbytes
from repro.core import (
    FederatedConfig,
    InnerOptConfig,
    OuterOptConfig,
    ParticipationConfig,
    SyncAggregator,
    federated_round_with_uplink,
    get_codec,
    init_federated_state,
    init_uplink_residuals,
)

POPULATION_JSON = "BENCH_population_scale.json"


def _quad_loss(params, batch):
    pred = batch["x"] @ params["w"]
    loss = jnp.mean(jnp.square(pred - batch["y"]))
    return loss, {"loss": loss, "grad_norm": jnp.zeros(())}


def _make_fed(tau: int, clients: int) -> FederatedConfig:
    return FederatedConfig(
        clients_per_round=clients,
        local_steps=tau,
        inner=InnerOptConfig(name="sgd", lr_max=0.05, weight_decay=0.0,
                             grad_clip=1e9, warmup_steps=0, total_steps=10_000,
                             alpha=1.0),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )


def _round_batches(rnd: int, tau: int, clients: int, dim: int, n: int = 4):
    """Deterministic per-round batches, identical across every arm and P."""
    rng = np.random.default_rng(1000 + rnd)
    return {
        "x": jnp.asarray(rng.standard_normal((tau, clients, n, dim)), jnp.float32),
        "y": jnp.asarray(rng.standard_normal((tau, clients, n, dim)), jnp.float32),
    }


def _run_sweep_point(population: int, *, dim: int, rounds: int, tau: int,
                     clients: int, cohort_tile: int) -> dict:
    params = {"w": jnp.zeros((dim, dim), jnp.float32)}
    fed = _make_fed(tau, clients)
    pcfg = ParticipationConfig(population=population, clients_per_round=clients)
    codec = get_codec("topk", 0.25)
    with PeakRss() as mem:
        agg = SyncAggregator(
            _quad_loss, fed, pcfg, codec=codec, seed=0, params=params,
            rng=jax.random.PRNGKey(1), cohort_tile=cohort_tile,
        )
        selected = set()
        for rnd in range(rounds):
            plan = agg.plan(rnd)
            selected.update(int(i) for i in plan.selected)
            agg.run_round(_round_batches(rnd, tau, clients, dim), plan)
        jax.block_until_ready(agg.state["params"])
    store = agg.residual_store
    assert store is not None and len(store) == len(selected), (
        f"store materialized {len(store)} rows, ever-selected {len(selected)}"
    )
    return {
        "population": population,
        "ever_selected": len(selected),
        "store_rows": len(store),
        "row_bytes": int(store.row_nbytes),
        "store_bytes": int(store.nbytes),
        "dense_store_bytes": population * int(store.row_nbytes),
        "state_bytes": int(tree_nbytes(agg.state)),
        "live_device_bytes": int(live_device_bytes()),
        "peak_rss_bytes": int(mem.peak),
    }


def _run_bitwise_check(population: int, *, rounds: int, tau: int,
                       clients: int, dim: int = 4) -> dict:
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (dim, dim))}
    fed = _make_fed(tau, clients)
    pcfg = ParticipationConfig(population=population, clients_per_round=clients)
    codec = get_codec("topk", 0.25)

    # Arm A: the production aggregator — sparse store, host gather/scatter
    agg = SyncAggregator(
        _quad_loss, fed, pcfg, codec=codec, seed=0, params=params,
        rng=jax.random.PRNGKey(1), donate=False,
    )
    # Arm B: the dense reference — the pure population-keyed round over the
    # full (P, ...) store (6.4 MB at (4,4)/100k: allocatable on purpose)
    dense_state = init_federated_state(fed, params, jax.random.PRNGKey(1))
    dense_state["uplink_residuals"] = init_uplink_residuals(
        codec, params, population
    )
    dense_fn = jax.jit(
        lambda s, b, w, sel: federated_round_with_uplink(
            _quad_loss, fed, codec, s, b, client_weights=w, selected=sel
        )
    )

    params_bitwise = True
    selected = set()
    for rnd in range(rounds):
        plan = agg.plan(rnd)
        selected.update(int(i) for i in plan.selected)
        w = jnp.asarray(agg.round_weights(plan))
        batches = _round_batches(rnd, tau, clients, dim)
        agg.run_round(batches, plan)
        dense_state, _ = dense_fn(
            dense_state, batches, w, jnp.asarray(plan.selected)
        )
        params_bitwise &= bool(
            np.array_equal(np.asarray(agg.state["params"]["w"]),
                           np.asarray(dense_state["params"]["w"]))
        )

    dense_rows = np.asarray(dense_state["uplink_residuals"]["w"])
    rows_bitwise = all(
        np.array_equal(np.asarray(agg.residual_store.row(cid)["w"]),
                       dense_rows[cid])
        for cid in sorted(selected)
    )
    assert params_bitwise, "sparse-store params diverged from the dense round"
    assert rows_bitwise, "sparse residual rows diverged from the dense store"
    assert len(agg.residual_store) == len(selected)
    return {
        "population": population,
        "rounds": rounds,
        "ever_selected": len(selected),
        "params_bitwise": params_bitwise,
        "residual_rows_bitwise": rows_bitwise,
    }


def main(quick: bool = False) -> None:
    pops = [1_000, 10_000] if quick else [1_000, 10_000, 100_000]
    rounds, tau, clients, cohort_tile = (2, 2, 4, 2) if quick else (3, 4, 8, 4)
    dim = 128 if quick else 256

    sweep = [
        _run_sweep_point(p, dim=dim, rounds=rounds, tau=tau,
                         clients=clients, cohort_tile=cohort_tile)
        for p in pops
    ]

    # exact accounting: flat in P — the store is bounded by the sampling
    # schedule (rounds·K rows) at every population, and the jitted round
    # state is byte-identical across the sweep
    row = sweep[0]["row_bytes"]
    max_rows = rounds * clients
    for pt in sweep:
        assert pt["row_bytes"] == row
        assert pt["store_rows"] <= max_rows, (
            f"P={pt['population']}: {pt['store_rows']} rows > schedule bound "
            f"{max_rows}"
        )
        assert pt["state_bytes"] == sweep[0]["state_bytes"]
    # sampled memory: the WHOLE sweep's RSS spread stays below the dense
    # store of even the smallest population
    rss = [pt["peak_rss_bytes"] for pt in sweep]
    spread = max(rss) - min(rss)
    dense_smallest = min(pt["dense_store_bytes"] for pt in sweep)
    assert spread < dense_smallest, (
        f"peak RSS spread {spread/2**20:.0f} MiB across P={pops} is not flat "
        f"(dense store at P={min(pops)} would be {dense_smallest/2**20:.0f} MiB)"
    )

    bitwise = _run_bitwise_check(
        pops[-1], rounds=rounds, tau=tau, clients=clients
    )

    with open(POPULATION_JSON, "w") as f:
        json.dump({"sweep": sweep, "bitwise": bitwise,
                   "rss_spread_bytes": int(spread)}, f, indent=2)

    for pt in sweep:
        emit(
            f"population_scale/P={pt['population']}",
            0.0,
            f"store={pt['store_bytes']/2**10:.0f}KiB "
            f"(dense would be {pt['dense_store_bytes']/2**20:.0f}MiB) "
            f"rows={pt['store_rows']} peak_rss={pt['peak_rss_bytes']/2**20:.0f}MiB",
        )
    emit(
        "population_scale/flat_memory", 0.0,
        f"rss_spread={spread/2**20:.0f}MiB<{dense_smallest/2**20:.0f}MiB OK",
    )
    emit(
        "population_scale/bitwise", 0.0,
        f"P={bitwise['population']} params_bitwise={bitwise['params_bitwise']} "
        f"residual_rows_bitwise={bitwise['residual_rows_bitwise']} OK",
    )


if __name__ == "__main__":
    main()
