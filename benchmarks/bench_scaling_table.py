"""Paper Table 1 + Table 2: token budgets and step counts per model scale.

Analytic reproduction of the paper's budgeting: Chinchilla-optimal tokens (20/param on
the vocabulary-adjusted size), the MPT recipe counts, and the federated sequential /
parallel split (parallel = sequential x clients)."""
from __future__ import annotations

from repro.configs import get_config
from benchmarks.common import emit

# (name, seq_len, batch, clients)  — Tables 1/4
ROWS = [
    ("photon-75m", 1024, 256, 8),
    ("photon-125m", 2048, 256, 8),
    ("photon-350m", 2048, 256, 8),
    ("photon-1.3b", 2048, 512, 8),
    ("photon-3b", 2048, 512, 64),
    ("photon-7b", 2048, 1024, 64),
]

# vocabulary-adjusted sizes from the paper's Table 1 (Hoffmann-equivalent params)
VOCAB_ADJ = {
    "photon-75m": 58.54e6,
    "photon-125m": 110.89e6,
    "photon-350m": 331.19e6,
    "photon-1.3b": 1.26e9,
    "photon-3b": 2.96e9,
    "photon-7b": 6.92e9,
}


def main(quick: bool = False) -> None:
    import time

    t0 = time.time()
    for name, seq, batch, clients in ROWS:
        cfg = get_config(name)
        n = cfg.param_count()
        n_adj = VOCAB_ADJ[name]
        chinchilla = 20.0 * n_adj
        steps = chinchilla / (seq * batch)
        par_tokens = chinchilla * clients / 8  # parallel budget at the paper's scale
        emit(
            f"scaling_table/{name}",
            (time.time() - t0) * 1e6 / len(ROWS),
            f"N={n/1e6:.0f}M Nadj={n_adj/1e6:.0f}M chinchilla_tokens={chinchilla:.2e} "
            f"steps@B{batch}xS{seq}={steps:.0f} parallel_tokens={par_tokens:.2e}",
        )


if __name__ == "__main__":
    main()
