"""Compressed uplink: bytes-vs-perplexity across the ``core/compression`` codecs
(the PR's acceptance table; Photon arXiv 2411.02908 §comm-efficiency).

Every row runs the identical federation — heavy straggler profile, FedAvg
data-size weighting, same seed, so the participation plans are identical — and
changes ONLY the uplink codec. The comparison is total uplink bytes over the run
vs the final validation perplexity: compression is only worth shipping if the
bytes drop without the model paying for it. With top-k at 5% the uplink must
shrink ≥ 10x while final perplexity stays within 5% of the uncompressed run
(asserted — the acceptance criterion), which is what error feedback buys: the
dropped 95% of each client's delta mass is re-injected on its next upload
instead of being lost.

The outer optimizer is FedAdam: under plain FedAvg a 5%-sparse delta only moves
5% of the coordinates per round and the compressed run trails the uncompressed
one for tens of rounds, while FedAdam's server-side moment accumulators spread
each sparse update over every coordinate (and normalize per-coordinate scale),
at which point error-feedback top-k matches — in this configuration beats — the
dense uplink. Compression composes with the outer optimizer choice; the bench
pins the pairing that makes the paper's comm-efficiency economics actually work.

Also cross-checks the *analytic* ``uplink_bytes`` accounting (what the training
loop logs) against the *measured* size of a real encoded payload — the logged
comm tables are only trustworthy if the two agree.

Writes ``BENCH_compressed_uplink.json`` for the CI bench lane's artifact upload.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, run_fed, tiny_cfg
from repro.core import get_codec, uplink_bytes

SCHEMES = ("float32", "bf16", "int8", "topk")
TOPK_FRACTION = 0.05
OUT_JSON = "BENCH_compressed_uplink.json"


def _measured_payload_bytes(scheme: str, params) -> float:
    """Encode one params-shaped pseudo-gradient and weigh the actual payload."""
    codec = get_codec(scheme, TOPK_FRACTION)
    rng = np.random.default_rng(0)
    delta = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32), params
    )
    payload, _ = codec.encode(delta)
    return codec.payload_nbytes(payload)


def main(quick: bool = False) -> None:
    rounds, tau, pop, k = (12, 6, 8, 4) if quick else (30, 8, 8, 4)
    cfg = tiny_cfg(d_model=128)
    base = [
        "--straggler-profile", "heavy", "--client-weighting", "examples",
        "--topk-fraction", str(TOPK_FRACTION),
    ]

    rows = {}
    for scheme in SCHEMES:
        out = run_fed(
            cfg=cfg, rounds=rounds, tau=tau, clients=k, population=pop,
            outer="fedadam", outer_lr=0.01,
            extra=base + ["--uplink", scheme],
        )
        hist = out["history"]
        params = out["state"]["params"]
        bytes_total = float(sum(h["uplink_bytes_round"] for h in hist))
        per_upload = uplink_bytes(params, scheme, TOPK_FRACTION)
        measured = _measured_payload_bytes(scheme, params)
        rows[scheme] = {
            "uplink_bytes_total": bytes_total,
            "bytes_per_upload_analytic": per_upload,
            "bytes_per_upload_measured": measured,
            "final_val_ppl": float(hist[-1]["val_ppl"]),
            "final_train_loss": float(hist[-1]["train_loss"]),
            "rounds": rounds,
        }
        emit(
            f"compressed_uplink/{scheme}",
            out["seconds"] * 1e6 / max(1, rounds * tau),
            f"bytes_total={bytes_total:.3e} per_upload={per_upload:.3e} "
            f"measured={measured:.3e} final_ppl={rows[scheme]['final_val_ppl']:.1f}",
        )

    f32, topk = rows["float32"], rows["topk"]
    ratio = f32["uplink_bytes_total"] / max(topk["uplink_bytes_total"], 1e-12)
    ppl_rel = topk["final_val_ppl"] / f32["final_val_ppl"]
    rows["summary"] = {
        "topk_fraction": TOPK_FRACTION,
        "topk_bytes_reduction": ratio,
        "topk_final_ppl_vs_float32": ppl_rel,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(rows, f, indent=2)

    # acceptance: ≥10x fewer uplink bytes at 5% top-k, perplexity within 5%
    assert ratio >= 10.0, f"topk bytes reduction only {ratio:.2f}x (< 10x)"
    assert ppl_rel <= 1.05, (
        f"topk final ppl {topk['final_val_ppl']:.1f} is {ppl_rel:.3f}x the "
        f"uncompressed {f32['final_val_ppl']:.1f} (> 1.05x): error feedback "
        f"failed to absorb the sparsification"
    )
    emit(
        "compressed_uplink/acceptance", 0.0,
        f"bytes_reduction={ratio:.2f}x>=10 ppl_ratio={ppl_rel:.3f}<=1.05 OK",
    )


if __name__ == "__main__":
    main()
