"""Async buffered aggregation vs the deadline-masking sync round (Photon's
FedBuff-style aggregator, arXiv 2411.02908): simulated wall-clock-to-loss under
hardware heterogeneity.

Both schedules run the identical jitted client phase on the identical straggler
population; only the aggregation policy differs. The sync round waits until the
deadline and throws away every straggler's τ local steps; the async server keeps
all K slots busy, buffers each completed delta with a staleness discount
``w/(1+s)^α``, and updates once per M admitted deltas. The comparison metric is
*simulated* wall-clock (median-client-round units) to reach the sync run's final
validation perplexity: under the ``heavy`` profile the async schedule must reach
it strictly faster (the PR's acceptance criterion, asserted below) — slow
clients' work lands in later buffers instead of evaporating at the deadline.

The ``mild`` row is the control, not a claim: with a loose deadline the sync
round discards almost nothing, so buffered aggregation pays its smaller-and-
staler-updates cost without a straggler problem to offset it and may not reach
the sync target at all (reported as speedup=0.00x). Async aggregation is a
heterogeneity play, not a free lunch.

The PARTIAL-PROGRESS arm (``--partial-progress``, the Aggregator seam's sync
weight policy) runs a heavy-straggler federation a third way: stragglers
contribute the τ_i = min(τ, ⌊τ·speed·deadline⌋) steps they realized, weighted
τ_i/τ, instead of being cut. The scenario is where the cut actually BITES:
statistical heterogeneity (disjoint Pile-category clients) with persistent
speeds and a tight deadline, so the deadline-cut baseline trains forever on the
one fast institution's domain and oscillates on the full-distribution
validation set, while partial progress keeps every domain fractionally
represented. FedAdam is the outer optimizer for the same reason the uplink
bench pairs it with top-k: partial deltas are *smaller* (fewer steps), and an
adaptive server renormalizes the step so the averaged-over-more-clients
direction wins — under plain FedAvg@1.0 the shrunken aggregate step cancels the
diversity gain. The acceptance criterion (asserted): partial progress reaches
the deadline-cut baseline's final perplexity in FEWER simulated
median-client-rounds. Trajectories land in ``BENCH_partial_progress.json`` for
the CI bench lane's artifact upload.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import emit, run_fed, tiny_cfg

PARTIAL_JSON = "BENCH_partial_progress.json"


def _sync_cum_times(hist):
    return np.cumsum([h["round_time_sim"] for h in hist])


def _time_to_target(times, ppls, target: float) -> float:
    for t, p in zip(times, ppls):
        if p <= target:
            return float(t)
    return float("inf")


def main(quick: bool = False) -> None:
    rounds, tau, pop, k = (4, 6, 8, 4) if quick else (8, 8, 8, 4)
    buffer_size = max(1, k // 2)
    cfg = tiny_cfg(d_model=128)

    speedups = {}
    for profile in ("mild", "heavy"):
        base = ["--straggler-profile", profile, "--client-weighting", "examples"]
        sync = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=k, population=pop,
                       extra=base)
        # async applies the same number of client deltas overall: one sync round
        # aggregates ≤ K deltas, one async update aggregates M — so give async
        # rounds·K/M updates to hold total admitted work comparable
        n_updates = rounds * k // buffer_size
        async_ = run_fed(
            cfg=cfg, rounds=n_updates, tau=tau, clients=k, population=pop,
            extra=base + ["--aggregation", "async",
                          "--buffer-size", str(buffer_size),
                          "--staleness-alpha", "0.5"],
        )

        sync_times = _sync_cum_times(sync["history"])
        sync_ppls = [h["val_ppl"] for h in sync["history"]]
        async_times = [h["sim_time"] for h in async_["history"]]
        async_ppls = [h["val_ppl"] for h in async_["history"]]

        target = sync_ppls[-1]  # what sync achieved with its full time budget
        t_sync = float(sync_times[-1])
        t_async = _time_to_target(async_times, async_ppls, target)
        speedup = t_sync / t_async if np.isfinite(t_async) else 0.0
        speedups[profile] = speedup

        stale = [h["staleness_mean"] for h in async_["history"]]
        emit(
            f"async_vs_sync/{profile}",
            async_["seconds"] * 1e6 / max(1, n_updates * tau),
            f"sync_t={t_sync:.2f} async_t_to_target={t_async:.2f} "
            f"speedup={speedup:.2f}x target_ppl={target:.1f} "
            f"async_final_ppl={async_ppls[-1]:.1f} "
            f"mean_staleness={np.mean(stale):.2f} "
            f"async_waste={async_['driver'].work_wasted:.1f}",
        )

    # acceptance: buffered aggregation beats deadline masking where stragglers bite
    assert speedups["heavy"] > 1.0, (
        f"async failed to beat sync under the heavy straggler profile: {speedups}"
    )
    emit("async_vs_sync/heavy_speedup", 0.0, f"{speedups['heavy']:.2f}x>1.0 OK")

    # ---- partial-progress arm (sync, heavy profile, heterogeneous) -------
    base = ["--straggler-profile", "heavy", "--client-weighting", "examples",
            "--deadline", "0.7", "--eval-batches", "4"]
    cut = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=k, population=pop,
                  heterogeneous=True, outer="fedadam", outer_lr=0.01, extra=base)
    part = run_fed(cfg=cfg, rounds=rounds, tau=tau, clients=k, population=pop,
                   heterogeneous=True, outer="fedadam", outer_lr=0.01,
                   extra=base + ["--partial-progress"])

    cut_times = _sync_cum_times(cut["history"])
    cut_ppls = [h["val_ppl"] for h in cut["history"]]
    part_times = _sync_cum_times(part["history"])
    part_ppls = [h["val_ppl"] for h in part["history"]]
    target = cut_ppls[-1]  # the deadline-cut baseline's final perplexity
    t_cut = float(cut_times[-1])
    t_part = _time_to_target(part_times, part_ppls, target)
    rescued = float(np.mean(
        [h["partial_rescued_clients"] for h in part["history"]]
    ))
    tau_mean = float(np.mean([h["partial_tau_mean"] for h in part["history"]]))

    with open(PARTIAL_JSON, "w") as f:
        json.dump({
            "deadline_cut": {"sim_times": [float(t) for t in cut_times],
                             "val_ppls": [float(p) for p in cut_ppls]},
            "partial_progress": {"sim_times": [float(t) for t in part_times],
                                 "val_ppls": [float(p) for p in part_ppls],
                                 "mean_rescued_clients": rescued,
                                 "mean_tau_fraction": tau_mean},
            "summary": {"target_ppl": float(target),
                        "t_deadline_cut": t_cut,
                        "t_partial_to_target": t_part,
                        "speedup": t_cut / t_part if np.isfinite(t_part) else 0.0},
        }, f, indent=2)

    emit(
        "async_vs_sync/partial_progress",
        part["seconds"] * 1e6 / max(1, rounds * tau),
        f"cut_t={t_cut:.2f} partial_t_to_target={t_part:.2f} "
        f"target_ppl={target:.1f} partial_final_ppl={part_ppls[-1]:.1f} "
        f"mean_tau={tau_mean:.2f} rescued/round={rescued:.1f}",
    )
    # acceptance: partial progress reaches the deadline-cut baseline's final
    # perplexity in strictly fewer simulated median-client-rounds
    assert t_part < t_cut, (
        f"partial progress failed to reach the deadline-cut final ppl "
        f"{target:.2f} faster: {t_part:.2f} vs {t_cut:.2f} sim-rounds"
    )
    emit("async_vs_sync/partial_speedup", 0.0,
         f"{t_cut / t_part:.2f}x<=t_cut OK" if np.isfinite(t_part) else "FAIL")


if __name__ == "__main__":
    main()
