"""Byzantine resilience of the aggregation rules (docs/robustness.md).

Three arms run the identical async buffered federation (same population, same
seed, same straggler profile); 20% of the population is Byzantine and rescales
every delta it pushes by ×64 (``--byzantine-kind scale`` — the strongest kind
that keeps the undefended arm *finite*, so "worse" is a measurable number
rather than a NaN):

* CLEAN     — no attackers, no defense: the reference trajectory.
* PLAIN     — attackers on, plain weighted mean: every poisoned flush drags
              the outer step off the honest direction.
* ROBUST    — attackers on, ``--robust-agg trimmed --screen``: the door's
              adaptive norm screen rejects poisoned pushes once warm, and the
              coordinate-wise trimmed mean discards whatever lands in the
              buffer before the screen has history.

Acceptance (asserted): the ROBUST arm's final validation perplexity lands
within 5% of CLEAN, while the PLAIN arm is measurably worse than that same
5% band (or non-finite). Trajectories and the defense counters land in
``BENCH_robust_agg.json`` for the CI bench lane's artifact upload.
"""
from __future__ import annotations

import json
import math

from benchmarks.common import emit, run_fed, tiny_cfg

ROBUST_JSON = "BENCH_robust_agg.json"
TOLERANCE = 1.05  # robust must land within 5% of the clean final perplexity


def _ppls(out):
    return [float(h["val_ppl"]) for h in out["history"]]


def main(quick: bool = False) -> None:
    updates, tau, pop, k = (4, 4, 10, 4) if quick else (8, 6, 10, 4)
    cfg = tiny_cfg(d_model=128)

    base = ["--aggregation", "async", "--buffer-size", "3",
            "--staleness-alpha", "0.5", "--client-weighting", "examples"]
    attacked = base + ["--byzantine-fraction", "0.2",
                       "--byzantine-kind", "scale"]
    defended = attacked + ["--robust-agg", "trimmed",
                           "--trim-fraction", "0.34",
                           "--screen", "--screen-warmup", "3"]

    common = dict(cfg=cfg, rounds=updates, tau=tau, clients=k, population=pop)
    clean = run_fed(extra=base, **common)
    plain = run_fed(extra=attacked, **common)
    robust = run_fed(extra=defended, **common)

    clean_ppl, plain_ppl, robust_ppl = (
        _ppls(clean)[-1], _ppls(plain)[-1], _ppls(robust)[-1]
    )
    band = clean_ppl * TOLERANCE
    rs = robust["driver"].robust_state
    counters = dict(rs.counters) if rs is not None else {}
    quarantined = sorted(rs.quarantine) if rs is not None else []

    with open(ROBUST_JSON, "w") as f:
        json.dump({
            "attack": {"fraction": 0.2, "kind": "scale", "population": pop},
            "clean": {"val_ppls": _ppls(clean)},
            "plain_mean": {"val_ppls": _ppls(plain)},
            "robust": {"val_ppls": _ppls(robust),
                       "rule": "trimmed", "screen": True,
                       "counters": counters,
                       "quarantined_clients": quarantined},
            "summary": {"clean_final_ppl": clean_ppl,
                        "plain_final_ppl": plain_ppl,
                        "robust_final_ppl": robust_ppl,
                        "tolerance_band": band},
        }, f, indent=2)

    emit(
        "robust_agg/scale_attack",
        robust["seconds"] * 1e6 / max(1, updates * tau),
        f"clean={clean_ppl:.1f} plain={plain_ppl:.1f} robust={robust_ppl:.1f} "
        f"band={band:.1f} screen_rejects={counters.get('screen_rejects', 0)}",
    )
    # acceptance: the defense recovers the clean trajectory, the plain mean
    # does not — an attacked-but-defended run is indistinguishable (5%) from
    # an unattacked one, while the undefended run measurably degrades
    assert math.isfinite(robust_ppl) and robust_ppl <= band, (
        f"robust arm missed the clean band: {robust_ppl:.2f} vs "
        f"{clean_ppl:.2f} × {TOLERANCE}"
    )
    assert not (math.isfinite(plain_ppl) and plain_ppl <= band), (
        f"plain mean was not degraded by the attack ({plain_ppl:.2f} within "
        f"{band:.2f}) — the arms are not separating"
    )
    emit("robust_agg/recovery", 0.0,
         f"robust={robust_ppl:.1f}<=band={band:.1f} OK "
         f"plain={plain_ppl:.1f} degraded OK")


if __name__ == "__main__":
    main()
