"""Kernel microbenchmarks: wall time of the jnp reference paths on CPU (the Pallas
kernels target TPU; interpret-mode timing is not meaningful, so the reference path is
what gets timed) + analytic FLOP/byte intensity per kernel."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ref import decode_attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from benchmarks.common import emit


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def main(quick: bool = False) -> None:
    B, H, S, hd = 1, 4, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)

    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us = _time(f, q, k, v)
    flops = 4 * B * H * S * S * hd
    emit("kernels/flash_attention_ref", us, f"flops={flops:.2e} achieved={flops/us*1e6/1e9:.1f}GFLOP/s")

    qd = q[:, :, :1].reshape(B, H, hd)
    fd = jax.jit(lambda q, k, v: decode_attention_ref(q, k, v, jnp.int32(S)))
    us = _time(fd, qd, k, v)
    byts = 2 * B * H * S * hd * 4
    emit("kernels/flash_decode_ref", us, f"kv_bytes={byts:.2e} bw={byts/us*1e6/1e9:.1f}GB/s")

    nh, ds, chunk = 4, 32, 64
    x = jax.random.normal(ks[3], (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[1], (nh,)) * 0.5)
    Bm = jax.random.normal(ks[2], (B, S, 1, ds), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, S, 1, ds), jnp.float32)
    fs = jax.jit(lambda *a: ssd_ref(*a, chunk)[0])
    us = _time(fs, x, dt, A, Bm, Cm)
    ssd_flops = 2 * B * S * nh * hd * (chunk + 2 * ds)
    emit("kernels/ssd_scan_ref", us, f"flops~{ssd_flops:.2e} chunk={chunk}")

    xr = jax.random.normal(ks[0], (4096, 1024), jnp.float32)
    sc = jnp.ones((1024,))
    fr = jax.jit(rmsnorm_ref)
    us = _time(fr, xr, sc)
    rb = 2 * xr.size * 4
    emit("kernels/rmsnorm_ref", us, f"bytes={rb:.2e} bw={rb/us*1e6/1e9:.1f}GB/s")


if __name__ == "__main__":
    main()
