"""Kernel microbenchmarks: wall time of the jnp reference paths on CPU (the Pallas
kernels target TPU; interpret-mode timing is not meaningful, so the reference path —
and for fedcore, the identical-math flat-buffer XLA chain — is what gets timed) +
analytic FLOP/byte intensity per kernel.

The ``fedcore`` arm additionally writes ``BENCH_fedkernels.json``: server-apply and
codec-encode wall times at 0.25–8M-param scale for C∈{4,16}, plus the analytic
bytes-moved roofline comparison (the fused single-pass layout must move ≥2x fewer
HBM bytes than the per-leaf multi-pass reference chain — the asserted acceptance;
CPU wall time is recorded honestly but only guarded against pathological
regression, since at these sizes the flat pack's concatenate puts the two paths
at parity-within-noise on a compute-cache-bound CPU)."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ref import decode_attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from benchmarks.common import emit

FEDKERNELS_JSON = "BENCH_fedkernels.json"


def _time(fn, *args, iters=3, warmup=1):
    """Mean wall µs per call. The warmup iterations run (and block) BEFORE the
    clock starts, so first-call jit compilation and lazy allocation can never
    pollute the reported time; the timed loop blocks once on the final value
    (async dispatch amortizes across iterations, as in production)."""
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _xla_bytes_accessed(jitted, *args):
    """XLA's measured 'bytes accessed' for the compiled computation on this
    host — implementation-sensitive (it reflects what the lowering actually
    materializes), unlike the analytic roofline model. None if unavailable."""
    try:
        cost = jitted.lower(*args).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax 0.4.x returns a list
            cost = cost[0] if cost else {}
        b = cost.get("bytes accessed")
        return float(b) if b is not None else None
    except Exception:
        return None


def _fed_tree(n: int, n_leaves: int, key) -> dict:
    """A synthetic params-shaped pytree of ~n total elements across n_leaves
    tensors (uneven sizes, so the per-leaf ref chain pays its real traversal
    cost)."""
    sizes = [max(1, n // n_leaves + (i % 3 - 1) * (n // (8 * n_leaves))) for i in range(n_leaves)]
    sizes[-1] = max(1, n - sum(sizes[:-1]))
    keys = jax.random.split(key, n_leaves)
    return {f"p{i}": jax.random.normal(k, (s,), jnp.float32) for i, (k, s) in enumerate(zip(keys, sizes))}


def _bench_fedcore(quick: bool) -> None:
    """Server-apply + codec-encode: the per-leaf jnp reference chain vs the
    flat-buffer fused layout (on CPU the fused math runs as one XLA-fused flat
    chain — the Pallas kernel computes the same formulas per block on TPU).

    Scales are capped for CI wall time: 0.25M (quick) / 1M and 8M (full)
    params; the layout is size-independent, so the bytes-moved ratios asserted
    here hold identically at the 100M+ TPU scale the kernel targets.
    """
    import functools

    from repro.core import (
        FederatedConfig,
        OuterOptConfig,
        TopKCodec,
        apply_aggregate,
        init_federated_state,
        uplink_bytes,
    )
    from repro.kernels.fedcore import (
        FusedTopKCodec,
        fused_apply_aggregate,
        server_apply_bytes,
        topk_encode_bytes,
    )

    cases = (
        [(1 << 18, 4)] if quick else [(1 << 20, 4), (1 << 20, 16), (1 << 23, 4)]
    )
    n_leaves = 24
    rows: dict = {"server_apply": [], "codec_encode": []}
    for n, c in cases:
        params = _fed_tree(n, n_leaves, jax.random.PRNGKey(0))
        n_real = sum(x.size for x in jax.tree_util.tree_leaves(params))
        fed = FederatedConfig(
            clients_per_round=c, local_steps=1,
            outer=OuterOptConfig(name="fedadam", lr=0.1),
        )
        state = init_federated_state(fed, params, jax.random.PRNGKey(1))
        deltas = jax.tree_util.tree_map(
            lambda p: jax.random.normal(jax.random.PRNGKey(2), (c,) + p.shape), params
        )
        w = jnp.linspace(0.5, 2.0, c)
        ref_fn = jax.jit(lambda s, d, ww: apply_aggregate(fed, s, d, client_weights=ww))
        fus_fn = jax.jit(
            lambda s, d, ww: fused_apply_aggregate(
                fed, s, d, client_weights=ww, use_pallas=False
            )
        )
        # min over repeats: robust to CI-runner load spikes, which would
        # otherwise make the no-slower assertion below flaky
        ref_us = min(_time(ref_fn, state, deltas, w, iters=5, warmup=2) for _ in range(3))
        fus_us = min(_time(fus_fn, state, deltas, w, iters=5, warmup=2) for _ in range(3))
        ref_b = server_apply_bytes(n_real, c, "fedadam")
        fus_b = server_apply_bytes(n_real, c, "fedadam", fused=True)
        rows["server_apply"].append({
            "n_params": n_real, "clients": c, "outer": "fedadam",
            "ref_us": ref_us, "fused_us": fus_us,
            # analytic roofline of the KERNEL SWEEP vs the per-leaf chain —
            # the single-pass property of the (C, N) layout
            "ref_bytes_moved": ref_b, "fused_bytes_moved": fus_b,
            "bytes_ratio": ref_b / fus_b,
            # XLA-measured bytes of this host's CPU lowering. The fused number
            # INCLUDES the per-call flat pack/unpack layout conversion (~CN of
            # extra traffic the resident-flat TPU layout amortizes), so it is
            # expected to exceed the ref here — recorded so the trade-off is
            # visible, never asserted as a win
            "ref_xla_cpu_bytes_accessed": _xla_bytes_accessed(ref_fn, state, deltas, w),
            "fused_xla_cpu_bytes_accessed": _xla_bytes_accessed(fus_fn, state, deltas, w),
        })
        emit(
            f"fedcore/server_apply_n{n_real}_c{c}", fus_us,
            f"ref={ref_us:.0f}us speedup={ref_us / max(fus_us, 1e-9):.2f}x "
            f"bytes {ref_b:.3e}->{fus_b:.3e} ({ref_b / fus_b:.2f}x fewer)",
        )

        delta1 = jax.tree_util.tree_map(lambda d: d[0], deltas)
        ref_c = TopKCodec(k_fraction=0.05)
        fus_c = FusedTopKCodec(k_fraction=0.05)
        res = ref_c.init_residual(delta1)
        ref_enc = jax.jit(lambda d, e: ref_c.encode(d, e))
        fus_enc = jax.jit(lambda d, e: fus_c.encode(d, e))
        ref_eus = _time(ref_enc, delta1, res, iters=5, warmup=2)
        fus_eus = _time(fus_enc, delta1, res, iters=5, warmup=2)
        rows["codec_encode"].append({
            "n_params": n_real, "codec": "topk@5%",
            "ref_us": ref_eus, "fused_us": fus_eus,
            "ref_bytes_moved": topk_encode_bytes(n_real),
            "fused_bytes_moved": topk_encode_bytes(n_real, fused=True),
            "wire_bytes_ref": uplink_bytes(params, "topk", 0.05),
            "wire_bytes_fused": fus_c.nbytes(params),
        })
        emit(
            f"fedcore/topk_encode_n{n_real}", fus_eus,
            f"ref={ref_eus:.0f}us speedup={ref_eus / max(fus_eus, 1e-9):.2f}x "
            f"wire={fus_c.nbytes(params):.3e}B",
        )

    # acceptance: the fused layout must move >=2x fewer bytes per round than
    # the ref multi-pass chain, and must not be slower where both are timeable
    speedup_min = min(
        r["ref_us"] / max(r["fused_us"], 1e-9) for r in rows["server_apply"]
    )
    rows["summary"] = {
        "server_apply_bytes_ratio_min": min(
            r["bytes_ratio"] for r in rows["server_apply"]
        ),
        "server_apply_speedup_min": speedup_min,
    }
    with open(FEDKERNELS_JSON, "w") as f:
        json.dump(rows, f, indent=2)
    # CPU wall time at quick sizes is parity-within-noise (the flat pack's
    # concatenate offsets the fusion win that HBM-bound TPU execution banks),
    # so the timing assertion is only a pathology guard; the stable, layout-
    # intrinsic acceptance is the bytes-moved roofline.
    for r in rows["server_apply"]:
        assert r["bytes_ratio"] >= 2.0, r
        assert r["fused_us"] <= r["ref_us"] * 2.0, (
            f"fused server apply pathologically slower than ref: {r}"
        )
    emit(
        "fedcore/acceptance", 0.0,
        f"bytes_ratio_min={rows['summary']['server_apply_bytes_ratio_min']:.2f}>=2 "
        f"server_apply_speedup_min={speedup_min:.2f}x",
    )


def main(quick: bool = False) -> None:
    _bench_fedcore(quick)
    B, H, S, hd = 1, 4, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)

    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us = _time(f, q, k, v)
    flops = 4 * B * H * S * S * hd
    emit("kernels/flash_attention_ref", us, f"flops={flops:.2e} achieved={flops/us*1e6/1e9:.1f}GFLOP/s")

    qd = q[:, :, :1].reshape(B, H, hd)
    fd = jax.jit(lambda q, k, v: decode_attention_ref(q, k, v, jnp.int32(S)))
    us = _time(fd, qd, k, v)
    byts = 2 * B * H * S * hd * 4
    emit("kernels/flash_decode_ref", us, f"kv_bytes={byts:.2e} bw={byts/us*1e6/1e9:.1f}GB/s")

    nh, ds, chunk = 4, 32, 64
    x = jax.random.normal(ks[3], (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[1], (nh,)) * 0.5)
    Bm = jax.random.normal(ks[2], (B, S, 1, ds), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, S, 1, ds), jnp.float32)
    fs = jax.jit(lambda *a: ssd_ref(*a, chunk)[0])
    us = _time(fs, x, dt, A, Bm, Cm)
    ssd_flops = 2 * B * S * nh * hd * (chunk + 2 * ds)
    emit("kernels/ssd_scan_ref", us, f"flops~{ssd_flops:.2e} chunk={chunk}")

    xr = jax.random.normal(ks[0], (4096, 1024), jnp.float32)
    sc = jnp.ones((1024,))
    fr = jax.jit(rmsnorm_ref)
    us = _time(fr, xr, sc)
    rb = 2 * xr.size * 4
    emit("kernels/rmsnorm_ref", us, f"bytes={rb:.2e} bw={rb/us*1e6/1e9:.1f}GB/s")


if __name__ == "__main__":
    main()
