"""Serving driver: prefill + batched greedy decode with the KV/SSM cache.

CPU-scale demo of the serve path the decode_32k/long_500k dry-runs lower; the same
``decode_step`` pjit-shards the cache per sharding/specs.py on the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \
      --batch 2 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def generate(model, params, prompt_tokens: jax.Array, max_new: int, *, audio_embed=None):
    """Greedy decode. prompt_tokens: (B, S0). Returns (B, S0+max_new)."""
    B, S0 = prompt_tokens.shape
    max_len = S0 + max_new
    batch = {"tokens": prompt_tokens}
    if audio_embed is not None:
        batch["audio_embed"] = audio_embed
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b))(params, batch)

    # grow attention caches to max_len
    full = model.init_cache(B, max_len, dtype=jnp.bfloat16)

    def merge(dst, src):
        if isinstance(dst, dict):
            return {k: merge(dst[k], src[k]) if k in src else dst[k] for k in dst}
        if isinstance(dst, list):
            return [merge(d, s) for d, s in zip(dst, src)]
        if hasattr(dst, "shape") and dst.shape != src.shape:
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)
        return src.astype(dst.dtype)

    cache = merge(full, cache)
    step = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i))

    tokens = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)]
    out = prompt_tokens
    for i in range(max_new):
        tok = tokens[-1][:, None]
        out = jnp.concatenate([out, tok], axis=1)
        if i == max_new - 1:
            break
        logits, cache = step(params, cache, tok, jnp.int32(S0 + i))
        tokens.append(jnp.argmax(logits[:, 0], -1).astype(jnp.int32))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.RandomState(args.seed)
    prompt = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    audio = None
    if cfg.enc_dec:
        audio = jnp.asarray(
            rng.randn(args.batch, cfg.n_audio_frames, cfg.d_model), jnp.float32
        )
    t0 = time.perf_counter()
    out = generate(model, params, prompt, args.gen, audio_embed=audio)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", np.asarray(out[0, -args.gen:]).tolist())


if __name__ == "__main__":
    main()
