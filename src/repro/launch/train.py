"""End-to-end federated pre-training driver (Photon Aggregator + LLM Nodes in one
process for CPU; the same round step pjit-shards onto the production mesh on TPU).

Implements Algorithm 1 faithfully: reproducible client sampling, per-round stream
binding, local training via the jitted federated round, checkpoint/auto-resume,
held-out validation, and the paper's norm monitors.

Elastic participation (paper §7 robustness claims): ``--participation`` picks the
client-availability model (``uniform`` | ``dirichlet`` popularity skew | ``markov``
on/off churn), ``--dropout-rate`` injects seeded mid-round client failures, and
``--straggler-profile`` (``none`` | ``mild`` | ``heavy``, with ``--deadline`` to
override the cut-off) simulates hardware heterogeneity — clients that miss the round
deadline are masked out of the aggregate. Dropped/straggling clients contribute
zero-weight deltas inside the same jitted round, so the effective cohort varies per
round with no recompilation. ``--client-weighting examples`` switches the aggregate
to FedAvg data-size weighting. Per-round effective-K, weight entropy, and straggler
counts are logged alongside the paper's norm monitors.

Async buffered aggregation (Photon's FedBuff-style aggregator, arXiv 2411.02908):
``--aggregation async`` replaces the deadline-masking synchronous round with an
event-driven timeline — K client slots stay busy, each completed client's
pseudo-gradient is admitted into a server-side delta buffer with a staleness
discount ``w/(1+s)^α``, and one outer update fires per ``--buffer-size`` admitted
deltas. Slow clients land in later buffers instead of being masked to zero, so
under straggler-heavy profiles the simulated wall-clock per unit of aggregated
work drops (logged as ``sim_time`` + ``wallclock_speedup`` per update, with
staleness histograms and buffer occupancy). ``--staleness-alpha`` sets the
discount exponent; ``--max-staleness`` rejects deltas older than that many server
rounds.

Compressed uplink (``core/compression.py`` codecs): ``--uplink {float32,bf16,
int8,topk}`` encodes each client's pseudo-gradient before it crosses the
client→server boundary — bf16 stochastic rounding (2x), per-tensor int8 (~4x), or
top-k sparsification with per-client error feedback (``--topk-fraction``, 10-100x).
The identity (float32) uplink is bitwise the uncompressed round. Error-feedback
residuals are keyed by population client id (one row per client, under sync
cohorts AND async dispatch), live inside the checkpointed state, and resume
exactly; per-round uplink bytes / compression ratio / residual norms are logged.

Straggler partial progress (``--partial-progress``, ROADMAP item 1): instead of
cutting a slow client at the deadline, credit the τ_i = min(τ,
⌊τ·speed·deadline⌋) local steps it actually finished — the jitted round holds a
spent client's lanes via a traced (K,) τ-mask (no recompile as τ_i varies) and
the Aggregator's weight policy scales its delta by τ_i/τ. Under async the
deadline becomes a per-dispatch budget and the partial delta admits at the
fractional weight. Per-round mean τ_i/τ, full-τ fraction and rescued-compute
estimates are logged.

Cross-process runtime (``--runtime sockets``, docs/runtime.md): the simulated
single-process timeline becomes a real deployment — ``--role server`` owns the
buffered aggregator, the dispatch manifest and every client's data cursor
behind a length-prefixed socket protocol; N ``--role client`` worker processes
pull self-describing assignments, run the same jitted client phase and push
encoded uplink payloads back. Leases redispatch work from dead workers,
``--flush-deadline`` keeps rounds progressing past stragglers, ``--chaos-*``
injects drop/delay/kill faults, and because the server alone owns resumable
state, ``--resume`` after a server kill replays the remainder bitwise. With
the same seeds the socket run's final params are bitwise the in-process run's.

Server-side aggregation is driven through the unified ``Aggregator`` seam
(``core/aggregator.py``): ``SyncAggregator`` / ``AsyncFederationDriver`` own
the admission rule, the weight policy and the canonical checkpoint schema —
which is what makes ``--aggregation async --resume`` exact: every update
checkpoints the buffer lanes, residual store, dispatch cursor and in-flight
params snapshots, and a killed-and-resumed run is bitwise the uninterrupted one.

Adaptive aggregation control (``--control``, docs/control.md): close the loop
between the observed telemetry and the aggregation knobs. ``--control
staleness`` (async) drives ``--staleness-alpha``/``--buffer-size`` toward a
target admitted-staleness quantile read off the cumulative histogram;
``--control cohort`` (sync) tunes the straggler deadline and
``--clients`` from the realized effective-K fraction. ``--control static``
(the default) is the identity — bitwise the uncontrolled run. Knob updates
land only at round/flush boundaries on bucketed grids (α on 1/16 steps, buffer
on powers of two, K in steps of 2), are emitted as ``knob_update`` obs events
with their triggering evidence, and the controller state rides the checkpoint
manifest so a governed run kills and ``--resume``\\ s bitwise.

Byzantine-resilient aggregation (docs/robustness.md): ``--robust-agg
{none,trimmed,median,normclip}`` swaps the server's plain weighted mean for a
robust rule (coordinate-wise trimmed mean / median, or per-delta norm
clipping); ``--screen`` adds a delta screen at the admission boundary —
non-finite deltas are rejected unconditionally, norm outliers past
``--screen-z`` robust z-scores are zero-weighted (sync cohort) or rejected at
the buffer door (async, with a ``--screen-warmup`` adaptive bound) and
quarantined for ``--quarantine-rounds``; ``--rollback`` (requires
``--ckpt-dir``) arms the divergence guard — an update norm spiking past
``--rollback-factor`` × the trailing ``--rollback-window`` median restores
the server from the last good checkpoint. All three compose freely and ride
the checkpoint manifest, so a defended run kills and ``--resume``\\ s bitwise;
with everything off the round is bitwise the undefended one. Attacks come
from ``--chaos-corrupt`` (socket runtime: worker payloads poisoned on the
wire side) or ``--byzantine-fraction``/``--byzantine-kind`` (async inproc:
deterministic attacker clients — the bench harness). ``--robust-agg`` is
incompatible with ``--fused-server``; under ``--cohort-tile`` the trimmed and
median rules stream per-tile fold buffers, normclip needs an absolute
``--clip-norm``, and ``--screen`` (whole-cohort norms) is unavailable.

The full flag matrix — how ``--aggregation`` × ``--uplink`` × ``--runtime`` ×
``--control`` × ``--robust-agg`` compose, and which doc covers which layer —
is mapped in docs/architecture.md.

Usage (CPU, minutes):
  PYTHONPATH=src python -m repro.launch.train --arch photon-75m --reduced \
      --rounds 4 --local-steps 8 --clients 4 --population 8
  PYTHONPATH=src python -m repro.launch.train --reduced --rounds 2 \
      --participation markov --dropout-rate 0.25 --straggler-profile mild
  PYTHONPATH=src python -m repro.launch.train --reduced --rounds 4 \
      --straggler-profile heavy --partial-progress
  PYTHONPATH=src python -m repro.launch.train --reduced --rounds 4 \
      --aggregation async --buffer-size 2 --straggler-profile heavy \
      --uplink topk --topk-fraction 0.05 --ckpt-dir /tmp/ck   # then --resume
  PYTHONPATH=src python -m repro.launch.train --reduced --rounds 6 \
      --aggregation async --straggler-profile heavy --control staleness \
      --control-target 4 --trace /tmp/run.jsonl
  PYTHONPATH=src python -m repro.launch.train --reduced --rounds 6 \
      --aggregation async --byzantine-fraction 0.2 --byzantine-kind nan \
      --robust-agg trimmed --screen --rollback --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.control import (
    CohortTuner,
    FederationController,
    KnobUpdate,
    StalenessGovernor,
)
from repro.core import (
    CORRUPT_KINDS,
    ROBUST_RULES,
    STRAGGLER_PROFILES,
    UPLINK_SCHEMES,
    AsyncAggConfig,
    AsyncBufferAggregator,
    AsyncFederationDriver,
    FederatedConfig,
    InnerOptConfig,
    OuterOptConfig,
    ParticipationConfig,
    RobustAggConfig,
    SyncAggregator,
    get_codec,
    make_byzantine_fn,
    plan_round,
)
from repro.data import build_client_streams, round_batches, validation_stream
from repro.metrics import (
    MetricLogger,
    evaluate_perplexity,
    partial_progress_metrics,
    participation_metrics,
    perplexity,
    staleness_stats,
    uplink_round_metrics,
    wallclock_speedup,
)
from repro.models import build_model
from repro.obs import JsonlSink, MetricsServer, Tracer
from repro.runtime import ChaosConfig, ClientWorker, FederationDriver, SocketBackend


def _chaos_from_args(args):
    chaos = ChaosConfig(
        drop=args.chaos_drop, delay=args.chaos_delay, kill=args.chaos_kill,
        corrupt=args.chaos_corrupt,
        corrupt_kinds=tuple(
            k.strip() for k in args.chaos_corrupt_kinds.split(",") if k.strip()
        ),
        seed=args.chaos_seed,
    )
    return chaos if chaos.active else None


def _robust_from_args(args):
    """``--robust-agg``/``--screen``/``--rollback`` → a
    :class:`RobustAggConfig`, or None when every defense is off (the
    aggregators then install no robust apply_fn at all — trivially bitwise
    the undefended round)."""
    if args.robust_agg == "none" and not args.screen and not args.rollback:
        return None
    return RobustAggConfig(
        rule=args.robust_agg,
        trim_fraction=args.trim_fraction,
        clip_mult=args.clip_mult,
        clip_norm=args.clip_norm,
        screen=args.screen,
        screen_z=args.screen_z,
        screen_warmup=args.screen_warmup,
        rollback=args.rollback,
        rollback_window=args.rollback_window,
        rollback_factor=args.rollback_factor,
        quarantine_rounds=args.quarantine_rounds,
    )


def _build_tracer(args, proc):
    """One tracer per process: events go to ``--trace`` (JSONL), counters feed
    ``--metrics-port``. Returns None when neither flag is set — every
    instrumented seam then sees the zero-overhead NULL_TRACER."""
    if args.trace is None and args.metrics_port is None:
        return None
    sink = JsonlSink(args.trace) if args.trace else None
    return Tracer(sink=sink, proc=proc, trace_id=f"seed{args.seed}")


def _start_metrics(args, tracer, extra=None):
    if tracer is None or args.metrics_port is None:
        return None
    srv = MetricsServer(tracer, port=args.metrics_port, extra=extra)
    print(f"metrics serving on {srv.host}:{srv.port}", flush=True)
    return srv


def _build_controller(args, acfg=None, straggler=None):
    """``--control`` → a :class:`FederationController` (or None for static).

    Validates the policy/aggregation pairing up front: the staleness governor
    only has async knobs, the cohort tuner only sync ones, and cohort resizing
    is incompatible with ``--keep-opt`` (the persisted inner state is
    K-shaped)."""
    if args.control == "static":
        return None  # no controller object at all: the bitwise-default path
    if args.control == "staleness":
        if args.aggregation != "async":
            raise SystemExit(
                "--control staleness drives the async buffer knobs "
                "(--staleness-alpha/--buffer-size) — it requires "
                "--aggregation async; for sync runs use --control cohort"
            )
        policy = StalenessGovernor(
            staleness_alpha=args.staleness_alpha,
            buffer_size=acfg.buffer_size,
            target=args.control_target if args.control_target is not None else 1.0,
            quantile=args.control_quantile,
            gain=args.control_gain if args.control_gain is not None else 0.5,
            buffer_max=max(acfg.buffer_size, args.clients),
        )
    else:  # cohort
        if args.aggregation != "sync":
            raise SystemExit(
                "--control cohort drives the sync deadline/cohort knobs — it "
                "requires --aggregation sync; for async runs use "
                "--control staleness"
            )
        if args.keep_opt:
            raise SystemExit(
                "--control cohort resizes the cohort, which is incompatible "
                "with --keep-opt (the persisted inner optimizer state is "
                "(K, ...)-shaped)"
            )
        if straggler.deadline <= 0.0:
            raise SystemExit(
                "--control cohort needs a finite straggler deadline to tune: "
                "pick --straggler-profile mild/heavy or set --deadline"
            )
        policy = CohortTuner(
            clients_per_round=args.clients,
            deadline=straggler.deadline,
            population=args.population,
            target=args.control_target if args.control_target is not None else 0.9,
            gain=args.control_gain if args.control_gain is not None else 0.25,
        )
    return FederationController(
        policy, window=args.control_window, interval=args.control_interval
    )


def _restore_controller(controller, manifest, latest):
    """Reconcile ``--control`` with the checkpoint's controller state.

    Returns the restored controller (None for a static resume). Refuses every
    asymmetric combination — a governed run resumed statically (or vice versa)
    would silently follow a different knob trajectory than the original."""
    ctrl_state = manifest.get("control") if isinstance(manifest, dict) else None
    if controller is None:
        if ctrl_state is not None:
            raise SystemExit(
                f"--resume: checkpoint round {latest} carries live "
                f"--control {ctrl_state.get('policy')} state but this run asked "
                f"for --control static — the knob trajectory would diverge; "
                f"resume with the original policy"
            )
        return None
    if ctrl_state is None:
        raise SystemExit(
            f"--resume: --control {controller.policy.name} requested but "
            f"checkpoint round {latest} was written without a controller — "
            f"resume with --control static or start fresh"
        )
    try:
        controller.load_state_dict(ctrl_state)
    except ValueError as e:
        raise SystemExit(f"--resume: {e}")
    return controller


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="photon-75m")
    ap.add_argument("--reduced", action="store_true", help="use the smoke-scale config")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=8, help="τ")
    ap.add_argument("--clients", type=int, default=4, help="K sampled per round")
    ap.add_argument("--population", type=int, default=8, help="P total clients")
    ap.add_argument("--batch", type=int, default=4, help="per-client batch size")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--heterogeneous", action="store_true", help="Pile-style partition")
    ap.add_argument("--outer", default="fedavg", choices=["fedavg", "fedmom", "fedadam"])
    ap.add_argument("--outer-lr", type=float, default=1.0)
    ap.add_argument("--inner-lr", type=float, default=3e-4)
    ap.add_argument("--keep-opt", action="store_true")
    ap.add_argument("--fedprox-mu", type=float, default=0.0)
    ap.add_argument("--dp-clip", type=float, default=0.0)
    ap.add_argument("--dp-noise", type=float, default=0.0)
    ap.add_argument("--pseudo-grad-dtype", default="float32",
                    help="legacy flat-cast uplink; superseded by --uplink")
    ap.add_argument(
        "--uplink", default="float32", choices=list(UPLINK_SCHEMES),
        help="pseudo-gradient uplink codec: float32 (identity, bitwise the "
             "uncompressed round), bf16 stochastic-rounding cast, per-tensor "
             "int8, or top-k sparsification with per-client error feedback",
    )
    ap.add_argument("--topk-fraction", type=float, default=0.05,
                    help="--uplink topk: fraction of entries kept per tensor")
    ap.add_argument(
        "--fused-server", action="store_true",
        help="fused Pallas federation path (kernels/fedcore): the server "
             "weighted-mean + DP noise + outer update run as ONE pass over the "
             "flat (C, N) delta buffer, and --uplink codecs use the fused "
             "flat-buffer kernels. Compiled on TPU; on CPU hosts the identical "
             "math runs as a flat XLA chain. Off (default) keeps the per-leaf "
             "jnp reference path, bitwise-unchanged",
    )
    ap.add_argument(
        "--cohort-tile", type=int, default=None,
        help="sync: stream the cohort through the round in fixed-size tiles "
             "of this many clients, folding each tile into weighted partial "
             "sums (two-tier aggregation, docs/aggregation.md) so the (C, N) "
             "delta buffer is bounded by the tile size regardless of cohort "
             "size. Bitwise the flat round when the tile equals --clients. "
             "Incompatible with --fused-server and --keep-opt",
    )
    ap.add_argument(
        "--participation", default="uniform", choices=["uniform", "dirichlet", "markov"],
        help="client-availability model: uniform sampling, Dirichlet popularity "
             "skew, or per-client Markov on/off churn",
    )
    ap.add_argument("--dirichlet-alpha", type=float, default=0.3,
                    help="popularity concentration for --participation dirichlet")
    ap.add_argument("--dropout-rate", type=float, default=0.0,
                    help="per-round probability each selected client fails mid-round")
    ap.add_argument(
        "--straggler-profile", default="none", choices=sorted(STRAGGLER_PROFILES),
        help="hardware-heterogeneity preset; stragglers past the deadline are masked",
    )
    ap.add_argument("--deadline", type=float, default=None,
                    help="round deadline in median-client-round units (overrides profile)")
    ap.add_argument(
        "--partial-progress", action="store_true",
        help="straggler partial progress: a client that misses the deadline "
             "contributes the τ_i = min(τ, ⌊τ·speed·deadline⌋) local steps it "
             "actually finished, weighted by τ_i/τ, instead of being cut "
             "(sync) or arriving late (async: the deadline becomes a "
             "per-dispatch budget and partial deltas admit at fractional "
             "weight)",
    )
    ap.add_argument(
        "--client-weighting", default="uniform", choices=["uniform", "examples"],
        help="aggregation weights: uniform mean or FedAvg data-size (n_k) weighting",
    )
    ap.add_argument(
        "--aggregation", default="sync", choices=["sync", "async"],
        help="sync: deadline-masked federated rounds; async: FedBuff-style "
             "buffered aggregation — stragglers land in later buffers with "
             "staleness-discounted weights instead of being dropped",
    )
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="async: deltas per outer update (M); default max(1, K//2)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="async: staleness discount exponent in w/(1+s)^alpha")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="async: reject deltas older than this many server rounds "
                         "(0 = accept any age)")
    ap.add_argument(
        "--runtime", default="inproc", choices=["inproc", "sockets"],
        help="inproc: the simulated single-process timeline; sockets: a real "
             "cross-process deployment — this process is the aggregation "
             "server (--role server) or one client worker (--role client) "
             "speaking the length-prefixed socket protocol (docs/runtime.md). "
             "Requires --aggregation async",
    )
    ap.add_argument("--role", default="server", choices=["server", "client"],
                    help="--runtime sockets: which process this is")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="server: listen port (0 = pick a free one, printed at "
                         "startup); client: the server's port")
    ap.add_argument("--worker-id", default="worker-0",
                    help="--role client: this worker's name (lease bookkeeping)")
    ap.add_argument("--lease-timeout", type=float, default=30.0,
                    help="server: seconds before a granted-but-unreturned "
                         "assignment is redispatched to another worker")
    ap.add_argument("--io-timeout", type=float, default=30.0,
                    help="sockets: per-request socket timeout")
    ap.add_argument("--flush-deadline", type=float, default=None,
                    help="server: flush a partially filled buffer when the next "
                         "in-order result stalls this many seconds (default: "
                         "wait forever — preserves exact parity with inproc)")
    ap.add_argument("--chaos-drop", type=float, default=0.0,
                    help="fault injection: P(outbound message dropped)")
    ap.add_argument("--chaos-delay", type=float, default=0.0,
                    help="fault injection: P(outbound message delayed)")
    ap.add_argument("--chaos-kill", type=float, default=0.0,
                    help="fault injection: P(process hard-exits before a send)")
    ap.add_argument("--chaos-corrupt", type=float, default=0.0,
                    help="fault injection: P(a worker's push payload is "
                         "poisoned before send — NaN/Inf fill, ×64 scale, "
                         "sign flip or replay of the previous push; "
                         "docs/robustness.md)")
    ap.add_argument("--chaos-corrupt-kinds", default=",".join(CORRUPT_KINDS),
                    help="comma-separated corruption kinds the --chaos-corrupt "
                         f"die picks from (any of: {', '.join(CORRUPT_KINDS)})")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument(
        "--robust-agg", default="none", choices=list(ROBUST_RULES),
        help="Byzantine-resilient aggregation rule (docs/robustness.md): "
             "none (plain weighted mean, bitwise the undefended round), "
             "trimmed (coordinate-wise trimmed mean), median (coordinate-wise "
             "median), or normclip (per-delta norm clipping before the "
             "weighted mean)",
    )
    ap.add_argument("--trim-fraction", type=float, default=0.1,
                    help="--robust-agg trimmed: fraction of extreme values "
                         "trimmed from EACH tail per coordinate")
    ap.add_argument("--clip-mult", type=float, default=3.0,
                    help="--robust-agg normclip: clip threshold as a multiple "
                         "of the cohort's median delta norm (used when "
                         "--clip-norm is 0)")
    ap.add_argument("--clip-norm", type=float, default=0.0,
                    help="--robust-agg normclip: absolute clip threshold "
                         "(0 = derive from --clip-mult; required >0 with "
                         "--cohort-tile)")
    ap.add_argument(
        "--screen", action="store_true",
        help="delta screen at the admission boundary: non-finite deltas are "
             "rejected unconditionally and norm outliers (median/MAD z-score "
             "past --screen-z) are zero-weighted (sync) or rejected at the "
             "buffer door (async)",
    )
    ap.add_argument("--screen-z", type=float, default=6.0,
                    help="--screen: robust z-score threshold for norm outliers")
    ap.add_argument("--screen-warmup", type=int, default=8,
                    help="async --screen: admitted norms observed before the "
                         "adaptive bound engages (unbounded until then)")
    ap.add_argument(
        "--rollback", action="store_true",
        help="divergence guard + automatic rollback (requires --ckpt-dir): "
             "when the update norm spikes past --rollback-factor × the "
             "trailing window median (or goes non-finite), the server "
             "restores params/outer from the last good checkpoint and "
             "quarantines the round's contributors (sync)",
    )
    ap.add_argument("--rollback-window", type=int, default=8,
                    help="--rollback: trailing update norms in the guard window")
    ap.add_argument("--rollback-factor", type=float, default=4.0,
                    help="--rollback: spike multiple over the window median "
                         "that trips the guard")
    ap.add_argument("--quarantine-rounds", type=int, default=4,
                    help="rounds a screened/rolled-back client is excluded "
                         "from aggregation")
    ap.add_argument("--byzantine-fraction", type=float, default=0.0,
                    help="simulated attack (async inproc, bench harness): "
                         "population clients below floor(fraction·P) corrupt "
                         "every delta they push")
    ap.add_argument("--byzantine-kind", default="scale",
                    choices=[k for k in CORRUPT_KINDS if k != "replay"],
                    help="what the --byzantine-fraction attackers send")
    ap.add_argument(
        "--control", default="static", choices=["static", "staleness", "cohort"],
        help="closed-loop aggregation control (docs/control.md): static = the "
             "identity policy, bitwise the uncontrolled run; staleness (async "
             "only) governs --staleness-alpha/--buffer-size toward a target "
             "admitted-staleness quantile; cohort (sync only) tunes the "
             "straggler deadline and --clients from the effective-K fraction",
    )
    ap.add_argument("--control-target", type=float, default=None,
                    help="policy setpoint: the admitted-staleness quantile "
                         "value in server rounds (staleness, default 1.0) or "
                         "the effective-K fraction (cohort, default 0.9)")
    ap.add_argument("--control-quantile", type=float, default=0.9,
                    help="--control staleness: which staleness quantile to "
                         "hold at the target")
    ap.add_argument("--control-gain", type=float, default=None,
                    help="proportional gain of the control law (default 0.5 "
                         "staleness / 0.25 cohort); lower it if the policy "
                         "oscillates (docs/control.md tuning guide)")
    ap.add_argument("--control-window", type=int, default=4,
                    help="metric rows the controller aggregates per decision")
    ap.add_argument("--control-interval", type=int, default=1,
                    help="boundaries between control decisions (1 = every "
                         "round/flush)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="append structured trace events to this JSONL file "
                         "(docs/observability.md); under --runtime sockets "
                         "give each process its own path, then merge with "
                         "python -m repro.obs.report. Tracing never changes "
                         "aggregation results (bitwise, tested)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve a Prometheus-style text endpoint on "
                         "127.0.0.1:PORT/metrics (0 = pick a free port, "
                         "printed at startup)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--eval-batches", type=int, default=2)
    return ap.parse_args(argv)


def run(args, cfg=None) -> dict:
    if cfg is None:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, max_seq_len=max(cfg.max_seq_len, args.seq_len))
    model = build_model(cfg)

    fed = FederatedConfig(
        clients_per_round=args.clients,
        local_steps=args.local_steps,
        inner=InnerOptConfig(
            lr_max=args.inner_lr,
            warmup_steps=max(1, args.rounds * args.local_steps // 20),
            total_steps=args.rounds * args.local_steps,
        ),
        outer=OuterOptConfig(name=args.outer, lr=args.outer_lr),
        keep_inner_state=args.keep_opt,
        fedprox_mu=args.fedprox_mu,
        dp_clip=args.dp_clip,
        dp_noise=args.dp_noise,
        pseudo_grad_dtype=args.pseudo_grad_dtype,
    )

    straggler = STRAGGLER_PROFILES[args.straggler_profile]
    if args.deadline is not None:
        straggler = dataclasses.replace(straggler, deadline=args.deadline)
    pcfg = ParticipationConfig(
        population=args.population,
        clients_per_round=args.clients,
        model=args.participation,
        dirichlet_alpha=args.dirichlet_alpha,
        dropout_rate=args.dropout_rate,
        straggler=straggler,
        weighting=args.client_weighting,
    )

    # --- Photon Data Sources: one stream per population member -----------
    streams = build_client_streams(
        args.population, args.seq_len, cfg.vocab_size,
        heterogeneous=args.heterogeneous, seed=args.seed,
    )
    val_stream = validation_stream(args.seq_len, cfg.vocab_size, args.heterogeneous)

    # --- server state ------------------------------------------------------
    params = model.init(jax.random.PRNGKey(args.seed))

    if args.uplink != "float32" and args.pseudo_grad_dtype != "float32":
        raise SystemExit(
            "--uplink and the legacy --pseudo-grad-dtype are mutually exclusive: "
            "the codec already defines the wire format"
        )
    codec = (
        get_codec(args.uplink, args.topk_fraction, fused=args.fused_server)
        if args.uplink != "float32" else None
    )

    if args.runtime == "sockets" and args.aggregation != "async":
        raise SystemExit(
            "--runtime sockets requires --aggregation async: the socket server "
            "IS the buffered-aggregation event loop (docs/runtime.md)"
        )
    try:
        robust = _robust_from_args(args)
    except ValueError as e:
        raise SystemExit(f"--robust-agg: {e}")
    if robust is not None and args.rollback and not args.ckpt_dir:
        raise SystemExit(
            "--rollback restores the server from the last good checkpoint — "
            "it requires --ckpt-dir"
        )
    if robust is not None and robust.active and args.fused_server:
        raise SystemExit(
            "--robust-agg/--screen and --fused-server are mutually exclusive: "
            "the fused Pallas server path computes the plain weighted mean "
            "in one pass and has no robust-rule variant (docs/robustness.md)"
        )
    if robust is not None and args.cohort_tile:
        if robust.screen:
            raise SystemExit(
                "--screen needs the whole cohort's delta norms at once and "
                "cannot compose with --cohort-tile streaming; use "
                "--robust-agg trimmed/median (tiled per-coordinate folds) "
                "or normclip with an absolute --clip-norm"
            )
        if robust.rule == "normclip" and robust.clip_norm <= 0.0:
            raise SystemExit(
                "--robust-agg normclip under --cohort-tile needs an absolute "
                "--clip-norm: the median-derived threshold (--clip-mult) "
                "requires every cohort norm before any tile is folded"
            )
    if args.byzantine_fraction > 0.0 and (
        args.aggregation != "async" or args.runtime != "inproc"
    ):
        raise SystemExit(
            "--byzantine-fraction is the in-process async attack simulator "
            "(the bench harness hook); under --runtime sockets inject payload "
            "corruption with --chaos-corrupt instead"
        )
    if args.aggregation == "async":
        if args.cohort_tile:
            raise SystemExit(
                "--cohort-tile applies to --aggregation sync only: the async "
                "path already streams one client delta at a time into the "
                "buffer, so its memory is bounded by the buffer size M, not "
                "the cohort"
            )
        if args.keep_opt:
            raise SystemExit(
                "--keep-opt with --aggregation async is not supported: async "
                "clients are stateless (paper §7.8) — a client's next dispatch "
                "may serve a different model version, so persisted inner Adam "
                "state would be silently stale"
            )
        if args.runtime == "sockets" and args.role == "client":
            return _run_worker(args, model, fed, pcfg, streams, codec)
        return _run_async(args, cfg, model, fed, pcfg, streams, val_stream, params, codec)

    def loss_fn(p, b):
        return model.loss(p, b)

    # the Aggregator seam owns (a) the admission rule (the plan's mask /
    # partial-progress τ_i), (b) the weight policy (FedAvg n_k scaled by τ_i/τ)
    # and (c) the checkpoint schema. Weights, cohort ids and the τ-mask enter
    # the jitted round as traced arguments: per-round participation changes
    # (dropouts, stragglers, K_eff < K, realized τ_i) never trigger a recompile.
    tracer = _build_tracer(args, "server")
    controller = _build_controller(args, straggler=straggler)
    agg = SyncAggregator(
        loss_fn, fed, pcfg, codec=codec, seed=args.seed,
        partial_progress=args.partial_progress, fused_server=args.fused_server,
        cohort_tile=args.cohort_tile, robust=robust,
        params=params, rng=jax.random.PRNGKey(args.seed + 1),
        tracer=tracer, controller=controller,
    )
    metrics_srv = _start_metrics(args, tracer)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_round = 0
    if ckpt and args.resume:
        latest = ckpt.latest_round()
        if latest is not None:
            agg_man = ckpt.load_manifest(latest).get("extra", {}).get("aggregator")
            if agg_man is not None:
                if agg_man.get("kind") != "sync":
                    # load_pytree would silently satisfy the sync template from
                    # an async checkpoint's npz (the sync keys are a strict
                    # subset of the async schema) — refuse the kind mismatch
                    raise SystemExit(
                        f"--resume: checkpoint round {latest} was written by a "
                        f"--aggregation {agg_man.get('kind')} run; resuming it "
                        f"synchronously would silently drop the buffer lanes "
                        f"and the in-flight dispatch queue — resume with the "
                        f"original aggregation mode or start fresh"
                    )
                try:
                    SyncAggregator.validate_manifest(agg_man, "sync")
                except ValueError as e:
                    raise SystemExit(f"--resume: {e}")
            # the load template comes from the checkpoint schema, not from
            # agg.state: the residual lane is sized by the manifest's recorded
            # id set (sparse checkpoints) or by the population (legacy dense
            # checkpoints) — either way nothing population-sized is allocated
            like = SyncAggregator.checkpoint_template(
                fed, pcfg, params, codec,
                uplink_ids=(
                    agg_man.get("uplink_ids")
                    if isinstance(agg_man, dict) else None
                ),
            )
            try:
                state, manifest = ckpt.load_server(latest, like)
            except KeyError as e:
                raise SystemExit(
                    f"--resume: checkpoint round {latest} does not carry the "
                    f"state this run needs (missing {e}); error-feedback "
                    f"residuals only round-trip when the checkpoint was written "
                    f"with the same --uplink codec"
                )
            ckpt_uplink = manifest.get("extra", {}).get("args", {}).get(
                "uplink", "float32"
            )
            if get_codec(ckpt_uplink).stateful and not (
                codec is not None and codec.stateful
            ):
                # the reverse direction of the KeyError above: load_pytree
                # ignores npz keys absent from the template, so without this
                # check the clients' accumulated residual mass would be
                # silently dropped
                raise SystemExit(
                    f"--resume: checkpoint round {latest} was written with "
                    f"--uplink {ckpt_uplink} and carries per-client "
                    f"error-feedback residuals; resuming with --uplink "
                    f"{args.uplink} would silently discard them — use the "
                    f"original codec or start fresh"
                )
            controller = _restore_controller(
                controller, agg_man if isinstance(agg_man, dict) else {}, latest
            )
            if controller is not None:
                # the checkpoint may have been taken mid-trajectory: rebuild
                # the aggregator at the controller's CURRENT knob values, not
                # the CLI defaults, before any round runs
                knobs = controller.knobs()
                agg.apply_knobs(KnobUpdate(
                    clients_per_round=int(knobs["clients_per_round"]),
                    deadline=knobs["deadline"],
                ))
            agg.restore(state, agg_man if isinstance(agg_man, dict) else None)
            start_round = latest + 1
            for i, s in enumerate(streams):
                try:
                    s.load_state_dict(ckpt.load_client(latest, i))
                except FileNotFoundError:
                    pass
            print(f"resumed from round {latest}")

    logger = MetricLogger(args.log) if args.log else None

    history = []
    try:
        _run_sync_rounds(
            args, model, agg, streams, val_stream, ckpt, logger, history,
            start_round, params, codec,
        )
    finally:
        if metrics_srv is not None:
            metrics_srv.close()
        if tracer is not None:
            tracer.close()

    return {"history": history, "state": agg.state, "model": model, "config": cfg,
            "aggregator": agg}


def _run_sync_rounds(args, model, agg, streams, val_stream, ckpt, logger,
                     history, start_round, params, codec):
    for rnd in range(start_round, args.rounds):
        t0 = time.perf_counter()  # monotonic: durations, never wall timestamps
        plan = agg.plan(rnd)
        sel = plan.selected
        batches_np = round_batches([streams[i] for i in sel], args.local_steps, args.batch)
        batches = {k: jnp.asarray(v) for k, v in batches_np.items()}
        metrics = agg.run_round(batches, plan)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics.update(
            round=rnd,
            selected=",".join(map(str, sel)),  # slot ids, incl. zero-weight padding
            contributors=",".join(map(str, sel[plan.mask])),  # actually aggregated
            seconds=time.perf_counter() - t0,
            train_ppl=perplexity(metrics["train_loss"]),
            **participation_metrics(plan),
            **partial_progress_metrics(plan, args.local_steps),
            **uplink_round_metrics(
                args.uplink, params, plan.effective_k, args.topk_fraction,
                codec=codec,
            ),
        )
        val_ppl = evaluate_perplexity(
            model, agg.state["params"], val_stream, batches=args.eval_batches,
            batch_size=args.batch,
        )
        metrics["val_ppl"] = val_ppl
        history.append(metrics)
        partial = (
            f" tau={metrics['partial_tau_mean']:.2f} "
            f"rescued={metrics['partial_rescued_clients']:.0f}"
            if args.partial_progress else ""
        )
        print(
            f"round {rnd}: loss={metrics['train_loss']:.4f} val_ppl={val_ppl:.2f} "
            f"pg_norm={metrics['pseudo_grad_norm']:.4f} "
            f"consensus={metrics['client_consensus']:.3f} "
            f"eff_K={plan.effective_k}/{len(plan.selected)} "
            f"stragglers={plan.n_stragglers} dropped={plan.n_dropped}"
            f"{partial} [{metrics['seconds']:.1f}s]"
        )
        # the round boundary is also the divergence-guard control point: the
        # guard sees this round's update norm BEFORE the checkpoint save, so a
        # poisoned round is rolled back and never becomes a resume point
        rs = agg.robust_state
        tripped = rolled_back = False
        if rs is not None and agg.robust is not None and agg.robust.rollback:
            metrics["rolled_back"] = 0.0
            tripped = rs.observe_update(metrics["pseudo_grad_norm"])
            if tripped:
                good = rs.last_good
                if good >= 0 and ckpt is not None:
                    like = {"params": agg.state["params"],
                            "outer": agg.state["outer"]}
                    restored, _ = ckpt.load_server(good, like)
                    agg.adopt_model(restored)
                    contributors = [int(c) for c in sel[plan.mask]]
                    rs.add_quarantine(contributors, rnd)
                    rs.note_rollback()
                    rolled_back = True
                    metrics["rolled_back"] = 1.0
                    if agg.tracer.enabled:
                        agg.tracer.point(
                            "rollback", round=rnd, restored_round=good,
                            pg_norm=float(metrics["pseudo_grad_norm"])
                            if metrics["pseudo_grad_norm"]
                            == metrics["pseudo_grad_norm"] else -1.0,
                            quarantined=len(contributors),
                        )
                        agg.tracer.count("rollbacks")
                    print(
                        f"  ROLLBACK: update norm "
                        f"{metrics['pseudo_grad_norm']:.4g} tripped the "
                        f"divergence guard — restored round {good}, "
                        f"quarantined {contributors} for "
                        f"{agg.robust.quarantine_rounds} rounds"
                    )
                else:
                    print(
                        "  divergence guard tripped but no good checkpoint "
                        "exists yet — continuing without rollback"
                    )
        # the round boundary is the sync control point: the cohort tuner sees
        # this round's composed row and may move the deadline/cohort knobs for
        # the NEXT round (applied knobs echo into the logged row)
        update = agg.control_step(metrics)
        if update is not None:
            for k, v in update.knob_dict().items():
                metrics[f"knob_{k}"] = v
            print("  control: " + ", ".join(
                f"{k}={v:g}" for k, v in update.knob_dict().items()
            ))
        if logger:
            logger.log(metrics)
        if ckpt:
            if rs is not None and (not tripped or rolled_back):
                # marked BEFORE checkpoint() so the saved manifest's last_good
                # points at THIS round — valid exactly when this checkpoint is
                # complete. A post-rollback checkpoint qualifies too: it holds
                # the restored clean state (and keeps the rollback target
                # inside the GC's keep-last window across consecutive trips)
                rs.mark_good(rnd)
            tree, agg_manifest = agg.checkpoint()
            ckpt.save_server(
                rnd, tree, extra={"args": vars(args), "aggregator": agg_manifest}
            )
            # every client's data cursor (unselected clients keep theirs unchanged;
            # saving all makes any round a complete resume point)
            for i in range(args.population):
                ckpt.save_client(rnd, i, streams[i].state_dict())


# args whose value changes the pure dispatch timeline, the data every client
# draws, or the optimizer/buffer semantics: an async resume with any of these
# altered would silently replay a DIFFERENT run ("--rounds" alone may change —
# extending the run is the point of resuming, though it re-derives the inner
# LR schedule's total_steps exactly as sync resume does)
_ASYNC_RESUME_ARGS = (
    "seed", "clients", "population", "local_steps", "batch", "buffer_size",
    "staleness_alpha", "max_staleness", "participation", "dirichlet_alpha",
    "dropout_rate", "straggler_profile", "deadline", "client_weighting",
    "uplink", "topk_fraction", "partial_progress", "fused_server",
    "arch", "reduced", "seq_len", "heterogeneous",
    "inner_lr", "outer", "outer_lr", "fedprox_mu",
    "dp_clip", "dp_noise", "pseudo_grad_dtype",
    "control", "control_target", "control_quantile", "control_gain",
    "control_window", "control_interval",
    "robust_agg", "trim_fraction", "clip_mult", "clip_norm",
    "screen", "screen_z", "screen_warmup",
    "rollback", "rollback_window", "rollback_factor", "quarantine_rounds",
    "byzantine_fraction", "byzantine_kind",
)

# flags with TRUTHY defaults that postdate older checkpoints: a checkpoint
# written before the flag existed behaved exactly like today's default, so
# only a non-default value conflicts (the falsy-default case is handled by
# the `not ours` skip below)
_RESUME_ARG_DEFAULTS = {
    "control": "static",
    "control_quantile": 0.9,
    "control_window": 4,
    "control_interval": 1,
    "robust_agg": "none",
    "trim_fraction": 0.1,
    "clip_mult": 3.0,
    "screen_z": 6.0,
    "screen_warmup": 8,
    "rollback_window": 8,
    "rollback_factor": 4.0,
    "quarantine_rounds": 4,
    "byzantine_kind": "scale",
}


def _run_worker(args, model, fed, pcfg, streams, codec=None) -> dict:
    """``--runtime sockets --role client``: one pure-compute worker process.

    It builds the SAME model/fed/participation configuration as the server (so
    both compile the same jitted client phase) but owns no federation state —
    every assignment ships the params snapshot, residual row, rng and the
    population client's data cursor (docs/runtime.md). The streams constructed
    here are cursor *receptacles*: the authoritative cursors live on the
    server and ride the wire.
    """
    if args.partial_progress:
        pcfg = dataclasses.replace(
            pcfg, partial_progress=True, local_steps=args.local_steps
        )
    tracer = _build_tracer(args, args.worker_id)
    worker = ClientWorker(
        lambda p, b: model.loss(p, b), fed, pcfg,
        streams=streams, batch_size=args.batch,
        host=args.host, port=args.port, codec=codec,
        name=args.worker_id, io_timeout=args.io_timeout,
        chaos=_chaos_from_args(args), tracer=tracer,
    )
    metrics_srv = _start_metrics(args, tracer)
    print(f"worker {args.worker_id} serving {args.host}:{args.port}")
    try:
        n = worker.run()
    finally:
        if metrics_srv is not None:
            metrics_srv.close()
        if tracer is not None:
            tracer.close()
    print(f"worker {args.worker_id} done after {n} assignments")
    return {"completed": n}


def _run_async(args, cfg, model, fed, pcfg, streams, val_stream, params, codec=None) -> dict:
    """Event-driven FedBuff-style training: K busy client slots, a server-side
    delta buffer, one outer update per ``--buffer-size`` admitted deltas.

    With ``codec``, completions upload encoded payloads (decoded at admission)
    and the driver owns one error-feedback residual row per population client.
    Every update checkpoints the aggregator's CANONICAL schema — buffer lanes,
    residual store, dispatch cursor, in-flight slot table and params snapshots
    — so ``--resume`` replays the pure-in-(cfg, seed, n) timeline from the
    checkpoint exactly: the resumed run is bitwise the uninterrupted one.
    """
    acfg = AsyncAggConfig(
        buffer_size=(
            args.buffer_size if args.buffer_size is not None
            else max(1, args.clients // 2)
        ),
        staleness_alpha=args.staleness_alpha,
        max_staleness=args.max_staleness,
    )
    if args.partial_progress:
        # the deadline becomes a per-dispatch budget: plan_round derives τ_i and
        # the aggregator admits partial deltas at the fractional τ_i/τ weight
        pcfg = dataclasses.replace(
            pcfg, partial_progress=True, local_steps=args.local_steps
        )
    controller = _build_controller(args, acfg=acfg)
    robust = _robust_from_args(args)

    def loss_fn(p, b):
        return model.loss(p, b)

    def make_batches(cid):
        b = round_batches([streams[cid]], args.local_steps, args.batch)
        return {k: jnp.asarray(v) for k, v in b.items()}

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    logger = MetricLogger(args.log) if args.log else None

    state = dispatch = None
    start_update = 0
    deltas_resumed = 0
    if args.resume:
        if ckpt is None:
            raise SystemExit("--resume with --aggregation async needs --ckpt-dir")
        latest = ckpt.latest_round()
        if latest is not None:
            manifest = ckpt.load_manifest(latest)
            extra = manifest.get("extra", {})
            dispatch = extra.get("aggregator")
            if not isinstance(dispatch, dict) or dispatch.get("kind") != "async":
                raise SystemExit(
                    f"--resume: checkpoint round {latest} carries no async "
                    f"aggregator manifest (written before the resumable schema, "
                    f"or by a sync run) — the in-flight dispatch queue cannot "
                    f"be replayed; start fresh"
                )
            ck_args = extra.get("args", {})
            for key in _ASYNC_RESUME_ARGS:
                ours = getattr(args, key)
                if key not in ck_args and (
                    not ours or ours == _RESUME_ARG_DEFAULTS.get(key)
                ):
                    # the flag postdates this checkpoint (e.g. --fused-server on
                    # a PR-4 checkpoint): the old run used today's default
                    # semantics, so only a non-default value conflicts
                    continue
                theirs = ck_args.get(key)
                if theirs is not None or ours is not None:
                    if ours != theirs:
                        raise SystemExit(
                            f"--resume: --{key.replace('_', '-')}={ours} does not "
                            f"match the checkpoint's {theirs} — the async "
                            f"timeline is pure in (config, seed), so resuming "
                            f"under a different configuration would silently "
                            f"replay a different run"
                        )
            controller = _restore_controller(controller, dispatch, latest)
            if controller is not None:
                # rebuild the async config at the controller's checkpointed
                # knob values: the buffer lanes in the npz have THAT shape,
                # and the resumed governor continues its trajectory from them
                knobs = controller.knobs()
                acfg = dataclasses.replace(
                    acfg,
                    staleness_alpha=float(knobs["staleness_alpha"]),
                    buffer_size=int(knobs["buffer_size"]),
                )
            like = AsyncBufferAggregator.checkpoint_template(
                fed, acfg, pcfg, params, codec,
                uplink_ids=dispatch.get("uplink_ids"),
            )
            state, _ = ckpt.load_server(latest, like)
            start_update = latest + 1
            deltas_resumed = int(extra.get("train", {}).get("deltas_admitted", 0))
            for i, s in enumerate(streams):
                try:
                    s.load_state_dict(ckpt.load_client(latest, i))
                except FileNotFoundError:
                    pass
            print(f"resumed async run from update {latest} "
                  f"(dispatch cursor {dispatch['cursor']}, "
                  f"sim_time {dispatch['sim_time']:.2f})")

    tracer = _build_tracer(args, "server")
    backend = None
    if args.runtime == "sockets":
        # the server owns every population client's data cursor: it ships the
        # cursor out with each assignment and commits the advanced cursor in
        # event order, so the checkpointed cursors stay consistent with the
        # dispatch manifest (any worker can then serve any client, and resume
        # recreates in-flight assignments with the cursor they shipped with)
        backend = SocketBackend(
            host=args.host, port=args.port,
            stream_states=[s.state_dict() for s in streams],
            lease_timeout=args.lease_timeout, io_timeout=args.io_timeout,
            chaos=_chaos_from_args(args), tracer=tracer,
        )
        print(f"server listening on {backend.host}:{backend.port}", flush=True)
        driver = FederationDriver(
            backend, fed, acfg, pcfg, flush_deadline=args.flush_deadline,
            seed=args.seed, params=params, rng=jax.random.PRNGKey(args.seed + 1),
            codec=codec, state=state, dispatch=dispatch, robust=robust,
            fused_server=args.fused_server, tracer=tracer, controller=controller,
        )
    else:
        driver = AsyncFederationDriver(
            loss_fn, fed, acfg, pcfg, make_batches,
            seed=args.seed, params=params, rng=jax.random.PRNGKey(args.seed + 1),
            codec=codec, state=state, dispatch=dispatch, robust=robust,
            fused_server=args.fused_server, tracer=tracer, controller=controller,
        )
        # the in-process attack simulator: deterministic Byzantine population
        # clients poison every delta they push (the robust-agg bench arms)
        driver.corrupt_fn = make_byzantine_fn(
            args.byzantine_fraction, args.byzantine_kind, args.population
        )
    metrics_srv = _start_metrics(
        args, tracer,
        # liveness + live control knobs (control_* gauges) from the backend
        extra=(backend.metrics_extras if backend is not None else None),
    )

    # reference: what the deadline-masking sync schedule pays to aggregate the
    # same number of client deltas (cached cumulative replay of plan_round)
    sync_cum = [(0.0, 0)]  # (cumulative sim time, cumulative aggregated deltas)

    def sync_equiv_time(n_deltas: int) -> float:
        while sync_cum[-1][1] < n_deltas and len(sync_cum) < 100_000:
            plan = plan_round(pcfg, args.seed, len(sync_cum) - 1)
            t, d = sync_cum[-1]
            sync_cum.append((t + plan.round_time, d + plan.effective_k))
        return sync_cum[-1][0] if sync_cum[-1][1] >= n_deltas else float("inf")

    history = []
    deltas_admitted = [deltas_resumed]
    t_wall = [time.perf_counter()]  # monotonic: row["seconds"] is a duration

    def on_update(i, row):
        u = start_update + i  # absolute outer-update index across resumes
        # mean/max staleness + buffer occupancy come in-graph from flush_buffer;
        # the host side only adds the histogram buckets of the admitted ages
        staleness = row.pop("admitted_staleness", [])
        row.update(
            (k, v)
            for k, v in staleness_stats(staleness).items()
            if k.startswith("staleness_hist_")
        )
        deltas_admitted[0] += int(row.get("buffer_fill", 0))
        row.update(
            uplink_round_metrics(
                args.uplink, params, row.get("buffer_fill", 0.0),
                args.topk_fraction, codec=codec,
            )
        )
        row.update(
            update=u,
            round=u,  # outer-update index, the async analogue of the round
            deltas_admitted=float(deltas_admitted[0]),
            wallclock_speedup=wallclock_speedup(
                sync_equiv_time(deltas_admitted[0]), row["sim_time"]
            ),
            work_completed=driver.work_completed,
            work_wasted=driver.work_wasted,
            seconds=time.perf_counter() - t_wall[0],
            train_loss=row["train_loss_mean"],
            train_ppl=perplexity(row["train_loss_mean"]),
        )
        t_wall[0] = time.perf_counter()
        row["val_ppl"] = evaluate_perplexity(
            model, driver.state["params"], val_stream,
            batches=args.eval_batches, batch_size=args.batch,
        )
        history.append(row)
        print(
            f"update {u}: loss={row['train_loss_mean']:.4f} "
            f"val_ppl={row['val_ppl']:.2f} "
            f"pg_norm={row['pseudo_grad_norm']:.4f} "
            f"staleness={row['staleness_mean']:.2f}/{row['staleness_max']:.0f} "
            f"buf={row['buffer_fill']:.0f}/{driver.acfg.buffer_size} "
            f"t_sim={row['sim_time']:.2f} "
            f"speedup={row['wallclock_speedup']:.2f}x [{row['seconds']:.1f}s]"
        )
        knobs = {k[len("knob_"):]: v for k, v in row.items()
                 if k.startswith("knob_")}
        if knobs:
            print("  control: " + ", ".join(
                f"{k}={v:g}" for k, v in knobs.items()
            ))
        # divergence guard (async): a spiking flush norm rolls the server back
        # to the last good checkpointed update. Contributors are NOT
        # quarantined here — the flushed buffer mixes many senders and the
        # lanes are already drained; repeat offenders are the door screen's
        # job (docs/robustness.md)
        rs = driver.robust_state
        tripped = rolled_back = False
        if rs is not None and robust is not None and robust.rollback:
            row["rolled_back"] = 0.0
            tripped = rs.observe_update(row["pseudo_grad_norm"])
            if tripped:
                good = rs.last_good
                if good >= 0 and ckpt is not None:
                    like = {"params": driver.state["params"],
                            "outer": driver.state["outer"]}
                    restored, _ = ckpt.load_server(good, like)
                    driver.adopt_model(restored)
                    rs.note_rollback()
                    rolled_back = True
                    row["rolled_back"] = 1.0
                    if driver.tracer.enabled:
                        driver.tracer.point(
                            "rollback", round=u, restored_round=good,
                        )
                        driver.tracer.count("rollbacks")
                    print(
                        f"  ROLLBACK: flush norm tripped the divergence "
                        f"guard — restored update {good} (buffer drained)"
                    )
                else:
                    print(
                        "  divergence guard tripped but no good checkpoint "
                        "exists yet — continuing without rollback"
                    )
        if logger:
            logger.log(row)
        if ckpt:
            if rs is not None and (not tripped or rolled_back):
                # pre-checkpoint mark (same discipline as the sync path): the
                # manifest's last_good points at this update, valid exactly
                # when this checkpoint commits
                rs.mark_good(u)
            # the CANONICAL aggregator checkpoint: buffer lanes, the residual
            # store, the K in-flight params snapshots (state pytree) plus the
            # dispatch cursor / per-slot finish-time+version tags (manifest) —
            # everything `--resume` needs to replay the run bitwise
            tree, agg_manifest = driver.checkpoint()
            ckpt.save_server(
                u, tree,
                extra={"args": vars(args), "aggregator": agg_manifest,
                       "train": {"deltas_admitted": deltas_admitted[0]},
                       "sim_time": row["sim_time"]},
            )
            # the cursor source of truth differs by runtime: inproc mutates the
            # stream objects directly; sockets commits returned cursors into
            # the backend in event order
            cursors = (
                backend.snapshot_stream_states() if backend is not None
                else [streams[ci].state_dict() for ci in range(args.population)]
            )
            for ci, cur in enumerate(cursors):
                ckpt.save_client(u, ci, cur)

    try:
        if args.rounds > start_update:
            driver.run_updates(args.rounds - start_update, on_update=on_update)
        else:
            print(f"nothing to do: checkpoint already at update {start_update - 1} "
                  f"of {args.rounds}")
    finally:
        driver.finalize_trace()  # close in-flight dispatch spans (no-op untraced)
        if backend is not None:
            backend.close(linger=1.0)  # let workers pull the "done" answer
        if metrics_srv is not None:
            metrics_srv.close()
        if tracer is not None:
            tracer.close()
    return {"history": history, "state": driver.state, "model": model,
            "config": cfg, "driver": driver}


def main() -> None:
    run(parse_args())


if __name__ == "__main__":
    main()
