"""Automatic micro-batch sizing (paper §6.2).

The paper binary-searches powers of two on real GPUs until OOM; on TPU, memory is
static after compile, so we *estimate* from the model's memory model and then verify
the chosen size against ``compiled.memory_analysis()`` — a compile-time "OOM check"
rather than a runtime one.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig

TPU_V5E_HBM = 16 * 1024**3


def activation_bytes_per_token(cfg: ModelConfig) -> float:
    """Rough per-token activation residency during one remat'd train step."""
    d = cfg.d_model
    per_layer_carry = 2 * d  # bf16 residual stream saved per layer
    # remat working set ~ a few layer-widths; attention adds the chunked score block
    working = 12 * d
    return cfg.n_layers * per_layer_carry + working


def estimate_micro_batch(
    cfg: ModelConfig,
    seq_len: int,
    *,
    hbm_bytes: int = TPU_V5E_HBM,
    model_parallel: int = 16,
    param_bytes_per_param: float = 4.0,
    opt_copies: float = 4.0,  # params + m + v + pseudo-grad/momentum
) -> int:
    """Largest power-of-two micro-batch expected to fit; >=1."""
    params_per_dev = cfg.param_count() / model_parallel
    fixed = params_per_dev * param_bytes_per_param * opt_copies
    budget = hbm_bytes * 0.9 - fixed
    if budget <= 0:
        return 0
    per_seq = activation_bytes_per_token(cfg) * seq_len
    n = int(budget // per_seq)
    mb = 1
    while mb * 2 <= n:
        mb *= 2
    return mb if n >= 1 else 0


def verify_micro_batch(compiled, hbm_bytes: int = TPU_V5E_HBM) -> bool:
    """Compile-time OOM check from memory_analysis()."""
    mem = compiled.memory_analysis()
    total = (
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes
    )
    return total <= hbm_bytes
