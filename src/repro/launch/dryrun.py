import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)).

Lowers + compiles every (architecture × input shape) against the production meshes —
single-pod (16, 16) = 256 chips and multi-pod (2, 16, 16) = 512 chips — on 512
placeholder host devices, printing memory_analysis() (proves it fits) and
cost_analysis() (FLOPs/bytes for §Roofline), plus the HLO collective traffic.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch assigned --shape all --multi-pod both
"""
import argparse
import json
import time
import traceback


def main() -> None:
    from repro.core.compression import UPLINK_SCHEMES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="assigned", help="arch id | 'assigned' | comma list")
    ap.add_argument("--shape", default="all", help="shape name | 'all' | comma list")
    ap.add_argument("--multi-pod", default="no", choices=["no", "yes", "both"])
    ap.add_argument("--tau-lowered", type=int, default=4)
    ap.add_argument("--train-mode", default="federated", choices=["federated", "centralized", "both"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-elastic", action="store_true",
                    help="drop the (C,) participation-weight input from the "
                         "federated round (legacy flat-mean lowering)")
    ap.add_argument("--pseudo-grad-dtype", default="float32")
    ap.add_argument("--uplink", default="float32",
                    choices=list(UPLINK_SCHEMES),
                    help="compressed-uplink codec for the federated round: the "
                         "encoded-delta dtypes are carried through the mesh "
                         "lowering (residual inputs sharded like the client axis)")
    ap.add_argument("--topk-fraction", type=float, default=0.05)
    ap.add_argument("--partial-progress", action="store_true",
                    help="thread the (C,) straggler partial-progress τ-mask "
                         "through the federated round (replicated int32 input "
                         "consumed inside the scan — shardings unperturbed)")
    ap.add_argument("--fused-server", action="store_true",
                    help="request the fused flat-buffer server phase "
                         "(kernels/fedcore). On multi-device meshes the GSPMD "
                         "lowering keeps the reference phase (the fused kernel "
                         "is the aggregator-host path), so this asserts the "
                         "flag cannot perturb shardings or footprint")
    ap.add_argument("--cohort-tile", type=int, default=None,
                    help="lower the federated step as ONE TILE of a streamed "
                         "cohort (run_client_tile, client width = tile): the "
                         "population/cohort sizes never enter the lowering, "
                         "so per-device memory is flat in P (asserted by the "
                         "slow dry-run test)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="", help="suffix for result filenames (perf iters)")
    args = ap.parse_args()

    import jax

    from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step
    from repro.roofline.analysis import analyze_compiled

    archs = ASSIGNED_ARCHS if args.arch == "assigned" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, why = cfg.supports_shape(shape_name)
            if not ok:
                print(f"SKIP  {arch} x {shape_name}: {why}")
                continue
            modes = ["federated"]
            if INPUT_SHAPES[shape_name].kind == "train":
                modes = {
                    "federated": ["federated"],
                    "centralized": ["centralized"],
                    "both": ["federated", "centralized"],
                }[args.train_mode]
            else:
                modes = [None]
            for multi_pod in pods:
                mesh = make_production_mesh(multi_pod=multi_pod)
                chips = mesh.size
                for mode in modes:
                    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
                    if mode:
                        tag += f"__{mode}"
                    if args.tag:
                        tag += f"__{args.tag}"
                    t0 = time.perf_counter()
                    try:
                        kw = {}
                        if INPUT_SHAPES[shape_name].kind == "train":
                            kw = dict(
                                tau_lowered=args.tau_lowered,
                                remat=not args.no_remat,
                                mode=mode,
                                pseudo_grad_dtype=args.pseudo_grad_dtype,
                                elastic=not args.no_elastic,
                                uplink=args.uplink,
                                topk_fraction=args.topk_fraction,
                                partial_progress=args.partial_progress,
                                fused_server=args.fused_server,
                                cohort_tile=args.cohort_tile,
                            )
                        with mesh:
                            step = build_step(cfg, shape_name, mesh, **kw)
                            lowered = step.fn.lower(*step.args)
                            compiled = lowered.compile()
                            mem = compiled.memory_analysis()
                            print(f"== {tag} ==")
                            print(f"  memory_analysis: {mem}")
                            cost = compiled.cost_analysis()
                            print(
                                "  cost_analysis: flops=%.3e bytes=%.3e"
                                % (cost.get("flops", 0), cost.get("bytes accessed", 0))
                            )
                            report = analyze_compiled(
                                tag, compiled, chips, model_flops=step.model_flops,
                                extra={"meta": step.meta, "arch": arch,
                                       "shape": shape_name, "multi_pod": multi_pod,
                                       "mode": mode or "serve",
                                       "compile_s": time.perf_counter() - t0},
                            )
                            print(
                                "  roofline: compute=%.4fs memory=%.4fs collective=%.4fs -> %s"
                                % (report.t_compute, report.t_memory,
                                   report.t_collective, report.bottleneck)
                            )
                            print(f"  collectives: {report.collective_counts}")
                            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                                json.dump(report.to_dict(), f, indent=2, default=str)
                    except Exception:
                        n_fail += 1
                        print(f"FAIL  {tag}")
                        traceback.print_exc()
                    finally:
                        print(f"  [{time.perf_counter() - t0:.1f}s]", flush=True)

    print(f"\ndone; failures: {n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
