"""Step builders shared by the dry-run and the real launcher.

For every (architecture × input shape × mesh) this module produces:
  - the step function (federated round / centralized step / prefill / decode),
  - abstract inputs (`jax.ShapeDtypeStruct` with NamedSharding attached — no
    allocation), the `input_specs()` contract of deliverable (e).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.core import (
    FederatedConfig,
    InnerOptConfig,
    OuterOptConfig,
    centralized_step,
    federated_round,
    get_codec,
    init_uplink_residuals,
    run_client_tile,
)
from repro.core.outer_opt import init_outer_state
from repro.models import build_model
from repro.sharding import specs as sh


def _sds(shape, dtype, mesh: Mesh, pspec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, pspec))


def _tree_sds(shape_tree, sharding_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s, p: _sds(s.shape, s.dtype, mesh, p),
        shape_tree,
        sharding_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


# ---------------------------------------------------------------------------
# Abstract parameter / state trees
# ---------------------------------------------------------------------------


def abstract_params(model, mesh: Mesh, fsdp_axes: Tuple[str, ...] = (), dtype=None):
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), dtype=dtype))
    pspecs = sh.params_pspecs(mesh, model.axes(), model.shapes(), fsdp_axes)
    return _tree_sds(shapes, pspecs, mesh), pspecs


def _serve_fsdp_axes(cfg: ModelConfig, mesh: Mesh) -> Tuple[str, ...]:
    """Weight-gathered serving for models whose bf16 weights overflow one
    model-parallel slice (>20B params): shard params over the batch axes too."""
    return sh.client_axes(mesh) if cfg.param_count() > 20e9 else ()


def abstract_fed_state(model, mesh: Mesh, fed: FederatedConfig, fsdp_axes: Tuple[str, ...] = ()):
    params_sds, pspecs = abstract_params(model, mesh, fsdp_axes)

    outer_shapes = jax.eval_shape(
        lambda: init_outer_state(fed.outer, model.init(jax.random.PRNGKey(0)))
    )

    # outer state subtrees that mirror params get params' specs; scalars replicate
    outer_sds = {}
    for key, val in outer_shapes.items():
        if key == "round":
            outer_sds[key] = _sds((), jnp.int32, mesh, P())
        else:
            outer_sds[key] = _tree_sds(val, pspecs, mesh)

    state = {
        "params": params_sds,
        "outer": outer_sds,
        "round": _sds((), jnp.int32, mesh, P()),
        "rng": _sds((2,), jnp.uint32, mesh, P()),
    }
    return state, pspecs


# ---------------------------------------------------------------------------
# input_specs() — deliverable (e)
# ---------------------------------------------------------------------------


def input_specs(
    cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh,
    *,
    tau_lowered: int = 4,
    mode: str = "federated",  # 'federated' | 'centralized' (train shapes only)
) -> Dict[str, Any]:
    """Abstract model inputs (ShapeDtypeStruct; weak-type-correct, shardable, zero
    allocation) for the given input shape.

    Training batches are PRE-SPLIT into micro-batches: federated
    (τ, C, grad_accum, B_micro, ...) with the client dim over the client axes and the
    micro-batch dim over the within-client FSDP/DDP axes (reshaping a sharded batch
    dim inside jit breaks GSPMD propagation); centralized (grad_accum, B_micro, ...).
    """
    ca = sh.client_axes(mesh)
    if shape.kind == "train":
        client_ax, fsdp_ax, C = sh.choose_client_mapping(mesh, cfg.param_count())
        b_loc = shape.global_batch // C
        import numpy as _np

        fsdp_div = int(_np.prod([mesh.shape[a] for a in fsdp_ax])) if fsdp_ax else 1
        ga = default_grad_accum(b_loc, shape.seq_len, fsdp_div,
                                target_tokens=_target_tokens(cfg))
        b_mb = b_loc // ga
        if mode == "federated":
            cspec = client_ax if client_ax else None
            bspec = fsdp_ax if fsdp_ax else None
            toks = _sds(
                (tau_lowered, C, ga, b_mb, shape.seq_len), jnp.int32, mesh,
                P(None, cspec, None, bspec, None),
            )
            out = {"tokens": toks}
            if cfg.enc_dec:
                out["audio_embed"] = _sds(
                    (tau_lowered, C, ga, b_mb, cfg.n_audio_frames, cfg.d_model),
                    jnp.bfloat16, mesh, P(None, cspec, None, bspec, None, None),
                )
            return out
        else:  # centralized per-step batch, micro-batches pre-split
            ga_c = default_grad_accum(
                shape.global_batch, shape.seq_len,
                fsdp_div=mesh.size // mesh.shape["model"],
                target_tokens=_target_tokens(cfg),
            )
            b_mb = shape.global_batch // ga_c
            toks = _sds((ga_c, b_mb, shape.seq_len), jnp.int32, mesh, P(None, ca, None))
            out = {"tokens": toks}
            if cfg.enc_dec:
                out["audio_embed"] = _sds(
                    (ga_c, b_mb, cfg.n_audio_frames, cfg.d_model),
                    jnp.bfloat16, mesh, P(None, ca, None, None),
                )
            return out

    if shape.kind == "prefill":
        bspec = ca if shape.global_batch >= sh.n_clients(mesh) else None
        out = {
            "tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32, mesh, P(bspec, None))
        }
        if cfg.enc_dec:
            out["audio_embed"] = _sds(
                (shape.global_batch, cfg.n_audio_frames, cfg.d_model),
                jnp.bfloat16, mesh, P(bspec, None, None),
            )
        return out

    if shape.kind == "decode":
        bspec = ca if shape.global_batch >= sh.n_clients(mesh) else None
        return {
            "tokens": _sds((shape.global_batch, 1), jnp.int32, mesh, P(bspec, None)),
            "cache_index": _sds((), jnp.int32, mesh, P()),
        }
    raise ValueError(shape.kind)


def abstract_cache(cfg: ModelConfig, shape: InputShape, mesh: Mesh, model=None):
    """Abstract KV/SSM cache with serving shardings (sequence-sharded KV)."""
    model = model or build_model(cfg)
    long_ctx = shape.seq_len > 100_000
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, jnp.bfloat16)
    )

    base_ndim = {"kv": 4, "conv": 3, "ssd": 4, "cross": 4}

    def leaf_spec(path, leaf):
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        name = keys[-1]
        if name in ("k", "v"):
            kind = "cross" if "cross" in keys else "kv"
        else:
            kind = name  # 'conv' | 'ssd'
        extra = leaf.ndim - base_ndim[kind]
        core = sh.decode_cache_pspec(mesh, leaf.shape[extra:], kind, long_ctx)
        return _sds(leaf.shape, leaf.dtype, mesh, P(*([None] * extra), *core))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


@dataclass
class BuiltStep:
    name: str
    fn: Callable  # jit-wrapped
    args: Tuple  # abstract args (lower(*args))
    model_flops: float  # 6·N_active·D equivalent for §Roofline
    meta: Dict[str, Any]


def default_fed_config(C: int, tau_lowered: int, grad_accum: int = 1) -> FederatedConfig:
    return FederatedConfig(
        clients_per_round=C,
        local_steps=tau_lowered,
        inner=InnerOptConfig(lr_max=3e-4, total_steps=60_000),
        outer=OuterOptConfig(name="fedmom", lr=0.7, momentum=0.9),
        grad_accum=grad_accum,
    )


def _target_tokens(cfg: ModelConfig) -> int:
    """Per-device tokens per micro-batch: activation carries scale with the model's
    widest live buffer (d_model; or the MoE expert dispatch width), so wide models
    get smaller micro-batches."""
    width = max(cfg.d_model, (cfg.moe_d_ff or 0) // 2)
    if cfg.n_heads % 16:
        # head_dim-fallback sharding replicates score blocks across the model axis;
        # scale micro-batches down to compensate (whisper 20H, coder 56H, llama4 40H)
        width *= 4
    return max(4096, 16_384 * 2048 // width)


def default_grad_accum(
    b_loc: int, seq_len: int, fsdp_div: int = 1, target_tokens: int = 16_384
) -> int:
    """Micro-batches per local step so one micro-batch is ~target_tokens per DEVICE of
    the within-client group, with the micro-batch divisible by the FSDP width."""
    rows_per_dev = max(1, target_tokens // seq_len)
    b_mb = min(b_loc, max(1, fsdp_div) * rows_per_dev)
    ga = max(1, b_loc // b_mb)
    while ga > 1 and (b_loc % ga or (b_loc // ga) % max(1, fsdp_div)):
        ga -= 1
    return ga


def build_train_step(
    cfg: ModelConfig,
    shape: InputShape,
    mesh: Mesh,
    *,
    tau_lowered: int = 4,
    remat: bool = True,
    mode: str = "federated",
    fed: Optional[FederatedConfig] = None,
    pseudo_grad_dtype: str = "float32",
    elastic: bool = True,
    uplink: str = "float32",
    topk_fraction: float = 0.05,
    partial_progress: bool = False,
    fused_server: bool = False,
    cohort_tile: Optional[int] = None,
) -> BuiltStep:
    model = build_model(cfg)
    loss_fn = lambda p, b: model.loss(p, b, remat=remat)

    if mode == "federated":
        client_ax, fsdp_ax, C = sh.choose_client_mapping(mesh, cfg.param_count())
        b_loc = shape.global_batch // C
        import numpy as _np

        fsdp_div = int(_np.prod([mesh.shape[a] for a in fsdp_ax])) if fsdp_ax else 1
        ga = default_grad_accum(b_loc, shape.seq_len, fsdp_div,
                                target_tokens=_target_tokens(cfg))
        fed = fed or default_fed_config(C, tau_lowered, ga)
        from dataclasses import replace

        fed = replace(fed, pre_split_micro=True)
        if pseudo_grad_dtype != "float32":
            fed = replace(fed, pseudo_grad_dtype=pseudo_grad_dtype)
        state, pspecs = abstract_fed_state(model, mesh, fed, fsdp_ax)
        client_pspecs = sh.clientize_tree(mesh, pspecs, client_ax)

        def shard_clients(tree):
            return jax.lax.with_sharding_constraint(
                tree,
                jax.tree_util.tree_map(
                    lambda p: NamedSharding(mesh, p), client_pspecs,
                    is_leaf=lambda x: isinstance(x, P),
                ),
            )

        # the fused flat-buffer server phase (kernels/fedcore) is the
        # aggregator-host path: it consumes the whole (C, N) delta buffer in one
        # kernel, which cannot span a GSPMD-sharded client axis. On multi-device
        # meshes the flag therefore keeps the reference server phase — by
        # construction the lowering, shardings and memory footprint are
        # identical with or without --fused-server (the dry-run smoke asserts
        # it); only single-device lowerings swap the fused pass in.
        fused_active = fused_server and mesh.size == 1
        codec = (
            get_codec(uplink, topk_fraction, fused=fused_active)
            if uplink != "float32" else None
        )
        stateful = codec is not None and codec.stateful
        if (stateful or partial_progress) and not elastic:
            raise ValueError(
                "stateful uplink codecs and partial progress require the "
                "elastic round"
            )
        apply_fn = None
        if fused_active:
            from repro.kernels.fedcore import fused_apply_aggregate

            apply_fn = fused_apply_aggregate
        batches = input_specs(cfg, shape, mesh, tau_lowered=tau_lowered, mode="federated")

        if cohort_tile is not None:
            # streamed-cohort lowering: the compiled unit is ONE TILE of the
            # round (run_client_tile), client width = cohort_tile. The host
            # loop replays it over every tile and folds the weighted partial
            # sums (docs/aggregation.md), so per-device memory is bounded by
            # the tile — the population P and the cohort C never enter the
            # lowering at all. The tile's client dim shards over the same
            # client axes as the flat round.
            if not elastic:
                raise ValueError("cohort tiling requires the elastic round: "
                                 "pad slots ride as zero-weight clients")
            if fused_server:
                raise ValueError(
                    "--fused-server consumes the full (C, N) delta buffer "
                    "with pre-normalized weights, not the tiled partial-sum "
                    "layout"
                )
            client_width = int(
                _np.prod([mesh.shape[a] for a in client_ax])
            ) if client_ax else 1
            if cohort_tile % client_width:
                raise ValueError(
                    f"cohort_tile={cohort_tile} must be a multiple of the "
                    f"mesh client-axis width {client_width} (axes "
                    f"{list(client_ax)}): jit inputs reject uneven GSPMD "
                    f"padding on the sharded client dim"
                )
            fed_tile = replace(fed, clients_per_round=cohort_tile)

            def _retile(sds):
                return jax.ShapeDtypeStruct(
                    (sds.shape[0], cohort_tile) + sds.shape[2:],
                    sds.dtype, sharding=sds.sharding,
                )

            batches = jax.tree_util.tree_map(
                _retile, batches,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
            # run_client_tile reads only the params/round/rng lanes; the outer
            # optimizer state stays host-side with apply_aggregate_partial
            tile_state = {k: state[k] for k in ("params", "round", "rng")}
            args = (tile_state, batches,
                    _sds((cohort_tile,), jnp.float32, mesh, P()))
            arg_names = ["client_weights"]
            if stateful:
                res_shapes = jax.eval_shape(
                    lambda: init_uplink_residuals(
                        codec, model.init(jax.random.PRNGKey(0)), cohort_tile
                    )
                )
                args = args + (_tree_sds(res_shapes, client_pspecs, mesh),)
                arg_names.append("residuals")
            if partial_progress:
                args = args + (_sds((cohort_tile,), jnp.int32, mesh, P()),)
                arg_names.append("tau_steps")

            def _tile(s, b, w, *rest):
                kw = dict(zip(arg_names[1:], rest))
                return run_client_tile(
                    loss_fn, fed_tile, s, b, w,
                    shard_clients=shard_clients, codec=codec, **kw,
                )

            # the server state is NOT donated (every tile of the round reads
            # the same params snapshot); the tile's residual rows are replaced
            # wholesale, so they are
            donate = ()
            if "residuals" in arg_names:
                donate = (2 + arg_names.index("residuals"),)
            step = jax.jit(_tile, donate_argnums=donate)
            tokens_per_tile = tau_lowered * cohort_tile * (
                shape.global_batch // C) * shape.seq_len
            mf = 6.0 * cfg.active_param_count() * tokens_per_tile
            return BuiltStep(
                name=f"{cfg.name}:{shape.name}:federated-tile",
                fn=step,
                args=args,
                model_flops=mf,
                meta={
                    "tau_lowered": tau_lowered,
                    "tokens_per_call": tokens_per_tile,
                    "clients": cohort_tile,
                    "cohort_tile": cohort_tile,
                    "grad_accum": ga,
                    "client_axes": list(client_ax),
                    "fsdp_axes": list(fsdp_ax),
                    "elastic": elastic,
                    "uplink": uplink,
                    "partial_progress": partial_progress,
                    "fused_server": False,
                    "fused_server_requested": fused_server,
                },
            )
        # elastic participation on the mesh: the (C,) weight vector enters the
        # jitted round as a replicated traced input — dropouts / stragglers /
        # K_eff < C on the production mesh never trigger a recompile, exactly
        # like the CPU driver. All-ones weights are bitwise the flat round.
        # The partial-progress τ-mask rides the same way: a replicated (C,)
        # int32 input consumed inside the scan, so per-round realized step
        # counts change freely without perturbing any sharding.
        args = (state, batches)
        arg_names = []
        if elastic:
            args = args + (_sds((C,), jnp.float32, mesh, P()),)
            arg_names.append("client_weights")
        if stateful:
            # per-client error-feedback residuals ride the mesh exactly like the
            # (C, ...) client-axis params replicas: same clientized pspecs, so
            # the encoded-uplink round cannot perturb the parameter shardings
            res_shapes = jax.eval_shape(
                lambda: init_uplink_residuals(
                    codec, model.init(jax.random.PRNGKey(0)), C
                )
            )
            args = args + (_tree_sds(res_shapes, client_pspecs, mesh),)
            arg_names.append("residuals")
        if partial_progress:
            args = args + (_sds((C,), jnp.int32, mesh, P()),)
            arg_names.append("tau_steps")

        def _round(s, b, *rest):
            kw = dict(zip(arg_names, rest))
            return federated_round(
                loss_fn, fed, s, b, shard_clients=shard_clients, codec=codec,
                apply_fn=apply_fn, **kw,
            )

        # donate the server state (params + outer lanes + rng) and, when
        # present, the cohort residual rows: both are replaced wholesale every
        # round, so the round stops double-buffering its params-sized arrays
        donate = (0,)
        if "residuals" in arg_names:
            donate = donate + (2 + arg_names.index("residuals"),)
        step = jax.jit(_round, donate_argnums=donate)
        tokens_per_round = tau_lowered * shape.global_batch * shape.seq_len
        mf = 6.0 * cfg.active_param_count() * tokens_per_round
        return BuiltStep(
            name=f"{cfg.name}:{shape.name}:federated",
            fn=step,
            args=args,
            model_flops=mf,
            meta={
                "tau_lowered": tau_lowered,
                "tokens_per_call": tokens_per_round,
                "clients": C,
                "grad_accum": ga,
                "client_axes": list(client_ax),
                "fsdp_axes": list(fsdp_ax),
                "elastic": elastic,
                "uplink": uplink,
                "partial_progress": partial_progress,
                "fused_server": fused_active,
                "fused_server_requested": fused_server,
            },
        )

    # centralized baseline: per-step gradient sync (the paper's comparison).
    # Big models ZeRO-shard params+optimizer over the batch axes (standard FSDP).
    inner = InnerOptConfig(lr_max=3e-4, total_steps=60_000)
    cen_fsdp = (
        sh.client_axes(mesh)
        if cfg.param_count() * 12 > 0.55 * 16 * (1 << 30) * mesh.shape["model"]
        else ()
    )
    params_sds, pspecs = abstract_params(model, mesh, cen_fsdp)
    abs_p = model.abstract_params()
    state = {
        "params": params_sds,
        "inner": {
            "m": _tree_sds(abs_p, pspecs, mesh),
            "v": _tree_sds(abs_p, pspecs, mesh),
            "count": _sds((), jnp.int32, mesh, P()),
        },
        "step": _sds((), jnp.int32, mesh, P()),
    }
    ga_c = default_grad_accum(
        shape.global_batch, shape.seq_len, fsdp_div=mesh.size // mesh.shape["model"],
        target_tokens=_target_tokens(cfg),
    )
    step = jax.jit(
        functools.partial(centralized_step, loss_fn, inner, grad_accum=ga_c, pre_split=True)
    )
    batch = input_specs(cfg, shape, mesh, mode="centralized")
    tokens = shape.global_batch * shape.seq_len
    mf = 6.0 * cfg.active_param_count() * tokens
    return BuiltStep(
        name=f"{cfg.name}:{shape.name}:centralized",
        fn=step,
        args=(state, batch),
        model_flops=mf,
        meta={"tokens_per_call": tokens, "grad_accum": ga_c, "fsdp_axes": list(cen_fsdp)},
    )


def build_prefill_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> BuiltStep:
    model = build_model(cfg)
    params_sds, _ = abstract_params(model, mesh, _serve_fsdp_axes(cfg, mesh), dtype=jnp.bfloat16)
    step = jax.jit(lambda p, b: model.prefill(p, b))
    batch = input_specs(cfg, shape, mesh)
    tokens = shape.global_batch * shape.seq_len
    mf = 2.0 * cfg.active_param_count() * tokens
    return BuiltStep(
        name=f"{cfg.name}:{shape.name}:prefill",
        fn=step,
        args=(params_sds, batch),
        model_flops=mf,
        meta={"tokens_per_call": tokens},
    )


def build_decode_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> BuiltStep:
    model = build_model(cfg)
    params_sds, _ = abstract_params(model, mesh, _serve_fsdp_axes(cfg, mesh), dtype=jnp.bfloat16)
    cache_sds = abstract_cache(cfg, shape, mesh, model)
    inputs = input_specs(cfg, shape, mesh)

    def serve_step(params, cache, tokens, cache_index):
        return model.decode_step(params, cache, tokens, cache_index)

    step = jax.jit(serve_step, donate_argnums=(1,))
    tokens = shape.global_batch  # one new token per sequence
    mf = 2.0 * cfg.active_param_count() * tokens
    return BuiltStep(
        name=f"{cfg.name}:{shape.name}:decode",
        fn=step,
        args=(params_sds, cache_sds, inputs["tokens"], inputs["cache_index"]),
        model_flops=mf,
        meta={"tokens_per_call": tokens, "kv_len": shape.seq_len},
    )


def build_step(cfg: ModelConfig, shape_name: str, mesh: Mesh, **kw) -> BuiltStep:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh)
    return build_decode_step(cfg, shape, mesh)
