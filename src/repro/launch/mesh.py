"""Production mesh construction (deliverable (e)).

A FUNCTION, not a module-level constant, so importing this module never touches jax
device state. Single pod: (data=16, model=16) = 256 chips; multi-pod: 2 pods = 512.
In Photon terms: 'model' is the within-client model-parallel group, ('pod','data')
indexes federated clients, and the 'pod' axis is the hierarchical-aggregation boundary
(client islands → server), matching Algorithm 1's two-level scheme.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Degenerate mesh for single-host simulation/tests."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
