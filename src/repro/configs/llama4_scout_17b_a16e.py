"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]

Early-fusion multimodality enters through the shared token vocabulary (like chameleon);
the vision encoder is out of scope (text backbone per assignment).
"""
from repro.configs.base import ModelConfig, register

LLAMA4_SCOUT = register(
    ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202_048,
        n_experts=16,
        n_shared_experts=1,  # llama4 uses a shared expert alongside top-1 routing
        moe_top_k=1,
        moe_d_ff=8192,
        pos_embedding="rope",
        rope_theta=500_000.0,
        tie_embeddings=False,
    )
)
