"""chameleon-34b [vlm] — early-fusion, VQ image tokens share the text vocabulary; the
VQ-GAN image tokenizer is STUBBED (inputs are plain token ids). [arXiv:2405.09818]"""
from repro.configs.base import ModelConfig, register

CHAMELEON_34B = register(
    ModelConfig(
        name="chameleon-34b",
        family="vlm",
        source="arXiv:2405.09818",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22_016,
        vocab_size=65_536,
        qk_norm=True,  # chameleon's QK-norm is central to its training stability
        pos_embedding="rope",
        tie_embeddings=False,
        norm="layernorm",  # chameleon uses (swin-style) layernorm placement
    )
)
