"""The paper's own model family (Table 2): MPT-style decoder transformers with ALiBi.

75M, 125M, 350M, 1.3B, 3B, 7B — used by the benchmarks that reproduce the paper's
figures, and as --arch selectable configs like the assigned pool.
"""
from repro.configs.base import ModelConfig, register

_COMMON = dict(
    family="dense",
    source="Photon paper Table 2 (MPT-style, ALiBi, vocab 50368 [gpt-neox-20b tokenizer])",
    n_kv_heads=-1,  # filled below: MPT uses MHA
    vocab_size=50_368,
    pos_embedding="alibi",
    norm="layernorm",
    activation="gelu",
    tie_embeddings=True,
    max_seq_len=2048,
)


def _photon(name, n_layers, d_model, n_heads, seq_len):
    kw = dict(_COMMON)
    kw.update(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads,
        d_ff=4 * d_model,
        max_seq_len=seq_len,
    )
    return register(ModelConfig(name=name, **kw))


PHOTON_75M = _photon("photon-75m", 3, 896, 16, 1024)
PHOTON_125M = _photon("photon-125m", 12, 768, 12, 2048)
PHOTON_350M = _photon("photon-350m", 24, 1024, 16, 2048)
PHOTON_1_3B = _photon("photon-1.3b", 24, 2048, 16, 2048)
PHOTON_3B = _photon("photon-3b", 32, 2560, 20, 2048)
PHOTON_7B = _photon("photon-7b", 32, 4096, 32, 2048)
