"""Configuration system for the Photon reproduction.

Every architecture (the 10 assigned ones plus the paper's own Photon/MPT models) is a
``ModelConfig``. Configs are plain frozen dataclasses registered by id; the launcher
selects them with ``--arch <id>``.

Layer heterogeneity (hybrid attention/SSM interleave, sliding-window patterns, MoE
placement) is described declaratively via ``layer_kinds()`` which returns one
``LayerKind`` per depth index; the transformer engine groups equal-signature layers into
``lax.scan`` stacks automatically.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned; see system spec)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Per-layer kind descriptor
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerKind:
    """Static description of one layer's structure.

    ``mixer``:  'attn' | 'ssm'
    ``ffn``:    'dense' | 'moe' | 'none'   ('none' for mamba2-style pure-SSM blocks)
    ``window``: attention window (None = full causal). A *value* (not structure):
                layers that differ only in window share a scan stack and receive the
                window as per-layer scanned data.
    """

    mixer: str = "attn"
    ffn: str = "dense"
    window: Optional[int] = None
    cross_attn: bool = False  # decoder layers of enc-dec models

    @property
    def signature(self) -> Tuple:
        """Stacking signature: layers with equal signature share parameters shapes
        and can be stacked into one lax.scan. ``window`` deliberately excluded."""
        return (self.mixer, self.ffn, self.cross_attn)


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'audio' | 'vlm'
    source: str  # citation for the config numbers

    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 50_368
    head_dim: Optional[int] = None  # default: d_model // n_heads

    # --- attention options ------------------------------------------------
    pos_embedding: str = "rope"  # 'rope' | 'alibi' | 'learned' | 'none'
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    sliding_window: Optional[int] = None  # window size for local layers
    global_attn_every: Optional[int] = None  # e.g. 6 -> gemma3 5:1 local:global
    tie_embeddings: bool = True
    max_seq_len: int = 131_072  # for 'learned' positions / ALiBi cap

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: Optional[int] = None  # expert hidden dim (fine-grained MoE)
    moe_every: int = 1  # MoE at layers where (idx % moe_every == moe_offset)
    moe_offset: int = 0
    first_layer_dense: bool = False  # deepseek-moe: layer 0 keeps a dense FFN
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0  # d_state; 0 -> arch has no SSM layers
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_n_groups: int = 1
    ssm_chunk: int = 64  # SSD chunk length

    # --- hybrid pattern -------------------------------------------------------
    # repeating mixer pattern, e.g. jamba: 'MMMAMMMM' (A=attn, M=mamba). None => uniform.
    hybrid_pattern: Optional[str] = None

    # --- encoder/decoder (audio) ----------------------------------------------
    enc_dec: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500  # whisper frame count after conv frontend (stubbed)

    # --- numerics / norm ------------------------------------------------------
    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    activation: str = "silu"  # 'silu' | 'gelu'
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    z_loss: float = 1e-4

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the 'vocab' axis shards evenly (Megatron-style
        padding); logits are sliced back to vocab_size before the loss."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    # ------------------------------------------------------------------
    def layer_kinds(self) -> List[LayerKind]:
        """One LayerKind per decoder layer index."""
        kinds: List[LayerKind] = []
        for i in range(self.n_layers):
            # mixer
            if self.family == "ssm":
                mixer = "ssm"
            elif self.hybrid_pattern:
                mixer = "attn" if self.hybrid_pattern[i % len(self.hybrid_pattern)] == "A" else "ssm"
            else:
                mixer = "attn"
            # ffn
            if self.family == "ssm":
                ffn = "none"  # mamba2 blocks carry no separate FFN
            elif self.is_moe:
                if self.first_layer_dense and i == 0:
                    ffn = "dense"
                elif i % self.moe_every == self.moe_offset:
                    ffn = "moe"
                else:
                    ffn = "dense"
            else:
                ffn = "dense"
            # attention window
            window: Optional[int] = None
            if mixer == "attn" and self.sliding_window is not None:
                if self.global_attn_every:
                    is_global = (i + 1) % self.global_attn_every == 0
                    window = None if is_global else self.sliding_window
                else:
                    window = self.sliding_window
            kinds.append(
                LayerKind(mixer=mixer, ffn=ffn, window=window, cross_attn=self.enc_dec)
            )
        return kinds

    def encoder_layer_kinds(self) -> List[LayerKind]:
        return [LayerKind(mixer="attn", ffn="dense") for _ in range(self.n_encoder_layers)]

    # ------------------------------------------------------------------
    def supports_shape(self, shape_name: str) -> Tuple[bool, str]:
        """Whether this arch runs the given input shape (long_500k gating)."""
        shape = INPUT_SHAPES[shape_name]
        if shape.name == "long_500k":
            sub_quadratic = (
                self.family in ("ssm", "hybrid")
                or self.sliding_window is not None
            )
            if not sub_quadratic:
                return False, "full-attention arch: long_500k skipped (see DESIGN.md)"
        if self.enc_dec and shape.name == "long_500k":
            return False, "enc-dec context model caps far below 500k; skipped"
        return True, ""

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks); used for 6ND."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        if self.pos_embedding == "learned":
            total += self.max_seq_len * d

        def attn_params() -> int:
            return d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d

        def dense_ffn(dff: int) -> int:
            return 3 * d * dff if self.activation == "silu" else 2 * d * dff

        def moe_ffn() -> int:
            dff = self.moe_d_ff or self.d_ff
            routed = self.n_experts * 3 * d * dff
            shared = self.n_shared_experts * 3 * d * dff
            router = d * self.n_experts
            return routed + shared + router

        def ssm_params() -> int:
            di, g, ds, nh = self.d_inner, self.ssm_n_groups, self.ssm_state, self.ssm_n_heads
            conv_dim = di + 2 * g * ds
            return (
                d * (2 * di + 2 * g * ds + nh)  # in_proj
                + conv_dim * self.ssm_conv_width  # conv
                + nh * 2  # A_log, dt_bias... (nh + nh)
                + nh  # D
                + di  # gated norm
                + di * d  # out_proj
            )

        for k in self.layer_kinds():
            total += 2 * d  # two norms (approx; ssm blocks have one)
            if k.mixer == "attn":
                total += attn_params()
                if k.cross_attn:
                    total += attn_params() + d
            else:
                total += ssm_params()
            if k.ffn == "dense":
                total += dense_ffn(self.d_ff)
            elif k.ffn == "moe":
                total += moe_ffn()
        for _ in range(self.n_encoder_layers):
            total += 2 * d + attn_params() + dense_ffn(self.d_ff)
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dff = self.moe_d_ff or self.d_ff
        inactive_per_moe_layer = (self.n_experts - self.moe_top_k) * 3 * d * dff
        n_moe_layers = sum(1 for k in self.layer_kinds() if k.ffn == "moe")
        return self.param_count() - n_moe_layers * inactive_per_moe_layer

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers (one hybrid period worth of structure
        collapsed to 2), d_model<=512, <=4 experts."""
        kw = dict(
            n_layers=2,
            d_model=256,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=64,
            d_ff=512,
            vocab_size=512,
            # learned-position archs must still cover the assigned input shapes
            max_seq_len=32_768 if self.pos_embedding == "learned" else 4096,
        )
        if self.is_moe:
            kw.update(n_experts=4, moe_top_k=min(self.moe_top_k, 2), moe_d_ff=128,
                      n_shared_experts=min(self.n_shared_experts, 1))
        if self.ssm_state:
            kw.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=16)
        if self.hybrid_pattern:
            kw.update(hybrid_pattern="MA")  # one mamba + one attn layer
        if self.sliding_window is not None:
            kw.update(sliding_window=32, global_attn_every=2)
        if self.enc_dec:
            kw.update(n_encoder_layers=2, n_audio_frames=16)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config: {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # Import side-effect registration.
    from repro import configs as _  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    from repro import configs as _  # noqa: F401

    return sorted(_REGISTRY)


ASSIGNED_ARCHS = [
    "granite-3-2b",
    "qwen3-1.7b",
    "mamba2-1.3b",
    "jamba-v0.1-52b",
    "deepseek-moe-16b",
    "llama4-scout-17b-a16e",
    "whisper-large-v3",
    "chameleon-34b",
    "deepseek-coder-33b",
    "gemma3-4b",
]
