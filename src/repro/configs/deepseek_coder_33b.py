"""deepseek-coder-33b [dense] — llama-arch. [arXiv:2401.14196]"""
from repro.configs.base import ModelConfig, register

DEEPSEEK_CODER_33B = register(
    ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        source="arXiv:2401.14196",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19_200,
        vocab_size=32_256,
        pos_embedding="rope",
        rope_theta=100_000.0,
        tie_embeddings=False,
    )
)
