"""Config registry: importing this package registers every architecture."""
from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    InputShape,
    LayerKind,
    ModelConfig,
    get_config,
    list_configs,
)

# Registration side effects:
from repro.configs import granite_3_2b  # noqa: F401
from repro.configs import qwen3_1_7b  # noqa: F401
from repro.configs import mamba2_1_3b  # noqa: F401
from repro.configs import jamba_v0_1_52b  # noqa: F401
from repro.configs import deepseek_moe_16b  # noqa: F401
from repro.configs import llama4_scout_17b_a16e  # noqa: F401
from repro.configs import whisper_large_v3  # noqa: F401
from repro.configs import chameleon_34b  # noqa: F401
from repro.configs import deepseek_coder_33b  # noqa: F401
from repro.configs import gemma3_4b  # noqa: F401
from repro.configs import photon  # noqa: F401
