"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887]

Jamba block structure (period 8): attention at position 4 of each period (1:7 ratio),
MoE FFN every other layer (odd positions). We use Mamba2/SSD blocks for the SSM layers
(the original uses Mamba1) for framework uniformity — noted in DESIGN.md §2.
Jamba's SSM uses d_state=16.
"""
from repro.configs.base import ModelConfig, register

JAMBA_V0_1_52B = register(
    ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        source="arXiv:2403.19887",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14_336,
        vocab_size=65_536,
        n_experts=16,
        moe_top_k=2,
        moe_every=2,
        moe_offset=1,
        moe_d_ff=14_336,
        moe_capacity_factor=1.0,  # memory: its 14336-wide experts dominate residency
        hybrid_pattern="MMMMAMMM",
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        pos_embedding="none",  # Jamba uses no explicit positional embeddings
        tie_embeddings=False,
    )
)
