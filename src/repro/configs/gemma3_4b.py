"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt family]"""
from repro.configs.base import ModelConfig, register

GEMMA3_4B = register(
    ModelConfig(
        name="gemma3-4b",
        family="dense",
        source="hf:google/gemma-3-1b-pt",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10_240,
        vocab_size=262_144,
        sliding_window=1024,
        global_attn_every=6,  # layers 6,12,... are global; rest local (5:1)
        qk_norm=True,
        pos_embedding="rope",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        max_seq_len=1_048_576,
    )
)
