"""qwen3-1.7b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig, register

QWEN3_1_7B = register(
    ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        source="hf:Qwen/Qwen3-8B",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=6144,
        vocab_size=151_936,
        qk_norm=True,
        pos_embedding="rope",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )
)
