"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, register

MAMBA2_1_3B = register(
    ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        source="arXiv:2405.21060",
        n_layers=48,
        d_model=2048,
        n_heads=1,  # unused for pure SSM
        n_kv_heads=1,
        d_ff=0,
        vocab_size=50_280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_n_groups=1,
        pos_embedding="none",  # SSM needs no positional encoding
        tie_embeddings=True,
        norm="rmsnorm",
    )
)
