"""whisper-large-v3 [audio] — encoder-decoder transformer backbone; conv/mel frontend
STUBBED (input_specs provides precomputed frame embeddings). [arXiv:2212.04356]

kv=20 == n_heads: whisper uses MHA (no GQA). Learned positions on the decoder.
"""
from repro.configs.base import ModelConfig, register

WHISPER_LARGE_V3 = register(
    ModelConfig(
        name="whisper-large-v3",
        family="audio",
        source="arXiv:2212.04356",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51_866,
        enc_dec=True,
        n_encoder_layers=32,
        n_audio_frames=1500,
        pos_embedding="learned",
        max_seq_len=32_768,  # mechanically extended for the assigned shapes
        norm="layernorm",
        activation="gelu",
        tie_embeddings=True,
    )
)
