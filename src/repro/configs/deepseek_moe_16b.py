"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6, first layer
dense. [arXiv:2401.06066]"""
from repro.configs.base import ModelConfig, register

DEEPSEEK_MOE_16B = register(
    ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        source="arXiv:2401.06066",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408 * 8,  # dense-FFN layers use the standard expansion (10944 in HF; 8x approx)
        vocab_size=102_400,
        n_experts=64,
        n_shared_experts=2,
        moe_top_k=6,
        moe_d_ff=1408,
        first_layer_dense=True,
        pos_embedding="rope",
        tie_embeddings=False,
    )
)
