"""Federated training monitors (§6.2): per-round norm tracking (the paper's divergence
leading-indicators), perplexity evaluation, and a lightweight CSV metric logger."""
from __future__ import annotations

import csv
import io
import math
import os
from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def perplexity(loss_ce: float) -> float:
    return float(math.exp(min(30.0, loss_ce)))


# ---------------------------------------------------------------------------
# Elastic-participation monitors (paper §7: partial participation / stragglers)
# ---------------------------------------------------------------------------


def effective_clients(weights) -> int:
    """K_eff: clients with nonzero aggregation weight this round."""
    return int(np.count_nonzero(np.asarray(weights)))


def weight_entropy(weights) -> float:
    """Shannon entropy (nats) of the normalized aggregation weights. log(K) means a
    perfectly balanced round; falling entropy flags domination by few clients (the
    data-size-skew failure mode of FedAvg weighting)."""
    w = np.asarray(weights, np.float64)
    w = w[w > 0]
    if w.size == 0:
        return 0.0
    p = w / w.sum()
    return float(-(p * np.log(p)).sum())


def participation_metrics(plan) -> Dict[str, float]:
    """Flatten a ``ParticipationPlan`` into the per-round logging row. Deliberately
    omits a ``weight_entropy`` key: the jitted round already reports the in-round
    value under that name, and a host-side copy would silently clobber it."""
    return {
        "effective_k": float(plan.effective_k),
        "straggler_count": float(plan.n_stragglers),
        "dropout_count": float(plan.n_dropped),
        "unavailable_count": float(np.asarray(plan.unavailable).sum()),
        "round_time_sim": float(plan.round_time),
    }


def partial_progress_metrics(plan, tau: int) -> Dict[str, float]:
    """Per-round straggler partial-progress monitors (core/aggregator weight
    policy): how much of the requested τ the cohort actually realized, and how
    much compute the deadline-cut baseline would have thrown away.

    - ``partial_tau_mean``: mean realized fraction τ_i/τ over the contributors
      (1.0 = nobody was slowed).
    - ``partial_full_fraction``: fraction of contributors that finished all τ
      steps.
    - ``partial_rescued_clients`` / ``partial_rescued_work``: the clients the
      deadline cut would have dropped entirely, and the client-rounds of
      compute (Σ τ_i/τ) their partial deltas salvage instead.
    - ``partial_wasted_work``: client-rounds still burned this round — clients
      too slow for even one step hold their slot until the deadline
      (deadline·speed ≈ the fraction of a full round they computed for
      nothing), plus the plain deadline-cut waste when partial progress is off.

    Returns ``{}``-compatible zeros when the plan carries no ``local_steps``
    (partial progress disabled), so the logging row stays schema-stable.
    """
    mask = np.asarray(plan.mask)
    speeds_all = np.asarray(plan.speeds, np.float64)
    if plan.local_steps is None:
        # deadline-cut baseline: a cut straggler ran until the deadline (≈ the
        # round time) and every one of those client-rounds was discarded
        cut = np.asarray(plan.stragglers)
        return {
            "partial_tau_mean": 1.0 if mask.any() else 0.0,
            "partial_full_fraction": 1.0 if mask.any() else 0.0,
            "partial_rescued_clients": 0.0,
            "partial_rescued_work": 0.0,
            "partial_wasted_work": float(
                np.minimum(1.0, plan.round_time * speeds_all[cut]).sum()
            ),
        }
    ls = np.asarray(plan.local_steps, np.float64)
    frac = ls[mask] / float(tau)
    rescued = mask & (ls < tau)  # clients the deadline cut would have dropped
    cut = np.asarray(plan.stragglers)  # still dropped: τ_i < 1
    wasted = float(np.minimum(1.0, plan.round_time * speeds_all[cut]).sum())
    return {
        "partial_tau_mean": float(frac.mean()) if mask.any() else 0.0,
        "partial_full_fraction": float((ls[mask] >= tau).mean()) if mask.any() else 0.0,
        "partial_rescued_clients": float(rescued.sum()),
        "partial_rescued_work": float((ls[rescued] / float(tau)).sum()),
        "partial_wasted_work": wasted,
    }


# ---------------------------------------------------------------------------
# Async-aggregation monitors (FedBuff-style buffer, core/async_agg.py)
# ---------------------------------------------------------------------------

# histogram bucket edges for delta staleness (server rounds); last bucket is open
_STALENESS_BUCKETS = ((0, 0), (1, 1), (2, 3), (4, 7), (8, None))


def staleness_stats(staleness: Iterable[float]) -> Dict[str, float]:
    """Per-update staleness summary + histogram of the admitted deltas' ages.

    Buckets (``staleness_hist_*``): exactly-fresh (0), one round late (1), 2–3,
    4–7, and 8+ — a long right tail means the buffer is mostly absorbing ancient
    work and ``max_staleness`` / a larger cohort should be considered.
    """
    s = np.asarray(list(staleness), np.float64)
    out = {
        "staleness_mean": float(s.mean()) if s.size else 0.0,
        "staleness_max": float(s.max()) if s.size else 0.0,
    }
    for lo, hi in _STALENESS_BUCKETS:
        if hi is None:
            out[f"staleness_hist_{lo}p"] = float((s >= lo).sum())
        elif lo == hi:
            out[f"staleness_hist_{lo}"] = float(((s >= lo) & (s <= hi)).sum())
        else:
            out[f"staleness_hist_{lo}_{hi}"] = float(((s >= lo) & (s <= hi)).sum())
    return out


def staleness_hist_counts(staleness: Iterable[float]) -> np.ndarray:
    """Per-bucket counts of admitted-delta staleness, aligned with
    ``_STALENESS_BUCKETS`` (the same buckets ``staleness_stats`` logs and the
    Prometheus endpoint exports) — the cumulative-histogram input the control
    layer's staleness governor reads quantiles from."""
    s = np.asarray(list(staleness), np.float64)
    counts = []
    for lo, hi in _STALENESS_BUCKETS:
        if hi is None:
            counts.append(float((s >= lo).sum()))
        else:
            counts.append(float(((s >= lo) & (s <= hi)).sum()))
    return np.asarray(counts, np.float64)


def histogram_quantile(counts, q: float) -> float:
    """Conservative quantile off the cumulative staleness histogram.

    Returns the UPPER edge of the first bucket whose cumulative count reaches
    ``q * total`` (ties included: a ``q`` landing exactly on a cumulative
    boundary resolves to that bucket). The open-ended last bucket has no finite
    upper edge and reports its LOWER edge instead; an empty histogram is 0.0.
    The possible return values are therefore exactly the bucket edges
    {0, 1, 3, 7, 8} — coarse on purpose: a governor stepping on bucket edges
    cannot chase sub-bucket noise.
    """
    c = np.asarray(counts, np.float64)
    if c.shape[0] != len(_STALENESS_BUCKETS):
        raise ValueError(
            f"expected {len(_STALENESS_BUCKETS)} bucket counts, got {c.shape[0]}"
        )
    total = float(c.sum())
    if total <= 0.0:
        return 0.0
    rank = float(q) * total
    cum = 0.0
    for (lo, hi), n in zip(_STALENESS_BUCKETS, c):
        cum += float(n)
        if cum >= rank:
            return float(hi if hi is not None else lo)
    return float(_STALENESS_BUCKETS[-1][0])  # pragma: no cover — q > 1 guard


def window_mean(rows, key: str, default: float = 0.0) -> float:
    """Mean of ``row[key]`` over the rows of a metrics window that carry the
    key; ``default`` when none do (empty window, or a metric the current
    configuration never emits). Non-finite values are skipped, not averaged:
    a single NaN round metric (a poisoned cohort before the screen engages)
    must not turn every downstream window statistic — and the control loop
    decisions made from them — into NaN forever."""
    vals = [
        float(r[key]) for r in rows
        if r.get(key) is not None and math.isfinite(float(r[key]))
    ]
    if not vals:
        return float(default)
    return float(sum(vals) / len(vals))


def window_concat(rows, key: str) -> List[float]:
    """Concatenate per-row LIST metrics (e.g. ``admitted_staleness``) across a
    metrics window; rows without the key contribute nothing, and non-finite
    elements are dropped (same NaN-propagation discipline as
    :func:`window_mean`)."""
    out: List[float] = []
    for r in rows:
        v = r.get(key)
        if v:
            out.extend(float(x) for x in v if math.isfinite(float(x)))
    return out


def wallclock_speedup(sync_time: float, async_time: float) -> float:
    """Simulated wall-clock speedup of reaching the same point: how much longer
    the deadline-masking sync schedule would have taken than the async buffered
    schedule (> 1.0 means async wins)."""
    return float(sync_time) / max(float(async_time), 1e-12)


# ---------------------------------------------------------------------------
# Compressed-uplink monitors (core/compression.py codecs)
# ---------------------------------------------------------------------------


def uplink_round_metrics(
    scheme: str, params_like, n_uploads: float, topk_fraction: float = 0.05,
    codec=None,
) -> Dict[str, float]:
    """Per-round uplink cost row: bytes one client sends under ``scheme``, bytes
    the whole round's ``n_uploads`` uploads cost, and the compression ratio vs
    the uncompressed float32 uplink. Uses the analytic accounting from
    ``uplink_bytes``, which the tier-1 tests pin to real encoded payload sizes.

    Pass the run's live ``codec`` when one exists: a codec may override its
    wire accounting (the fused flat top-k prices ONE global kept-entry budget,
    not per-leaf budgets), and the logged bytes must match what that codec
    actually ships — not what the scheme name alone would suggest."""
    from repro.core.compression import uplink_bytes

    per_client = (
        float(codec.nbytes(params_like)) if codec is not None
        else uplink_bytes(params_like, scheme, topk_fraction)
    )
    f32 = uplink_bytes(params_like, "float32")
    return {
        "uplink_bytes_per_client": float(per_client),
        "uplink_bytes_round": float(per_client) * float(n_uploads),
        "uplink_compression_ratio": float(f32) / max(float(per_client), 1e-12),
    }


def evaluate_perplexity(model, params, stream, batches: int = 4, batch_size: int = 4) -> float:
    """Held-out perplexity on a validation stream (server-side evaluation, §4.2)."""
    loss_fn = jax.jit(lambda p, b: model.loss(p, b)[1]["ce"])
    total, n = 0.0, 0
    for _ in range(batches):
        tokens = jnp.asarray(stream.next_batch(batch_size))
        total += float(loss_fn(params, {"tokens": tokens}))
        n += 1
    return perplexity(total / n)


def activation_l2_probe(model, params, batch) -> float:
    """L2 norm of output logits activations — the divergence leading indicator the
    paper tracks (Fig 5)."""
    logits, _, _ = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    return float(jnp.sqrt(jnp.mean(jnp.square(logits.astype(jnp.float32)))))


class MetricLogger:
    """Append-only CSV logger, one row per round/step.

    Schema growth is handled, not swallowed: the first ``log`` fixes the
    header, and a later row introducing NEW keys (e.g. ``val_ppl`` appearing
    only on eval rounds) atomically rewrites the file with the widened header
    — earlier rows pad the new columns with ``""``. The old behaviour
    (``extrasaction="ignore"``) silently discarded such keys forever;
    ``extrasaction="raise"`` now backstops the union logic so a dropped field
    can only ever be a loud error, never lost data.
    """

    def __init__(self, path: str, fieldnames: Optional[List[str]] = None):
        self.path = path
        self.fieldnames = list(fieldnames) if fieldnames else None
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._initialized = os.path.exists(path)
        if self._initialized:
            # resuming into an existing file: adopt (and union with) its header
            with open(self.path, newline="") as f:
                existing = next(csv.reader(f), None)
            if existing:
                merged = list(existing)
                merged += [c for c in (self.fieldnames or []) if c not in merged]
                self.fieldnames = merged

    def _grow_schema(self, new_keys: List[str]) -> None:
        """Widen the header in place: atomic whole-file rewrite (checkpoint
        module's tmp+fsync+replace pattern), old rows padded with ''."""
        from repro.checkpoint.checkpoint import _atomic_write

        old_rows = self.read() if self._initialized else []
        self.fieldnames = list(self.fieldnames or []) + list(new_keys)

        buf = io.StringIO(newline="")
        w = csv.DictWriter(
            buf, fieldnames=self.fieldnames, extrasaction="raise", restval=""
        )
        w.writeheader()
        for r in old_rows:
            w.writerow(r)
        _atomic_write(self.path, lambda f: f.write(buf.getvalue().encode("utf-8")))
        self._initialized = True

    def log(self, row: Dict) -> None:
        row = {k: (float(v) if hasattr(v, "item") or isinstance(v, (int, float)) else v)
               for k, v in row.items()}
        if self.fieldnames is None:
            self.fieldnames = list(row.keys())
        new_keys = [k for k in row if k not in self.fieldnames]
        if new_keys and self._initialized:
            self._grow_schema(new_keys)
        elif new_keys:
            self.fieldnames += new_keys
        write_header = not self._initialized
        with open(self.path, "a", newline="") as f:
            w = csv.DictWriter(
                f, fieldnames=self.fieldnames, extrasaction="raise", restval=""
            )
            if write_header:
                w.writeheader()
            w.writerow(row)
        self._initialized = True

    def read(self) -> List[Dict]:
        with open(self.path) as f:
            return list(csv.DictReader(f))
