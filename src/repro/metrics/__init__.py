from repro.metrics.fedmetrics import (  # noqa: F401
    MetricLogger,
    activation_l2_probe,
    evaluate_perplexity,
    perplexity,
)
