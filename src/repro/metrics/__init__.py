from repro.metrics.fedmetrics import (  # noqa: F401
    MetricLogger,
    activation_l2_probe,
    effective_clients,
    evaluate_perplexity,
    partial_progress_metrics,
    participation_metrics,
    perplexity,
    staleness_stats,
    uplink_round_metrics,
    wallclock_speedup,
    weight_entropy,
)
