from repro.metrics.fedmetrics import (  # noqa: F401
    MetricLogger,
    activation_l2_probe,
    effective_clients,
    evaluate_perplexity,
    participation_metrics,
    perplexity,
    staleness_stats,
    wallclock_speedup,
    weight_entropy,
)
