"""Fused RMSNorm Pallas TPU kernel: one pass over rows, fp32 accumulation in VMEM.

Grid: (n_row_blocks,) with block (br, D) — D stays whole (norms reduce over it), rows
tile. A pure VPU kernel; its value on TPU is fusing the square-mean + rsqrt + scale
into one VMEM-resident pass instead of three HBM round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_fwd(
    x2d: jax.Array,  # (R, D)
    scale: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    R, D = x2d.shape
    assert R % block_rows == 0, (R, block_rows)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    compiler_params = None
    if pltpu is not None and not interpret:
        compiler_params = pltpu.CompilerParams(dimension_semantics=("parallel",))
    return pl.pallas_call(
        kernel,
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        compiler_params=compiler_params,
        interpret=interpret,
    )(x2d, scale)
