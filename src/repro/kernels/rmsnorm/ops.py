"""Jit'd wrapper for the fused RMSNorm kernel (arbitrary leading dims)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_fwd


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _pick_block(r: int, preferred: int = 256) -> int:
    for b in (preferred, 128, 64, 32, 16, 8, 4, 2, 1):
        if r % b == 0:
            return b
    return 1


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6, interpret=None) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    shape = x.shape
    x2d = x.reshape(-1, shape[-1])
    out = rmsnorm_fwd(
        x2d, scale, eps=eps, block_rows=_pick_block(x2d.shape[0]), interpret=interpret
    )
    return out.reshape(shape)
