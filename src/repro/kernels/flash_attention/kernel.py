"""Flash attention Pallas TPU kernel: online-softmax tiling with explicit BlockSpec
VMEM placement. GQA-aware (KV blocks indexed by query-head → kv-head mapping), causal
and sliding-window masking.

Grid: (B, Hq, n_q_blocks, n_kv_blocks) — the last (kv) dimension is sequential
('arbitrary'), carrying the running max/denominator/accumulator in VMEM scratch across
kv steps, the canonical TPU flash-attention schedule. Block shapes are chosen by the
ops.py wrapper to be MXU-aligned (multiples of 128 where the problem allows).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU compiler params are optional under interpret mode
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, bq, hd) VMEM
    k_ref,  # (1, 1, bk, hd)
    v_ref,  # (1, 1, bk, hd)
    o_ref,  # (1, 1, bq, hd)
    m_scr,  # (bq,) f32 scratch
    l_scr,  # (bq,) f32
    acc_scr,  # (bq, hd) f32
    *,
    causal: bool,
    window: Optional[int],
    sm_scale: float,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    q_offset: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: keep numerics clean
    p = jnp.where(mask, p, 0.0)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, Hq, Sq, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    grp = Hq // Hkv
    n_q, n_kv = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / (hd**0.5)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_kv,
        q_offset=q_offset,
    )

    grid = (B, Hq, n_q, n_kv)
    q_spec = pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // grp, j, 0))
    o_spec = pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0))

    compiler_params = None
    if pltpu is not None and not interpret:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q, hd), jnp.float32),
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    if pltpu is not None:
        return pltpu.VMEM(shape, dtype)
    return pl.MemorySpace.ANY(shape, dtype)  # pragma: no cover
