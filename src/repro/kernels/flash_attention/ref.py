"""Pure-jnp oracle for the flash attention kernel (GQA, causal, sliding window)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, hd)
    k: jax.Array,  # (B, Hkv, Sk, hd)
    v: jax.Array,  # (B, Hkv, Sk, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,  # absolute position of q[0] (decode: Sk - Sq)
) -> jax.Array:
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    grp = Hq // Hkv
    qr = q.reshape(B, Hkv, grp, Sq, hd).astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhsd->bhgqs", qr, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bhsd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, Sq, hd).astype(q.dtype)
