"""Jit'd public wrapper for the flash attention kernel.

Handles layout (model code uses (B, S, H, hd); kernel uses (B, H, S, hd)), block-size
selection (MXU-aligned), padding to block multiples, and the CPU/TPU dispatch
(interpret mode on CPU hosts so the same code path is testable everywhere).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pick_block(s: int, preferred: int = 128) -> int:
    for b in (preferred, 64, 32, 16, 8):
        if s % b == 0:
            return b
    return s


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, hd) — model layout
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    if window is not None and not isinstance(window, int):
        raise TypeError("kernel path needs a static window")

    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    Sq, Sk = qt.shape[2], kt.shape[2]
    bq, bk = _pick_block(Sq), _pick_block(Sk)
    out = flash_attention_fwd(
        qt, kt, vt,
        causal=causal, window=window, q_offset=q_offset,
        block_q=bq, block_k=bk, interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)
