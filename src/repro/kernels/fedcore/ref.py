"""Pure-jnp references for the fedcore kernel suite.

Unlike the model kernels (whose refs are standalone oracles), the federation
path's reference IS the production default: the per-leaf jnp chain in
``core/federated.apply_aggregate`` and the ``core/compression`` primitives.
This module re-exports them under the kernel-layer naming so tests and
benchmarks compare ``fedcore.ops`` against exactly the code the non-fused
round runs — the fused path can never drift from a stale copy of the ref.
"""
from __future__ import annotations

from repro.core.compression import (  # noqa: F401
    cast_compress as sr_bf16_ref,
    int8_compress as int8_quant_ref,
    int8_decompress as int8_dequant_ref,
    topk_compress as topk_ef_ref,
)
from repro.core.federated import apply_aggregate as server_apply_ref  # noqa: F401
