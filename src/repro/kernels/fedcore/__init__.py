"""Fused federation kernels: flat-buffer server apply + uplink codec kernels."""
from repro.kernels.fedcore.ops import (  # noqa: F401
    BLOCK,
    FlatSpec,
    FusedBf16Codec,
    FusedInt8Codec,
    FusedTopKCodec,
    dtype_group_indices,
    fused_apply_aggregate,
    pack_client_leaves,
    pack_flat,
    pack_leaves,
    server_apply_bytes,
    topk_encode_bytes,
    unpack_flat,
    unpack_leaves,
)
