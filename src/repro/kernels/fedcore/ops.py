"""Flat-buffer federation ops: pytree packing, the fused server apply, and the
fused uplink codecs — the jit'd layer between ``core/federated.py`` and the
Pallas kernels in ``kernel.py``.

Flat-buffer layout
------------------
``pack_leaves`` concatenates every pytree leaf *of one dtype* into a single
contiguous 1D buffer, zero-padded up to a block multiple so the kernels' grids
divide evenly; ``unpack_leaves`` is its exact inverse (the round-trip is bitwise
— property-tested). Mixed-dtype trees pack into one buffer per dtype
(``dtype_group_indices``), so a bf16-params model and its float32 optimizer
lanes each get their own contiguous view. Client-axis trees (leaves ``(C, ...)``)
pack into one ``(C, N)`` buffer — the shape the fused server apply consumes.

:func:`fused_apply_aggregate` is the drop-in fused replacement for
``core/federated.apply_aggregate`` (same signature, same state/metrics
contract): ONE pass over the (C, N) delta buffer fuses the weighted mean, the
optional DP noise add and the outer-optimizer update, with the aggregation
metrics accumulated in-kernel instead of re-read. On non-TPU hosts it runs the
identical math as a flat jnp chain (XLA fuses the elementwise tail into a
near-single pass — this is also what the CPU benchmarks time); pass
``use_pallas=True, interpret=True`` to execute the actual kernel in interpret
mode (the parity tests do).

Differences vs the per-leaf reference, both bounded and tested:

  - float reassociation: the ref sums ``w·x`` then divides; the kernel scales by
    ``w/Σw`` then sums — parity is within float32 tolerance, not bitwise. The
    DEFAULT (non-fused) round is untouched and stays bitwise-stable.
  - DP noise is drawn per flat dtype-group buffer instead of per leaf, so the
    noise realization differs from the ref's at equal rng (same distribution,
    same scale; the rng lane itself advances identically).

The fused codecs (:class:`FusedTopKCodec`, :class:`FusedBf16Codec`,
:class:`FusedInt8Codec`) subclass the ``core/compression`` codecs, so they plug
into ``run_clients`` / ``apply_aggregate`` / ``admit_deltas`` without any
call-site change. FusedTopKCodec selects top-k over the ONE flat buffer (a
single global threshold + a single fused mask/EF pass) rather than per leaf,
which is also what the flat-length-sized index accounting in
``compression.uplink_bytes`` prices.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import (
    Bf16Codec,
    Int8Codec,
    TopKCodec,
    init_error_feedback,
    _topk_index_nbytes,
)
from repro.core.federated import aggregation_metrics
from repro.kernels.fedcore import kernel as K

# default flat-buffer block: 8192 f32 = 32 KiB per input tile — deep enough to
# amortize grid overhead, small enough that C=16 delta tiles + params + two
# optimizer lanes stay well under the ~16 MiB VMEM budget
BLOCK = 8192


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _default_modes(use_pallas: Optional[bool], interpret: Optional[bool]):
    """Resolve the (use_pallas, interpret) pair: compiled Pallas on TPU, the
    identical-math flat jnp chain elsewhere, interpret mode when Pallas is
    forced onto a CPU host (tests)."""
    if use_pallas is None:
        use_pallas = not _on_cpu()
    if interpret is None:
        interpret = _on_cpu()
    return use_pallas, interpret


# ---------------------------------------------------------------------------
# Flat-buffer pack/unpack
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FlatSpec:
    """Static layout of a packed leaf list: per-leaf shapes (in pack order),
    the true element count ``n`` and the padded length ``n_pad``."""

    shapes: Tuple[Tuple[int, ...], ...]
    n: int
    n_pad: int

    @property
    def offsets(self) -> Tuple[int, ...]:
        offs, o = [], 0
        for s in self.shapes:
            offs.append(o)
            o += _leaf_size(s)
        return tuple(offs)


def _leaf_size(shape: Tuple[int, ...]) -> int:
    out = 1
    for d in shape:
        out *= d
    return out


def _pad_len(n: int, pad_multiple: int) -> int:
    return ((n + pad_multiple - 1) // pad_multiple) * pad_multiple if n else pad_multiple


def pack_leaves(
    leaves: Sequence[jax.Array], pad_multiple: int = 1
) -> Tuple[jax.Array, FlatSpec]:
    """Concatenate same-dtype leaves into one contiguous 1D buffer, zero-padded
    to a multiple of ``pad_multiple``. Inverse: :func:`unpack_leaves` (bitwise)."""
    shapes = tuple(tuple(l.shape) for l in leaves)
    n = sum(_leaf_size(s) for s in shapes)
    n_pad = _pad_len(n, pad_multiple)
    flat = (
        jnp.concatenate([l.reshape(-1) for l in leaves])
        if len(leaves) > 1
        else leaves[0].reshape(-1)
    )
    if n_pad != n:
        flat = jnp.pad(flat, (0, n_pad - n))
    return flat, FlatSpec(shapes=shapes, n=n, n_pad=n_pad)


def unpack_leaves(flat: jax.Array, spec: FlatSpec) -> List[jax.Array]:
    out = []
    for shape, off in zip(spec.shapes, spec.offsets):
        out.append(flat[off : off + _leaf_size(shape)].reshape(shape))
    return out


def pack_flat(tree, pad_multiple: int = 1) -> Tuple[jax.Array, Any, FlatSpec]:
    """Tree-level packing for a single-dtype pytree: returns
    ``(flat (N_pad,), treedef, spec)``; :func:`unpack_flat` inverts bitwise."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat, spec = pack_leaves(leaves, pad_multiple)
    return flat, treedef, spec


def unpack_flat(flat: jax.Array, treedef, spec: FlatSpec):
    return jax.tree_util.tree_unflatten(treedef, unpack_leaves(flat, spec))


def pack_client_leaves(
    leaves: Sequence[jax.Array], c: int, pad_multiple: int = 1
) -> Tuple[jax.Array, FlatSpec]:
    """Pack leaves with a leading client axis ``(C, ...)`` into one ``(C, N_pad)``
    buffer; the per-client layout equals :func:`pack_leaves` of the trailing dims."""
    shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    n = sum(_leaf_size(s) for s in shapes)
    n_pad = _pad_len(n, pad_multiple)
    flat = (
        jnp.concatenate([l.reshape(c, -1) for l in leaves], axis=1)
        if len(leaves) > 1
        else leaves[0].reshape(c, -1)
    )
    if n_pad != n:
        flat = jnp.pad(flat, ((0, 0), (0, n_pad - n)))
    return flat, FlatSpec(shapes=shapes, n=n, n_pad=n_pad)


def dtype_group_indices(leaves: Sequence[jax.Array]) -> List[Tuple[Any, List[int]]]:
    """Group leaf indices by dtype, preserving first-seen order — one flat
    buffer per dtype ('one contiguous 1D view per dtype')."""
    groups: List[Tuple[Any, List[int]]] = []
    seen: Dict[Any, List[int]] = {}
    for i, l in enumerate(leaves):
        dt = jnp.dtype(l.dtype)
        if dt not in seen:
            seen[dt] = []
            groups.append((dt, seen[dt]))
        seen[dt].append(i)
    return groups


# ---------------------------------------------------------------------------
# Fused server apply — drop-in for core/federated.apply_aggregate
# ---------------------------------------------------------------------------

_OUTER_LANES = {"fedavg": (), "fedmom": ("momentum",), "fedadam": ("m", "v")}


def fused_apply_aggregate(
    fed,  # FederatedConfig
    state: Dict[str, Any],
    deltas,  # pytree, leaves (C, ...) — pseudo-gradients or codec payloads
    client_weights: Optional[jax.Array] = None,
    codec=None,
    *,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    block: int = BLOCK,
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Server phase on the flat-buffer layout: ONE fused pass replaces the ref's
    per-leaf weighted-mean → DP-noise → outer-update chain. Same signature,
    state schema and metrics keys as ``apply_aggregate`` (so it slots into
    ``federated_round(apply_fn=...)``); numerics agree within float32 tolerance
    (reassociated reduction — see module docstring), rng/round lanes bitwise.
    """
    use_pallas, interpret = _default_modes(use_pallas, interpret)
    if codec is not None:
        deltas = jax.vmap(codec.decode)(deltas)

    d_leaves, d_treedef = jax.tree_util.tree_flatten(deltas)
    C = d_leaves[0].shape[0]
    elastic = client_weights is not None
    if elastic:
        w = client_weights.astype(jnp.float32)
        wn = w / jnp.maximum(jnp.sum(w), 1e-12)  # pre-divided: Σ_c wn_c·Δ_c
    else:
        w = jnp.ones((C,), jnp.float32)
        wn = jnp.full((C,), 1.0 / C, jnp.float32)

    ocfg = fed.outer
    lane_names = _OUTER_LANES[ocfg.name]
    rnd = state["outer"]["round"] + 1
    bias_corr = None
    if ocfg.name == "fedadam":
        c_f = rnd.astype(jnp.float32)
        bias_corr = (1.0 - ocfg.momentum**c_f, 1.0 - ocfg.beta2**c_f)

    rng, noise_rng = jax.random.split(state["rng"])
    has_noise = fed.dp_noise > 0.0
    if has_noise:
        if elastic:
            noise_scale = fed.dp_noise * jnp.max(w) / jnp.maximum(jnp.sum(w), 1e-12)
        else:
            noise_scale = fed.dp_noise / C

    p_leaves, p_treedef = jax.tree_util.tree_flatten(state["params"])
    lane_leaf_lists = [
        jax.tree_util.tree_flatten(state["outer"][name])[0] for name in lane_names
    ]

    new_p_leaves: List[Optional[jax.Array]] = [None] * len(p_leaves)
    new_lane_leaves: List[List[Optional[jax.Array]]] = [
        [None] * len(p_leaves) for _ in lane_names
    ]
    pg_sq = jnp.zeros((), jnp.float32)
    newp_sq = jnp.zeros((), jnp.float32)
    delta_sq = jnp.zeros((C,), jnp.float32)

    for gi, (dt, idxs) in enumerate(dtype_group_indices(p_leaves)):
        p_flat, spec = pack_leaves([p_leaves[i] for i in idxs], block)
        lanes_flat = [
            pack_leaves([lanes[i] for i in idxs], block)[0] for lanes in lane_leaf_lists
        ]
        d_flat, _ = pack_client_leaves(
            [d_leaves[i].astype(jnp.float32) for i in idxs], C, block
        )
        noise_flat = None
        if has_noise:
            nz = noise_scale * jax.random.normal(
                jax.random.fold_in(noise_rng, gi), (spec.n,), jnp.float32
            )
            noise_flat = jnp.pad(nz, (0, spec.n_pad - spec.n))

        if use_pallas:
            new_p_flat, new_lanes_flat, g_pg_sq, g_np_sq, g_dsq = K.server_apply(
                d_flat, wn, p_flat, lanes_flat,
                opt=ocfg.name, lr=ocfg.lr, momentum=ocfg.momentum,
                nesterov=ocfg.nesterov, beta2=ocfg.beta2, eps=ocfg.eps,
                bias_corr=bias_corr, noise=noise_flat, block=block,
                interpret=interpret,
            )
            pg_sq = pg_sq + g_pg_sq[0, 0]
            newp_sq = newp_sq + g_np_sq[0, 0]
            delta_sq = delta_sq + g_dsq[:, 0]
        else:
            # the identical math as a flat jnp chain (XLA fuses the tail);
            # op-for-op the same formulas the kernel computes per block
            pg = jnp.sum(d_flat * wn[:, None], axis=0)
            if noise_flat is not None:
                pg = pg + noise_flat
            p32 = p_flat.astype(jnp.float32)
            if ocfg.name == "fedavg":
                new_p32 = p32 - ocfg.lr * pg
                new_lanes32 = []
            elif ocfg.name == "fedmom":
                m = lanes_flat[0].astype(jnp.float32)
                new_m = ocfg.momentum * m + pg
                upd = ocfg.momentum * new_m + pg if ocfg.nesterov else new_m
                new_p32 = p32 - ocfg.lr * upd
                new_lanes32 = [new_m]
            else:  # fedadam
                m = lanes_flat[0].astype(jnp.float32)
                v = lanes_flat[1].astype(jnp.float32)
                b1c, b2c = bias_corr
                new_m = ocfg.momentum * m + (1.0 - ocfg.momentum) * pg
                new_v = ocfg.beta2 * v + (1.0 - ocfg.beta2) * jnp.square(pg)
                new_p32 = p32 - ocfg.lr * (new_m / b1c) / (
                    jnp.sqrt(new_v / b2c) + ocfg.eps
                )
                new_lanes32 = [new_m, new_v]
            new_p_flat = new_p32.astype(dt)
            new_lanes_flat = [
                nl.astype(lf.dtype) for nl, lf in zip(new_lanes32, lanes_flat)
            ]
            pg_sq = pg_sq + jnp.sum(jnp.square(pg))
            newp_sq = newp_sq + jnp.sum(jnp.square(new_p_flat.astype(jnp.float32)))
            delta_sq = delta_sq + jnp.sum(jnp.square(d_flat), axis=1)

        for leaf, i in zip(unpack_leaves(new_p_flat, spec), idxs):
            new_p_leaves[i] = leaf
        for li, nl_flat in enumerate(new_lanes_flat):
            for leaf, i in zip(unpack_leaves(nl_flat, spec), idxs):
                new_lane_leaves[li][i] = leaf

    new_params = jax.tree_util.tree_unflatten(p_treedef, new_p_leaves)
    new_outer: Dict[str, Any] = {"round": rnd}
    for name, leaves in zip(lane_names, new_lane_leaves):
        new_outer[name] = jax.tree_util.tree_unflatten(p_treedef, leaves)

    # ---- aggregation metrics: the SHARED formula set (core/federated), fed
    # from the in-kernel accumulators instead of extra params-sized passes ----
    metrics = dict(
        aggregation_metrics(jnp.sqrt(delta_sq), jnp.sqrt(pg_sq), client_weights),
        global_model_norm=jnp.sqrt(newp_sq),
    )
    new_state = {
        "params": new_params,
        "outer": new_outer,
        "round": state["round"] + 1,
        "rng": rng,
    }
    return new_state, metrics


# ---------------------------------------------------------------------------
# Fused uplink codecs — drop-in Codec subclasses (core/compression seam)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FusedTopKCodec(TopKCodec):
    """Flat-buffer top-k with error feedback: the delta pytree packs into ONE
    contiguous buffer, the threshold is the k-th magnitude of the WHOLE buffer
    (k = max(1, ⌊N·k_fraction⌋) — a global budget, where the per-leaf ref gives
    every tensor its own k), and the mask + select + residual update run as one
    fused pass. For a single-leaf tree this is bitwise ``topk_compress``
    (tested). Wire accounting prices one flat-length-sized index per kept entry
    (``compression.uplink_bytes``)."""

    use_pallas: Optional[bool] = None
    interpret: Optional[bool] = None
    block: int = BLOCK

    def encode(self, delta, residual=None, rng: Optional[jax.Array] = None):
        if residual is None:
            residual = init_error_feedback(delta)
        use_pallas, interpret = _default_modes(self.use_pallas, self.interpret)
        x_flat, treedef, spec = pack_flat(
            jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), delta), self.block
        )
        e_flat, _, _ = pack_flat(residual, self.block)
        xf = x_flat + e_flat
        k = max(1, int(spec.n * self.k_fraction))
        # the one non-streaming step: the global k-th magnitude (padding is
        # excluded so the zero tail can never displace a real entry)
        thresh = jax.lax.top_k(jnp.abs(xf[: spec.n]), k)[0][-1]
        if use_pallas:
            kept, new_e = K.topk_mask_ef(
                xf, thresh, block=self.block, interpret=interpret
            )
        else:
            kept = jnp.where(jnp.abs(xf) >= thresh, xf, 0.0)
            new_e = xf - kept
        # payload values ship in the delta's own dtype (the ref's
        # kept.astype(x.dtype)); the residual stays float32 client state
        payload = jax.tree_util.tree_map(
            lambda k, d: k.astype(d.dtype), unpack_flat(kept, treedef, spec), delta
        )
        return payload, unpack_flat(new_e, treedef, spec)

    def nbytes(self, params_like) -> float:
        n = sum(x.size for x in jax.tree_util.tree_leaves(params_like))
        kept = max(1, int(n * self.k_fraction))
        return float(kept) * (4.0 + _topk_index_nbytes(n))

    def payload_nbytes(self, payload) -> float:
        # same GLOBAL budget as nbytes — the per-leaf analytic count inherited
        # from TopKCodec would over-bill the flat codec's shared k
        return self.nbytes(payload)


class FusedBf16Codec(Bf16Codec):
    """Flat-buffer bf16 stochastic rounding: one fused add-noise/truncate/cast
    pass over the packed buffer. The rounding noise is drawn exactly as the ref
    draws it (per leaf, same keys), so at equal rng the payload is BITWISE the
    ref's — only the passes fuse, never the distribution."""

    def __init__(
        self,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        block: int = BLOCK,
    ):
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.block = block

    def encode(self, delta, residual=None, rng: Optional[jax.Array] = None):
        use_pallas, interpret = _default_modes(self.use_pallas, self.interpret)
        leaves, treedef = jax.tree_util.tree_flatten(delta)
        x_flat, spec = pack_leaves(
            [l.astype(jnp.float32) for l in leaves], self.block
        )
        if rng is None:
            # deterministic degradation: the ref's astype rounds-to-nearest
            # (zero-noise truncation would bias low) — no kernel on this path
            out = x_flat.astype(jnp.bfloat16)
            return (
                jax.tree_util.tree_unflatten(treedef, unpack_leaves(out, spec)),
                residual,
            )
        keys = jax.random.split(rng, len(leaves))
        noise_leaves = [
            jax.random.randint(k, l.shape, 0, 1 << 16).astype(jnp.uint32)
            for k, l in zip(keys, leaves)
        ]
        noise, _ = pack_leaves(noise_leaves, self.block)
        if use_pallas:
            out = K.sr_bf16(x_flat, noise, block=self.block, interpret=interpret)
        else:
            bits = jax.lax.bitcast_convert_type(x_flat, jnp.uint32)
            rounded = (bits + noise) & jnp.uint32(0xFFFF0000)
            out = jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(
                jnp.bfloat16
            )
        return (
            jax.tree_util.tree_unflatten(treedef, unpack_leaves(out, spec)),
            residual,
        )


class FusedInt8Codec(Int8Codec):
    """Per-tensor symmetric int8 with the round/clip/cast fused into one pass
    per tensor (the absmax reduction stays an XLA reduction). Payload format and
    numerics are bitwise the ref's ``int8_compress``."""

    def __init__(
        self,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        block: int = BLOCK,
    ):
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.block = block

    def encode(self, delta, residual=None, rng: Optional[jax.Array] = None):
        use_pallas, interpret = _default_modes(self.use_pallas, self.interpret)

        def one(x):
            xf = x.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
            if use_pallas:
                flat, spec = pack_leaves([xf], self.block)
                q_flat = K.int8_quant(flat, scale, block=self.block, interpret=interpret)
                q = unpack_leaves(q_flat, spec)[0]
            else:
                q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
            return {"q": q, "scale": scale}

        return jax.tree_util.tree_map(one, delta), residual

    def decode(self, payload):
        use_pallas, interpret = _default_modes(self.use_pallas, self.interpret)

        def one(c):
            if use_pallas:
                flat, spec = pack_leaves([c["q"]], self.block)
                out = K.int8_dequant(
                    flat, c["scale"], block=self.block, interpret=interpret
                )
                return unpack_leaves(out, spec)[0]
            return c["q"].astype(jnp.float32) * c["scale"]

        return jax.tree_util.tree_map(
            one, payload, is_leaf=lambda n: isinstance(n, dict) and "q" in n
        )


# ---------------------------------------------------------------------------
# Analytic bytes-moved accounting (the roofline comparison the bench records)
# ---------------------------------------------------------------------------


def server_apply_bytes(
    n: int, c: int, opt: str, dp_noise: bool = False, fused: bool = False,
    dtype_bytes: int = 4,
) -> float:
    """HBM bytes one server apply moves, counting each primitive pass over
    params-sized data (the per-leaf jnp chain materializes each step):

    ref chain: weigh (read CN, write CN) → sum over clients (read CN, write N)
    → divide (r/w N) → [noise gen + add (3N)] → outer update (opt-dependent
    lane reads/writes) → metric passes (per-client delta norms read CN,
    pseudo-grad norm read N, new model norm read N).

    fused kernel: read CN + params + lanes [+ noise N], write params + lanes;
    metrics accumulate in-register.
    """
    lanes = {"fedavg": 0, "fedmom": 1, "fedadam": 2}[opt]
    if fused:
        reads = c * n + n + lanes * n + (n if dp_noise else 0)
        writes = n + lanes * n
        return float(dtype_bytes) * (reads + writes)
    weigh = 2 * c * n  # x * w broadcast materializes (C, N)
    reduce = c * n + n
    divide = 2 * n
    noise = 3 * n if dp_noise else 0  # gen write + (pg, noise) read + write
    outer = {
        "fedavg": 3 * n,  # read p, pg; write p
        "fedmom": 9 * n,  # mom update 3N + nesterov combine 3N + params 3N
        "fedadam": 10 * n,  # m 3N + v 3N + params read p,m,v write p 4N
    }[opt]
    metrics = c * n + 2 * n  # delta norms + pg norm + model norm
    return float(dtype_bytes) * (weigh + reduce + divide + noise + outer + metrics)


def topk_encode_bytes(n: int, fused: bool = False, dtype_bytes: int = 4) -> float:
    """Bytes one top-k+EF encode moves over the n-element delta.

    ref (per leaf, materialized): xf = x+e (3n) → abs (2n) → mask compare (2n)
    → select (3n) → residual subtract (3n), plus the top_k sort's own read (n).
    fused: xf add (3n) + sort read (n) + one mask/EF pass (read xf, write kept
    + residual = 3n)."""
    if fused:
        return float(dtype_bytes) * (3 * n + n + 3 * n)
    return float(dtype_bytes) * (3 * n + 2 * n + n + 2 * n + 3 * n + 3 * n)
