"""Fused Pallas TPU kernels for the federation hot loop (server side + uplink codecs).

The federated round is dominated by params-sized elementwise passes: the server's
weighted-mean-over-clients → DP noise → outer-optimizer chain re-reads the (C, N)
delta buffer and the params-sized optimizer lanes once per op when written as
per-leaf jnp (Photon, arXiv 2411.02908, names aggregation throughput as the
billion-parameter scaling bottleneck). These kernels operate on the *flat-buffer*
layout built by ``ops.pack_leaves``: every pytree leaf of one dtype concatenated
into a single contiguous 1D view, so one grid sweep touches each byte exactly once.

  - :func:`server_apply` — weighted mean over the client axis + optional DP noise
    + FedAvg/FedMom(Nesterov)/FedAdam outer update, fused into ONE pass: per grid
    block it reads the (C, bn) delta tile, the params tile and the optimizer-lane
    tiles, and writes the updated params/lanes. The aggregation metrics the jnp
    path derives from extra passes (per-client delta norms, pseudo-gradient norm,
    new model norm) are accumulated IN-KERNEL into tiny revisited output blocks —
    the grid dimension is declared "arbitrary" (sequential), which is what makes
    the accumulator pattern race-free on TPU.
  - :func:`topk_mask_ef` — the top-k codec's mask + select + error-feedback
    residual update in one pass (the threshold itself comes from ``lax.top_k``,
    the one genuinely non-streaming step).
  - :func:`sr_bf16` — bit-level stochastic-round-to-bf16 given pre-drawn uint32
    noise (bitwise-identical to ``compression.cast_compress``'s rounding).
  - :func:`int8_quant` / :func:`int8_dequant` — per-tensor symmetric int8.

All kernels run under ``interpret=True`` on CPU hosts — that is how the tier-1
parity tests execute them; the compiled path targets TPU. The jnp reference
semantics live in ``core/federated.apply_aggregate`` / ``core/compression`` (see
``ref.py``).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

# jax renamed TPUCompilerParams -> CompilerParams; support both
_COMPILER_PARAMS = (
    getattr(pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams", None)
    if pltpu is not None
    else None
)


def _compiler_params(interpret: bool, semantics: Tuple[str, ...]):
    if interpret or _COMPILER_PARAMS is None:
        return None
    return _COMPILER_PARAMS(dimension_semantics=semantics)


# ---------------------------------------------------------------------------
# Fused server apply: weighted mean + DP noise + outer update, one (C, N) pass
# ---------------------------------------------------------------------------


def _server_apply_kernel(
    *refs,
    opt: str,
    lr: float,
    momentum: float,
    nesterov: bool,
    beta2: float,
    eps: float,
    n_lanes: int,
    has_noise: bool,
    has_bias_corr: bool,
):
    """One grid block: refs are
    [wn (C,1), (b1c (1,1), b2c (1,1))?, deltas (C,bn), params (bn,), lanes*,
     noise (bn,)?] then outputs
    [new_params (bn,), new_lanes*, pg_sq (1,1), newp_sq (1,1), delta_sq (C,1)].
    """
    it = iter(refs)
    wn_ref = next(it)
    if has_bias_corr:
        b1c_ref, b2c_ref = next(it), next(it)
    d_ref = next(it)
    p_ref = next(it)
    lane_refs = [next(it) for _ in range(n_lanes)]
    noise_ref = next(it) if has_noise else None
    o_p_ref = next(it)
    o_lane_refs = [next(it) for _ in range(n_lanes)]
    pg_sq_ref = next(it)
    np_sq_ref = next(it)
    dsq_ref = next(it)

    i = pl.program_id(0)
    d = d_ref[...].astype(jnp.float32)  # (C, bn)
    wn = wn_ref[...].astype(jnp.float32)  # (C, 1), already w/Σw
    pg = jnp.sum(d * wn, axis=0)  # the ONE client-axis reduction
    if has_noise:
        pg = pg + noise_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)

    if opt == "fedavg":
        new_p = p - lr * pg
        new_lanes = []
    elif opt == "fedmom":
        m = lane_refs[0][...].astype(jnp.float32)
        new_m = momentum * m + pg
        upd = momentum * new_m + pg if nesterov else new_m
        new_p = p - lr * upd
        new_lanes = [new_m]
    elif opt == "fedadam":
        m = lane_refs[0][...].astype(jnp.float32)
        v = lane_refs[1][...].astype(jnp.float32)
        b1c = b1c_ref[0, 0]
        b2c = b2c_ref[0, 0]
        new_m = momentum * m + (1.0 - momentum) * pg
        new_v = beta2 * v + (1.0 - beta2) * jnp.square(pg)
        new_p = p - lr * (new_m / b1c) / (jnp.sqrt(new_v / b2c) + eps)
        new_lanes = [new_m, new_v]
    else:  # pragma: no cover — builder validates
        raise ValueError(opt)

    new_p_cast = new_p.astype(o_p_ref.dtype)
    o_p_ref[...] = new_p_cast
    for lane, o_ref in zip(new_lanes, o_lane_refs):
        o_ref[...] = lane.astype(o_ref.dtype)

    @pl.when(i == 0)
    def _():
        pg_sq_ref[0, 0] = 0.0
        np_sq_ref[0, 0] = 0.0
        dsq_ref[...] = jnp.zeros_like(dsq_ref)

    pg_sq_ref[0, 0] += jnp.sum(jnp.square(pg))
    # norm of the params as STORED (post-cast), matching the ref's global_norm
    np_sq_ref[0, 0] += jnp.sum(jnp.square(new_p_cast.astype(jnp.float32)))
    dsq_ref[...] += jnp.sum(jnp.square(d), axis=1, keepdims=True)


def server_apply(
    deltas2d: jax.Array,  # (C, Np) float32 — packed client deltas (padded)
    wn: jax.Array,  # (C,) float32 — weights pre-divided by Σw
    params_flat: jax.Array,  # (Np,) — packed params (any float dtype)
    lanes: Sequence[jax.Array],  # packed outer-opt lanes, each (Np,), params dtype
    *,
    opt: str,  # 'fedavg' | 'fedmom' | 'fedadam'
    lr: float,
    momentum: float = 0.9,
    nesterov: bool = True,
    beta2: float = 0.99,
    eps: float = 1e-8,
    bias_corr: Optional[Tuple[jax.Array, jax.Array]] = None,  # (b1c, b2c) fedadam
    noise: Optional[jax.Array] = None,  # (Np,) float32 pre-scaled DP noise
    block: int = 8192,
    interpret: bool = False,
):
    """One fused pass over the flat buffers. Returns
    ``(new_params (Np,), new_lanes, pg_sq (1,1), newp_sq (1,1), delta_sq (C,1))``.

    Reads each input byte exactly once and writes each output byte exactly once;
    the three metric outputs are revisited (1,1)/(C,1) accumulator blocks.
    """
    C, Np = deltas2d.shape
    assert Np % block == 0, (Np, block)
    n_lanes = len(lanes)
    has_noise = noise is not None
    has_bias_corr = bias_corr is not None
    kernel = functools.partial(
        _server_apply_kernel,
        opt=opt, lr=lr, momentum=momentum, nesterov=nesterov, beta2=beta2,
        eps=eps, n_lanes=n_lanes, has_noise=has_noise, has_bias_corr=has_bias_corr,
    )
    args = [wn.reshape(C, 1).astype(jnp.float32)]
    in_specs = [pl.BlockSpec((C, 1), lambda i: (0, 0))]
    if has_bias_corr:
        for b in bias_corr:
            args.append(jnp.asarray(b, jnp.float32).reshape(1, 1))
            in_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0)))
    args.append(deltas2d)
    in_specs.append(pl.BlockSpec((C, block), lambda i: (0, i)))
    args.append(params_flat)
    in_specs.append(pl.BlockSpec((block,), lambda i: (i,)))
    for lane in lanes:
        args.append(lane)
        in_specs.append(pl.BlockSpec((block,), lambda i: (i,)))
    if has_noise:
        args.append(noise)
        in_specs.append(pl.BlockSpec((block,), lambda i: (i,)))

    out_shape = [jax.ShapeDtypeStruct((Np,), params_flat.dtype)]
    out_specs = [pl.BlockSpec((block,), lambda i: (i,))]
    for lane in lanes:
        out_shape.append(jax.ShapeDtypeStruct((Np,), lane.dtype))
        out_specs.append(pl.BlockSpec((block,), lambda i: (i,)))
    out_shape += [
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((C, 1), jnp.float32),
    ]
    out_specs += [
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
        pl.BlockSpec((C, 1), lambda i: (0, 0)),
    ]

    outs = pl.pallas_call(
        kernel,
        grid=(Np // block,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        # the metric outputs accumulate across grid steps -> sequential grid
        compiler_params=_compiler_params(interpret, ("arbitrary",)),
        interpret=interpret,
    )(*args)
    new_p = outs[0]
    new_lanes = list(outs[1 : 1 + n_lanes])
    pg_sq, np_sq, dsq = outs[1 + n_lanes :]
    return new_p, new_lanes, pg_sq, np_sq, dsq


# ---------------------------------------------------------------------------
# Fused codec kernels (flat-buffer uplink)
# ---------------------------------------------------------------------------


def _topk_mask_ef_kernel(t_ref, xf_ref, kept_ref, resid_ref):
    xf = xf_ref[...].astype(jnp.float32)
    thresh = t_ref[0, 0]
    kept = jnp.where(jnp.abs(xf) >= thresh, xf, 0.0)
    kept_ref[...] = kept
    resid_ref[...] = xf - kept


def topk_mask_ef(
    xf: jax.Array,  # (Np,) float32 — delta + error-feedback residual, packed
    thresh: jax.Array,  # () float32 — the k-th magnitude (from lax.top_k)
    *,
    block: int = 8192,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Mask + select + residual update in ONE pass: reads xf once, writes the
    kept payload and the new residual once. (The ref chain re-reads xf for the
    abs, the mask, the select and the subtraction.)"""
    (Np,) = xf.shape
    assert Np % block == 0, (Np, block)
    return pl.pallas_call(
        _topk_mask_ef_kernel,
        grid=(Np // block,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Np,), jnp.float32)] * 2,
        compiler_params=_compiler_params(interpret, ("parallel",)),
        interpret=interpret,
    )(jnp.asarray(thresh, jnp.float32).reshape(1, 1), xf)


def _sr_bf16_kernel(x_ref, noise_ref, o_ref):
    bits = jax.lax.bitcast_convert_type(x_ref[...].astype(jnp.float32), jnp.uint32)
    rounded = (bits + noise_ref[...]) & jnp.uint32(0xFFFF0000)
    o_ref[...] = jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(jnp.bfloat16)


def sr_bf16(
    x: jax.Array,  # (Np,) float32
    noise: jax.Array,  # (Np,) uint32 in [0, 2^16) — pre-drawn rounding noise
    *,
    block: int = 8192,
    interpret: bool = False,
) -> jax.Array:
    """Bit-level stochastic round to bf16 in one pass — the identical arithmetic
    to ``compression.cast_compress`` (add 16-bit noise to the f32 pattern,
    truncate), so given the same noise the payload is bitwise the ref's."""
    (Np,) = x.shape
    assert Np % block == 0, (Np, block)
    return pl.pallas_call(
        _sr_bf16_kernel,
        grid=(Np // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.bfloat16),
        compiler_params=_compiler_params(interpret, ("parallel",)),
        interpret=interpret,
    )(x, noise)


def _int8_quant_kernel(s_ref, x_ref, q_ref):
    scale = s_ref[0, 0]
    q = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)


def int8_quant(
    x: jax.Array,  # (Np,) float32
    scale: jax.Array,  # () float32 — per-tensor absmax/127
    *,
    block: int = 8192,
    interpret: bool = False,
) -> jax.Array:
    (Np,) = x.shape
    assert Np % block == 0, (Np, block)
    return pl.pallas_call(
        _int8_quant_kernel,
        grid=(Np // block,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.int8),
        compiler_params=_compiler_params(interpret, ("parallel",)),
        interpret=interpret,
    )(jnp.asarray(scale, jnp.float32).reshape(1, 1), x)


def _int8_dequant_kernel(s_ref, q_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[0, 0]


def int8_dequant(
    q: jax.Array,  # (Np,) int8
    scale: jax.Array,  # () float32
    *,
    block: int = 8192,
    interpret: bool = False,
) -> jax.Array:
    (Np,) = q.shape
    assert Np % block == 0, (Np, block)
    return pl.pallas_call(
        _int8_dequant_kernel,
        grid=(Np // block,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.float32),
        compiler_params=_compiler_params(interpret, ("parallel",)),
        interpret=interpret,
    )(jnp.asarray(scale, jnp.float32).reshape(1, 1), q)
