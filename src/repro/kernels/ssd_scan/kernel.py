"""SSD (Mamba2) chunk-scan Pallas TPU kernel.

Grid: (B, n_heads, n_chunks) — chunks are sequential ('arbitrary'), carrying the
(hd, ds) recurrent state in VMEM scratch across chunk steps. Each chunk step does the
intra-chunk quadratic term (two MXU matmuls of shape (chunk, ds)x(ds, chunk) and
(chunk, chunk)x(chunk, hd)) plus the inter-chunk state propagation — the TPU-native
realisation of state-space duality: all FLOPs live in MXU-aligned matmuls, the
recurrence touches VMEM only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _ssd_kernel(
    x_ref,  # (1, 1, cl, hd)
    dt_ref,  # (1, 1, cl)
    a_ref,  # (1,)
    b_ref,  # (1, 1, cl, ds)
    c_ref,  # (1, 1, cl, ds)
    init_ref,  # (1, 1, hd, ds)
    y_ref,  # (1, 1, cl, hd) out
    final_ref,  # (1, 1, hd, ds) out
    state_scr,  # (hd, ds) f32 scratch
    *,
    chunk: int,
    n_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)  # (cl, hd)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (cl,)
    A = a_ref[0].astype(jnp.float32)  # scalar
    Bm = b_ref[0, 0].astype(jnp.float32)  # (cl, ds)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (cl, ds)

    dA = dt * A  # (cl,) negative
    dA_cum = jnp.cumsum(dA)  # inclusive
    dA_total = dA_cum[-1]
    dx = x * dt[:, None]  # (cl, hd)

    # intra-chunk: causal decay-weighted "attention"
    decay = dA_cum[:, None] - dA_cum[None, :]  # (cl_i, cl_j)
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (chunk, chunk), 1
    )
    L = jnp.where(causal, jnp.exp(decay), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (cl, cl)
    y_intra = jax.lax.dot_general(
        scores * L, dx, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (cl, hd)

    # inter-chunk: contribution of carried state
    state = state_scr[...]  # (hd, ds)
    y_inter = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(dA_cum)[:, None]  # (cl, hd)

    y_ref[0, 0, ...] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S' = exp(dA_total) S + sum_j exp(dA_total - dA_cum_j) dx_j B_j^T
    w = jnp.exp(dA_total - dA_cum)  # (cl,)
    new_state = jnp.exp(dA_total) * state + jax.lax.dot_general(
        dx * w[:, None], Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (hd, ds)
    state_scr[...] = new_state

    @pl.when(ic == n_chunks - 1)
    def _finalize():
        final_ref[0, 0, ...] = new_state


def ssd_scan_fwd(
    x: jax.Array,  # (B, nh, S, hd)
    dt: jax.Array,  # (B, nh, S)
    A: jax.Array,  # (nh,)
    Bm: jax.Array,  # (B, G, S, ds)
    Cm: jax.Array,  # (B, G, S, ds)
    init_state: jax.Array,  # (B, nh, hd, ds)
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    B, nh, S, hd = x.shape
    G, ds = Bm.shape[1], Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = nh // G

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    grid = (B, nh, nc)

    x_spec = pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0))
    dt_spec = pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c))
    a_spec = pl.BlockSpec((1,), lambda b, h, c: (h,))
    bc_spec = pl.BlockSpec((1, 1, chunk, ds), lambda b, h, c: (b, h // rep, c, 0))
    init_spec = pl.BlockSpec((1, 1, hd, ds), lambda b, h, c: (b, h, 0, 0))
    y_spec = pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0))
    fin_spec = pl.BlockSpec((1, 1, hd, ds), lambda b, h, c: (b, h, 0, 0))

    compiler_params = None
    if pltpu is not None and not interpret:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )

    y, final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, dt_spec, a_spec, bc_spec, bc_spec, init_spec],
        out_specs=[y_spec, fin_spec],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(x, dt, A, Bm, Cm, init_state)
    return y, final
