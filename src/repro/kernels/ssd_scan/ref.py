"""Oracle for the SSD chunk-scan kernel: re-exports the model-level chunked SSD
implementation (itself validated against a naive O(S·ds) sequential recurrence here)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked  # the pure-jnp chunked implementation


def ssd_naive(
    x: jax.Array,  # (B, S, nh, hd)
    dt: jax.Array,  # (B, S, nh)
    A: jax.Array,  # (nh,)
    Bm: jax.Array,  # (B, S, G, ds)
    Cm: jax.Array,  # (B, S, G, ds)
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Token-by-token linear recurrence — the ground-truth semantics."""
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    rep = nh // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    state = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, nh, hd, ds), jnp.float32)
    )

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (B,nh,hd), (B,nh), (B,nh,ds), (B,nh,ds)
        dA = jnp.exp(dtt * A.astype(jnp.float32))
        dx = xt.astype(jnp.float32) * dtt[..., None]
        state = state * dA[..., None, None] + jnp.einsum("bhd,bhn->bhdn", dx, Bt)
        y = jnp.einsum("bhdn,bhn->bhd", state, Ct)
        return state, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bh, 1, 0),
        jnp.moveaxis(Ch, 1, 0),
    )
    final, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


ssd_ref = ssd_chunked  # chunked oracle (validated against ssd_naive in tests)
