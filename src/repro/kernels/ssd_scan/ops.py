"""Jit'd wrapper for the SSD chunk-scan kernel (model layout (B, S, nh, hd))."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,  # (B, S, nh, hd)
    dt: jax.Array,  # (B, S, nh) — post-softplus
    A: jax.Array,  # (nh,) negative
    Bm: jax.Array,  # (B, S, G, ds)
    Cm: jax.Array,  # (B, S, G, ds)
    chunk: int = 64,
    initial_state: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = _on_cpu()
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if initial_state is None:
        initial_state = jnp.zeros((B, nh, hd, ds), jnp.float32)
    y, final = ssd_scan_fwd(
        jnp.moveaxis(x, 1, 2),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 2),
        A.astype(jnp.float32),
        jnp.moveaxis(Bm, 1, 2),
        jnp.moveaxis(Cm, 1, 2),
        initial_state.astype(jnp.float32),
        chunk=chunk,
        interpret=interpret,
    )
    y = jnp.moveaxis(y, 1, 2)
    if pad:
        y = y[:, :S]
    return y, final
