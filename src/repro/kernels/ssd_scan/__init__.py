from repro.kernels.ssd_scan import ops, ref  # noqa: F401
from repro.kernels.ssd_scan.kernel import ssd_scan_fwd  # noqa: F401
from repro.kernels.ssd_scan.ops import ssd  # noqa: F401
