"""Jit'd wrapper for the flash-decode kernel (model layout (B, 1, H, hd) queries)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import flash_decode_fwd


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _pick_block(s: int, preferred: int = 512) -> int:
    for b in (preferred, 256, 128, 64, 32, 16, 8):
        if s % b == 0:
            return b
    return s


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_decode(
    q: jax.Array,  # (B, 1, Hq, hd) — model layout, single new token
    k_cache: jax.Array,  # (B, S, Hkv, hd)
    v_cache: jax.Array,
    kv_len: jax.Array,  # scalar or (B,)
    *,
    window: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    B = q.shape[0]
    qt = q[:, 0].swapaxes(1, 1)  # (B, Hq, hd)
    kt = jnp.moveaxis(k_cache, 1, 2)  # (B, Hkv, S, hd)
    vt = jnp.moveaxis(v_cache, 1, 2)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    out = flash_decode_fwd(
        qt, kt, vt, kv_len,
        window=window, block_k=_pick_block(kt.shape[2]), interpret=interpret,
    )
    return out[:, None]  # (B, 1, Hq, hd)
