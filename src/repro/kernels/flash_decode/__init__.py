from repro.kernels.flash_decode import ops, ref  # noqa: F401
from repro.kernels.flash_decode.kernel import flash_decode_fwd  # noqa: F401
from repro.kernels.flash_decode.ops import flash_decode  # noqa: F401
