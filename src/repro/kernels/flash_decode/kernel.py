"""Flash-decode Pallas TPU kernel: one query token vs. a long KV cache.

Grid: (B, Hq, n_kv_blocks); the kv dimension is sequential, carrying the online-softmax
(m, l, acc) in VMEM scratch. Variable cache length enters as a scalar-prefetch style
operand (a (B,) int32 array in SMEM-like placement) so a single compiled kernel serves
every decode position. This is the memory-bound hot loop of decode_32k/long_500k: each
KV byte is touched exactly once.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # (1,) int32 — valid kv length for this batch row
    q_ref,  # (1, 1, hd)
    k_ref,  # (1, 1, bk, hd)
    v_ref,  # (1, 1, bk, hd)
    o_ref,  # (1, 1, hd)
    m_scr,  # (1,) f32
    l_scr,  # (1,) f32
    acc_scr,  # (hd,) f32 — wait, use (1, hd)
    *,
    sm_scale: float,
    block_k: int,
    n_kv_blocks: int,
    window: Optional[int],
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (hd,)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    s = jnp.sum(k * q[None, :], axis=1)  # (bk,)

    pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
    mask = pos < kv_len
    if window is not None:
        mask &= pos > (kv_len - 1 - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # (bk,)
    l_scr[0] = l_scr[0] * alpha + jnp.sum(p)
    acc_scr[...] = acc_scr[...] * alpha + jnp.sum(
        p[:, None] * v_ref[0, 0].astype(jnp.float32), axis=0, keepdims=True
    )
    m_scr[0] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0, :] = (acc_scr[0] / jnp.maximum(l_scr[0], 1e-30)).astype(o_ref.dtype)


def flash_decode_fwd(
    q: jax.Array,  # (B, Hq, hd)
    k: jax.Array,  # (B, Hkv, S, hd)
    v: jax.Array,
    kv_len: jax.Array,  # (B,) int32
    *,
    window: Optional[int] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert S % block_k == 0, (S, block_k)
    grp = Hq // Hkv
    n_kv = S // block_k
    sm_scale = 1.0 / (hd**0.5)

    kernel = functools.partial(
        _decode_kernel,
        sm_scale=sm_scale,
        block_k=block_k,
        n_kv_blocks=n_kv,
        window=window,
    )

    grid = (B, Hq, n_kv)
    len_spec = pl.BlockSpec((1,), lambda b, h, j: (b,))
    q_spec = pl.BlockSpec((1, 1, hd), lambda b, h, j: (b, h, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h // grp, j, 0))
    o_spec = pl.BlockSpec((1, 1, hd), lambda b, h, j: (b, h, 0))

    compiler_params = None
    if pltpu is not None and not interpret:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[len_spec, q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(kv_len, q, k, v)
