"""Oracle for single-token decode attention over a long KV cache."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jax.Array,  # (B, Hq, hd) — one query token per sequence
    k: jax.Array,  # (B, Hkv, S, hd)
    v: jax.Array,  # (B, Hkv, S, hd)
    kv_len: jax.Array,  # (B,) or scalar — valid cache length
    *,
    window: Optional[int] = None,
) -> jax.Array:
    B, Hq, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    grp = Hq // Hkv
    qr = q.reshape(B, Hkv, grp, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhsd->bhgs", qr, k.astype(jnp.float32)) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32)
    )
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len), (B,))
    pos = jnp.arange(S)
    mask = pos[None] < kv_len[:, None]  # (B, S)
    if window is not None:
        q_pos = kv_len - 1
        mask &= pos[None] > (q_pos[:, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)
