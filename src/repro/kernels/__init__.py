"""Pallas TPU kernels for the perf-critical compute layers, each with a pure-jnp
ref.py oracle and a jit'd ops.py wrapper (interpret=True on CPU hosts)."""
