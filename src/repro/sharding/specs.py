"""Logical-axis → mesh-axis sharding rules for every (architecture × shape × mesh).

Parameters carry logical axes from their ParamDesc declarations; this module resolves
them to PartitionSpecs against the production mesh with divisibility-aware fallbacks:

  dim % axis == 0  -> shard
  dim >= axis      -> shard (GSPMD pads; waste < 2x — e.g. coder's 56 heads over 16)
  dim <  axis      -> replicate (e.g. 8 KV heads over model=16; tensors are small)

Training/prefill shard batch/client over ('pod','data') and tensor dims over 'model'.
Decode shards the KV cache *sequence* over 'model' (flash-decode style partial-softmax
combine); long_500k (B=1) shards the sequence over every mesh axis.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# §Perf experiment toggle: replicate (instead of head_dim-sharding) small KV
# projections — removes the per-layer q/kv resharding collective for GQA archs whose
# kv-head count is below the model-axis size. REPRO_KV_REPLICATE=1.
import os

_KV_REPLICATE = os.environ.get("REPRO_KV_REPLICATE", "0") == "1"

# logical axis -> preferred mesh axis (training / generic tensors)
AXIS_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "ssm_heads": "model",
    "head_dim": None,  # fallback target when the head axis cannot shard (see below)
    "layers": None,  # scan-stacked layer dim: never sharded
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _resolve_dim(mesh: Mesh, logical: Optional[str], dim: int) -> Optional[str]:
    if logical is None:
        return None
    target = AXIS_RULES.get(logical)
    if target is None or target not in mesh.axis_names:
        return None
    n = _axis_size(mesh, target)
    # exact divisibility only: jit *input* shardings reject GSPMD padding, so uneven
    # head counts (coder 56, llama4 40, whisper 20) go through the head_dim fallback.
    if dim % n == 0:
        return target
    return None


def choose_client_mapping(mesh: Mesh, param_count: int, hbm_bytes: float = 16 * 1024**3):
    """Photon client → mesh mapping (§5.1 / Algorithm 1 L.15-24).

    Every federated client holds a full model replica + AdamW state (~16 B/param in
    fp32). Small models: one client per ('pod','data') slice (max parallel clients,
    single-GPU-node analogue). Models too large for one model-parallel slice fall back
    to the paper's hierarchical mode: fewer clients, with the leftover data axis used
    INSIDE each client for FSDP + data parallelism (the Photon LLM Node's multi-machine
    FSDP pipeline).

    Returns (client_axes, fsdp_axes, n_clients).
    """
    candidates = []
    all_client = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    candidates.append((all_client, ()))
    if "pod" in mesh.axis_names:
        candidates.append((("pod",), ("data",)))
    candidates.append(((), all_client))
    state_bytes = param_count * 16.0  # fp32 params + m + v + pseudo-grad
    for client_axes_, fsdp_axes_ in candidates:
        n_c = int(np.prod([mesh.shape[a] for a in client_axes_])) if client_axes_ else 1
        chips_per_client = mesh.size // n_c
        budget = chips_per_client * hbm_bytes * 0.55  # rest for activations/temps
        if state_bytes <= budget:
            return client_axes_, fsdp_axes_, n_c
    return candidates[-1][0], candidates[-1][1], 1


def add_fsdp_axes(
    spec: P,
    shape: Tuple[int, ...],
    mesh: Mesh,
    fsdp_axes: Tuple[str, ...],
    logical_axes: Tuple[Optional[str], ...] = (),
) -> P:
    """ZeRO-style sharding: place the fsdp axes on the first unsharded NON-STACK dim
    whose size divides them (params are gathered per-layer at use; GSPMD inserts the
    all-gather after the scan's per-layer slice). The 'layers' scan dim must never be
    fsdp-sharded — that would broadcast a different shard every scan step."""
    if not fsdp_axes:
        return spec
    n = int(np.prod([mesh.shape[a] for a in fsdp_axes]))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    logical = list(logical_axes) + [None] * (len(shape) - len(logical_axes))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if logical[i] == "layers":
            continue
        if e is None and dim % n == 0 and dim >= n:
            entries[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
            return P(*entries)
    return spec  # nothing divisible: replicate (tiny tensors only)


def param_pspec(mesh: Mesh, axes: Tuple[Optional[str], ...], shape: Tuple[int, ...]) -> P:
    resolved = []
    used = set()
    for logical, dim in zip(axes, shape):
        ax = _resolve_dim(mesh, logical, dim)
        if ax in used:  # an axis can appear at most once in a PartitionSpec
            ax = None
        if ax is not None:
            used.add(ax)
        resolved.append(ax)
    # head-count too small to shard (e.g. gemma3's 8 heads over model=16): fall back
    # to sharding head_dim — RoPE then pays a halo exchange, but the attention
    # parameter mass stays distributed.
    if "model" not in used and "model" in mesh.axis_names:
        n = _axis_size(mesh, "model")
        head_axes = ("heads",) if _KV_REPLICATE else ("heads", "kv_heads")
        wants_model = any(a in head_axes for a in axes)
        if wants_model:
            for i, (logical, dim) in enumerate(zip(axes, shape)):
                if logical == "head_dim" and dim % n == 0:
                    resolved[i] = "model"
                    break
    return P(*resolved)


def client_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that the federated client dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_clients(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in client_axes(mesh)]))


# ---------------------------------------------------------------------------
# Pytree spec builders
# ---------------------------------------------------------------------------


def params_pspecs(mesh: Mesh, axes_tree, shapes_tree, fsdp_axes: Tuple[str, ...] = ()):
    """Parameter PartitionSpecs: sharded over 'model' per the logical axes, plus
    optional ZeRO/FSDP sharding over the given leftover axes."""
    return jax.tree_util.tree_map(
        lambda a, s: add_fsdp_axes(param_pspec(mesh, a, s), s, mesh, fsdp_axes, a),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x
        ),
    )


def params_shardings(mesh: Mesh, axes_tree, shapes_tree):
    specs = params_pspecs(mesh, axes_tree, shapes_tree)
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), specs, is_leaf=lambda x: isinstance(x, P)
    )


def clientize_pspec(mesh: Mesh, spec: P, client_axes_: Optional[Tuple[str, ...]] = None) -> P:
    """Prepend the client axis to a parameter spec (client-stacked params/opt state)."""
    ca = client_axes(mesh) if client_axes_ is None else client_axes_
    return P(ca if ca else None, *spec)


def clientize_tree(mesh: Mesh, spec_tree, client_axes_: Optional[Tuple[str, ...]] = None):
    return jax.tree_util.tree_map(
        lambda p: clientize_pspec(mesh, p, client_axes_), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Activations / inputs
# ---------------------------------------------------------------------------


def train_batch_pspec(mesh: Mesh, ndim: int) -> P:
    """Round batches (τ, C, B, ...): client dim over ('pod','data')."""
    return P(None, client_axes(mesh), *([None] * (ndim - 2)))


def central_batch_pspec(mesh: Mesh, ndim: int) -> P:
    """Centralized baseline batches (B, ...): batch over ('pod','data')."""
    return P(client_axes(mesh), *([None] * (ndim - 1)))


def decode_cache_pspec(mesh: Mesh, shape: Tuple[int, ...], kind: str, long_context: bool) -> P:
    """KV cache (B, S, Hkv, hd) / SSM state shardings for serving.

    kind: 'kv' (B,S,Hkv,hd) | 'conv' (B,W,C) | 'ssd' (B,nh,hd,ds) | 'cross' (B,F,H,hd)
    Caches inside scan-stacked segments carry a leading layer dim; callers prepend None.
    """
    ca = client_axes(mesh)
    if kind == "kv":
        B, S = shape[0], shape[1]
        if long_context or B < max(1, np.prod([mesh.shape[a] for a in ca])):
            # batch too small to shard: shard sequence over everything
            return P(None, ca + ("model",), None, None)
        return P(ca, "model", None, None)
    if kind == "cross":
        B = shape[0]
        return P(ca, None, None, None) if B >= n_clients(mesh) else P(*([None] * len(shape)))
    if kind == "conv":
        B = shape[0]
        lead = ca if B >= n_clients(mesh) else None
        return P(lead, None, "model" if shape[-1] % mesh.shape["model"] == 0 else None)
    if kind == "ssd":
        B = shape[0]
        lead = ca if B >= n_clients(mesh) else None
        nh = shape[1]
        return P(lead, "model" if nh % mesh.shape["model"] == 0 or nh >= mesh.shape["model"] else None, None, None)
    raise ValueError(kind)
