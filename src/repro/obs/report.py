"""``python -m repro.obs.report`` — turn a run's JSONL event logs into the
round table, straggler/staleness breakdown and fault-injection audit (PR 7).

Usage::

    python -m repro.obs.report TRACE_DIR [more.jsonl ...] \
        [--check] [--expect-faults] [--chrome out.json] [--json]

``--check`` validates the merged timeline's structural invariants and exits
nonzero on violation — CI runs it against the chaos demo's trace:

* every server **dispatch span is closed with a terminal outcome**
  (``admitted`` / ``rejected_stale`` / ``rejected`` / ``no_show`` /
  ``inflight_at_exit``) — a dispatch the server forgot about is a leaked slot;
* **no orphan dispatch ids**: every worker-side assignment span parents into
  an existing server dispatch span (the wire-propagated ids line up);
* **no silently-unclosed spans**: an open span is only excused when its exact
  process *incarnation* (proc, pid) logged a chaos ``kill`` fault — a crash
  may leave half-open spans, but then the crash itself must be in the audit;
* every **norm-visible injected payload corruption** (``corrupt_nan`` /
  ``corrupt_inf`` fault instants) was defended against — screened at the
  door, quarantined, dedup-dropped, or unwound by a later rollback
  (:func:`corruption_coverage`);
* with ``--expect-faults``: the audit is non-empty (chaos actually fired).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Sequence

from repro.metrics.fedmetrics import staleness_stats

from .events import Event, load_run, span_pairs
from .export import round_rollups, write_chrome_trace

#: outcomes a dispatch span may legally close with
TERMINAL_OUTCOMES = (
    "admitted", "rejected", "rejected_stale", "no_show", "inflight_at_exit",
    "quarantined",
)

#: corruption kinds the delta screen is REQUIRED to catch: they make the
#: delta norm non-finite, which the admission screen rejects unconditionally.
#: ``scale`` may legitimately pass during the screen's warmup window,
#: ``sign_flip`` is norm-invariant (a robust rule's problem, not the
#: screen's), and ``replay`` is a valid-looking stale payload (the
#: staleness/dedup machinery's problem) — none of those three can be audited
#: as must-screen.
SCREENABLE_CORRUPTIONS = ("nan", "inf")


def dispatch_table(events: Sequence[Event]) -> List[Dict[str, Any]]:
    """One row per dispatch index: the full lease/retry/redispatch lifecycle."""
    closed, opened = span_pairs(events)
    rows: Dict[str, Dict[str, Any]] = {}
    for sp in closed:
        if sp["name"] == "dispatch":
            rows[sp["span"]] = {
                "span": sp["span"],
                "index": sp["attrs"].get("index"),
                "client": sp["attrs"].get("client"),
                "version": sp["attrs"].get("version"),
                "outcome": sp["attrs"].get("outcome"),
                "staleness": sp["attrs"].get("staleness"),
                "dur": sp["dur"],
                "leases": [],
                "pushes": [],
                "workers": [],
            }
    for ev in opened:
        if ev.name == "dispatch":
            rows[ev.span] = {
                "span": ev.span,
                "index": ev.attrs.get("index"),
                "client": ev.attrs.get("client"),
                "version": ev.attrs.get("version"),
                "outcome": None,
                "staleness": None,
                "dur": None,
                "leases": [],
                "pushes": [],
                "workers": [],
            }
    for ev in events:
        if ev.ph != "i":
            continue
        span = f"d{ev.attrs.get('index')}"
        if span not in rows:
            continue
        if ev.name == "lease_grant":
            rows[span]["leases"].append(
                {
                    "worker": ev.attrs.get("worker"),
                    "regrant": bool(ev.attrs.get("regrant")),
                    "expired": bool(ev.attrs.get("expired")),
                }
            )
        elif ev.name == "push_recv":
            rows[span]["pushes"].append(
                {"worker": ev.attrs.get("worker"), "dup": bool(ev.attrs.get("dup"))}
            )
    for sp in closed:
        if sp["name"] == "assignment" and sp["parent"] in rows:
            rows[sp["parent"]]["workers"].append(f"{sp['proc']}:{sp['pid']}")
    return sorted(
        rows.values(), key=lambda r: (r["index"] if r["index"] is not None else -1)
    )


def fault_audit(events: Sequence[Event]) -> List[Dict[str, Any]]:
    """Every injected fault: who, what kind, when."""
    return [
        {"proc": ev.proc, "pid": ev.pid, "ts": ev.ts, **ev.attrs}
        for ev in events
        if ev.name == "fault" and ev.ph == "i"
    ]


def straggler_breakdown(events: Sequence[Event]) -> Dict[str, Any]:
    """Admitted-staleness histogram + dispatch-outcome counts + lease stats."""
    admits = [ev.attrs for ev in events if ev.name == "admit" and ev.ph == "i"]
    accepted = [a for a in admits if a.get("accepted")]
    table = dispatch_table(events)
    outcomes: Dict[str, int] = {}
    regrants = expiries = 0
    for row in table:
        key = row["outcome"] or "open"
        outcomes[key] = outcomes.get(key, 0) + 1
        regrants += sum(1 for l in row["leases"] if l["regrant"])
        expiries += sum(1 for l in row["leases"] if l["expired"])
    dups = sum(
        1 for ev in events
        if ev.name == "push_recv" and ev.ph == "i" and ev.attrs.get("dup")
    )
    out = staleness_stats([a.get("staleness", 0.0) for a in accepted])
    out.update(
        {
            "dispatches": len(table),
            "admitted": len(accepted),
            "rejected": len(admits) - len(accepted),
            "outcomes": outcomes,
            "lease_regrants": regrants,
            "lease_expiries": expiries,
            "dedup_drops": dups,
        }
    )
    return out


def corruption_coverage(events: Sequence[Event]) -> List[str]:
    """Audit that every *norm-visible* injected payload corruption (NaN/Inf —
    the kinds the delta screen must reject unconditionally) was actually
    defended against. A corruption at dispatch index ``i`` is accounted for
    when any of these holds:

    * a ``screen_reject`` instant exists for index ``i`` (the door caught it);
    * the dispatch closed with a non-``admitted`` outcome (quarantined sender,
      staleness rejection, the frame never arrived, still in flight at exit);
    * the dispatch saw duplicate pushes (redispatch raced a clean execution —
      first-result-wins may have admitted the clean twin, and the trace cannot
      tell which push carried the poison);
    * a ``rollback`` instant fires at or after the corruption (the divergence
      guard unwound whatever got through).

    A NaN/Inf corruption that was admitted with none of those excuses is a
    defense failure and fails ``--check``.
    """
    problems: List[str] = []
    screened = {
        ev.attrs.get("index")
        for ev in events
        if ev.name == "screen_reject" and ev.ph == "i"
    }
    rollbacks = [ev.ts for ev in events if ev.name == "rollback" and ev.ph == "i"]
    rows = {r["index"]: r for r in dispatch_table(events)}
    for ev in events:
        if ev.name != "fault" or ev.ph != "i":
            continue
        kind = str(ev.attrs.get("kind", ""))
        if not kind.startswith("corrupt_"):
            continue
        if kind[len("corrupt_"):] not in SCREENABLE_CORRUPTIONS:
            continue
        idx = ev.attrs.get("index")
        if idx in screened:
            continue
        row = rows.get(idx)
        if row is None or row["outcome"] != "admitted":
            continue
        if any(p["dup"] for p in row["pushes"]):
            continue
        if any(ts >= ev.ts for ts in rollbacks):
            continue
        problems.append(
            f"injected {kind} at dispatch index {idx} was ADMITTED with no "
            f"screen_reject, no quarantine, and no subsequent rollback"
        )
    return problems


def check_run(events: Sequence[Event], expect_faults: bool = False) -> List[str]:
    """Structural invariants of a merged timeline; returns human-readable
    problems (empty list == pass)."""
    problems: List[str] = []
    closed, opened = span_pairs(events)

    killed = {
        (ev.proc, ev.pid)
        for ev in events
        if ev.name == "fault" and ev.attrs.get("kind") == "kill"
    }
    for ev in opened:
        if (ev.proc, ev.pid) in killed:
            continue  # chaos-killed incarnation: half-open spans are the record
        problems.append(
            f"unclosed span {ev.span!r} ({ev.name}) in {ev.proc}:{ev.pid} "
            f"with no kill fault recorded for that incarnation"
        )

    dispatch_ids = {sp["span"] for sp in closed if sp["name"] == "dispatch"}
    dispatch_ids |= {ev.span for ev in opened if ev.name == "dispatch"}
    for sp in closed:
        if sp["name"] == "dispatch":
            outcome = sp["attrs"].get("outcome")
            if outcome not in TERMINAL_OUTCOMES:
                problems.append(
                    f"dispatch span {sp['span']!r} closed with non-terminal "
                    f"outcome {outcome!r}"
                )
    for sp in closed:
        if sp["name"] == "assignment" and sp["parent"] not in dispatch_ids:
            problems.append(
                f"orphan assignment span {sp['span']!r} in {sp['proc']}: "
                f"parent dispatch {sp['parent']!r} unknown to the server"
            )
    for ev in opened:
        if ev.name == "assignment" and ev.parent not in dispatch_ids:
            problems.append(
                f"orphan open assignment span {ev.span!r} in {ev.proc}: "
                f"parent dispatch {ev.parent!r} unknown to the server"
            )

    problems.extend(corruption_coverage(events))

    if expect_faults and not fault_audit(events):
        problems.append("expected injected faults but the audit is empty")
    return problems


def _fmt_table(rows: List[Dict[str, Any]], cols: List[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
    head = "  ".join(c.ljust(widths[c]) for c in cols)
    lines = [head, "  ".join("-" * widths[c] for c in cols)]
    for r in rows:
        lines.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="Summarize and validate a federation run's trace JSONL.",
    )
    ap.add_argument("sources", nargs="+", help="trace dir or .jsonl files")
    ap.add_argument("--check", action="store_true",
                    help="validate timeline invariants; exit 1 on violation")
    ap.add_argument("--expect-faults", action="store_true",
                    help="with --check: fail if no injected faults are recorded")
    ap.add_argument("--chrome", metavar="OUT",
                    help="also write a Chrome/Perfetto trace JSON")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of tables")
    args = ap.parse_args(argv)

    source = args.sources[0] if len(args.sources) == 1 else args.sources
    events = load_run(source)

    rollups = round_rollups(events)
    table = dispatch_table(events)
    faults = fault_audit(events)
    breakdown = straggler_breakdown(events)

    if args.chrome:
        write_chrome_trace(events, args.chrome)

    if args.json:
        print(json.dumps(
            {"rounds": rollups, "dispatches": table, "faults": faults,
             "breakdown": breakdown},
            indent=2, default=str,
        ))
    else:
        print(f"== events: {len(events)} ==")
        if rollups:
            print("\n== round table ==")
            cols = [c for c in ("round", "buf_count", "n_admitted", "n_rejected",
                                "staleness_mean", "staleness_admitted_max",
                                "train_loss", "sim_time", "deadline")
                    if any(c in r for r in rollups)]
            print(_fmt_table(rollups, cols))
        if table:
            print("\n== dispatch lifecycle ==")
            view = [
                {
                    "span": r["span"],
                    "client": r["client"],
                    "version": r["version"],
                    "outcome": r["outcome"] or "open",
                    "leases": len(r["leases"]),
                    "regrants": sum(1 for l in r["leases"] if l["regrant"]),
                    "pushes": len(r["pushes"]),
                    "dups": sum(1 for p in r["pushes"] if p["dup"]),
                }
                for r in table
            ]
            print(_fmt_table(view, ["span", "client", "version", "outcome",
                                    "leases", "regrants", "pushes", "dups"]))
        print("\n== straggler / staleness breakdown ==")
        for k, v in breakdown.items():
            print(f"  {k}: {v}")
        print(f"\n== fault audit ({len(faults)} injected) ==")
        for f in faults:
            print(f"  {f.get('kind', '?'):6s} {f['proc']}:{f['pid']} "
                  f"role={f.get('role', '?')}")

    if args.check:
        problems = check_run(events, expect_faults=args.expect_faults)
        if problems:
            print("\nCHECK FAILED:", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            return 1
        print("\ncheck: OK (all spans accounted for, no orphan dispatches)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
