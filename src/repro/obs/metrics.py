"""Prometheus-style text metrics endpoint for the federation server (PR 7).

``MetricsServer`` wraps stdlib ``http.server`` (zero dependencies) around a
:class:`~repro.obs.tracer.Tracer`'s live counters/gauges and serves them as
text exposition at ``/metrics``. The launcher starts it with
``--metrics-port`` (0 picks a free port, printed at startup).

Thread-safety contract: the HTTP handler runs on its own thread, so it may
only read the tracer's **plain-float** counter/gauge stores (mutated under the
tracer lock) and the ``extra()`` callback's plain-float dict. It must never
touch jax arrays — the aggregators donate their state buffers to the round
jits, and a donated buffer read from another thread is a deleted-buffer crash.
Everything numeric is therefore converted to host floats on the event-loop
thread *before* it lands in a gauge.

Staleness histogram: admitted deltas' ages are bucketed with the same edges
``metrics/fedmetrics.staleness_stats`` uses for its CSV histogram
(0 / 1 / ≤3 / ≤7 / +Inf), rendered cumulatively as a Prometheus histogram.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Dict, Optional

from repro.metrics.fedmetrics import _STALENESS_BUCKETS

from .tracer import Tracer

#: Cumulative upper edges of the admitted-staleness histogram, derived from
#: the fedmetrics bucket table so CSV rows and the endpoint tell one story.
STALENESS_EDGES = tuple(hi for _, hi in _STALENESS_BUCKETS if hi is not None)

METRIC_PREFIX = "fed_"


def observe_staleness(tracer: Tracer, staleness: float) -> None:
    """Record one admitted delta's age into the histogram counters."""
    if not tracer.enabled:
        return
    for edge in STALENESS_EDGES:
        if staleness <= edge:
            tracer.count(f"staleness_le_{edge}")
    tracer.count("staleness_le_inf")
    tracer.count("staleness_sum", float(staleness))


def render_metrics(
    tracer: Tracer,
    extra: Optional[Callable[[], Dict[str, float]]] = None,
    prefix: str = METRIC_PREFIX,
) -> str:
    """Render counters/gauges (+ extra gauges) as Prometheus text exposition."""
    snap = tracer.snapshot()
    lines = []

    hist = {k: v for k, v in snap["counters"].items() if k.startswith("staleness_le_")}
    plain = {k: v for k, v in snap["counters"].items()
             if not k.startswith(("staleness_le_", "staleness_sum"))}

    for name in sorted(plain):
        metric = f"{prefix}{name}_total"
        lines.append(f"# TYPE {metric} counter")
        lines.append(f"{metric} {plain[name]:g}")

    if hist:
        metric = f"{prefix}staleness_admitted_rounds"
        lines.append(f"# TYPE {metric} histogram")
        for edge in STALENESS_EDGES:
            lines.append(
                f'{metric}_bucket{{le="{edge}"}} {hist.get(f"staleness_le_{edge}", 0.0):g}'
            )
        total = hist.get("staleness_le_inf", 0.0)
        lines.append(f'{metric}_bucket{{le="+Inf"}} {total:g}')
        lines.append(f"{metric}_sum {snap['counters'].get('staleness_sum', 0.0):g}")
        lines.append(f"{metric}_count {total:g}")

    gauges = dict(snap["gauges"])
    if extra is not None:
        try:
            gauges.update({k: float(v) for k, v in extra().items()})
        except Exception:
            pass  # a flaky extras provider must not take down the endpoint
    for name in sorted(gauges):
        metric = f"{prefix}{name}"
        lines.append(f"# TYPE {metric} gauge")
        lines.append(f"{metric} {gauges[name]:g}")

    return "\n".join(lines) + "\n"


class MetricsServer:
    """Background HTTP server exposing ``/metrics`` for one tracer."""

    def __init__(
        self,
        tracer: Tracer,
        host: str = "127.0.0.1",
        port: int = 0,
        extra: Optional[Callable[[], Dict[str, float]]] = None,
    ):
        self.tracer = tracer
        self.extra = extra
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib API name)
                if self.path.rstrip("/") not in ("", "/metrics".rstrip("/"), "/metrics"):
                    self.send_error(404)
                    return
                body = render_metrics(outer.tracer, outer.extra).encode()
                self.send_response(200)
                self.send_header("Content-Type", "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-request stderr noise
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics", daemon=True
        )
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=2.0)
