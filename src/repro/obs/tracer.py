"""Zero-dependency in-process tracer (PR 7 tentpole).

One :class:`Tracer` instance per process. It owns three stores:

* an optional :class:`~repro.obs.events.JsonlSink` — every span boundary,
  instant and counter snapshot is appended as a structured event;
* thread-safe **counters** and **gauges** — the live signal plane the
  Prometheus-style metrics endpoint renders (``obs/metrics.py``);
* a bounded **ring buffer** of recent events — in-memory flight recorder for
  tests and debugging, never unbounded.

The disabled path is the contract that lets instrumentation live inside hot
loops: ``NULL_TRACER`` (and any ``Tracer(enabled=False)``) makes every method
a constant-time early return that allocates nothing, takes no lock, reads no
clock and touches no device value — guarded by the overhead test in
``tests/test_obs.py`` and, more importantly, by the bitwise-parity tests:
tracing on or off, the aggregation math produces identical bits because the
tracer only ever *reads* host-side floats the metrics path already computed.

Span identity is caller-supplied and deterministic (see ``obs/events.py``);
``begin``/``end`` are split so spans can cross call boundaries (a dispatch
span opens at dispatch and closes rounds later at admission), while ``span()``
wraps the common enclosed case.
"""
from __future__ import annotations

import threading
from collections import deque
from contextlib import contextmanager
from typing import Any, Callable, Dict, Optional

from .events import Event, JsonlSink, make_event


class Tracer:
    """Per-process trace/metrics recorder.

    Args:
        sink: event sink (``JsonlSink`` or anything with ``emit/flush/close``).
            ``None`` keeps counters/gauges/ring live with no file IO — what
            ``--metrics-port`` without ``--trace`` uses.
        proc: this process's role label (``"server"``, ``"w0"``, ...).
        trace_id: run id shared by all processes of one deployment
            (``launch/train.py`` derives it from the seed).
        enabled: ``False`` turns every method into a no-op.
        ring_size: bound on the in-memory flight recorder.
    """

    def __init__(
        self,
        sink: Optional[JsonlSink] = None,
        proc: str = "proc",
        trace_id: str = "trace",
        enabled: bool = True,
        ring_size: int = 4096,
    ):
        self.enabled = enabled
        self.sink = sink
        self.proc = proc
        self.trace_id = trace_id
        self._lock = threading.Lock()
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.ring: deque = deque(maxlen=ring_size)
        self._open_parents: Dict[str, Optional[str]] = {}

    # -- event plumbing ----------------------------------------------------
    def _emit(self, ev: Event) -> None:
        with self._lock:
            self.ring.append(ev)
        if self.sink is not None:
            self.sink.emit(ev)

    # -- spans -------------------------------------------------------------
    def begin(
        self,
        name: str,
        span_id: Optional[str] = None,
        parent: Optional[str] = None,
        **attrs: Any,
    ) -> str:
        """Open a span; returns its id (defaults to ``name``)."""
        if not self.enabled:
            return span_id or name
        sid = span_id or name
        with self._lock:
            self._open_parents[sid] = parent
        self._emit(
            make_event(name, "B", self.proc, self.trace_id, sid, parent, attrs)
        )
        return sid

    def end(self, span_id: str, **attrs: Any) -> None:
        """Close a span by id; ``attrs`` (e.g. the outcome) land on the E event."""
        if not self.enabled:
            return
        with self._lock:
            parent = self._open_parents.pop(span_id, None)
        self._emit(
            make_event("end", "E", self.proc, self.trace_id, span_id, parent, attrs)
        )

    @contextmanager
    def span(
        self,
        name: str,
        span_id: Optional[str] = None,
        parent: Optional[str] = None,
        **attrs: Any,
    ):
        """Context-manager form for spans enclosed in one call frame."""
        if not self.enabled:
            yield span_id or name
            return
        sid = self.begin(name, span_id, parent, **attrs)
        try:
            yield sid
        finally:
            self.end(sid)

    # -- instants / counters / gauges --------------------------------------
    def point(
        self, name: str, parent: Optional[str] = None, **attrs: Any
    ) -> None:
        """Record an instant event (lease grant, admit, fault, ...)."""
        if not self.enabled:
            return
        self._emit(
            make_event(name, "i", self.proc, self.trace_id, "", parent, attrs)
        )

    def count(self, name: str, delta: float = 1.0) -> None:
        """Increment a monotonic counter (rendered as ``*_total``)."""
        if not self.enabled:
            return
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + delta

    def gauge(self, name: str, value: float) -> None:
        """Set a point-in-time gauge. Callers pass plain host floats only —
        never jax arrays: gauges are read from the metrics HTTP thread, and a
        donated device buffer may already be deleted by then."""
        if not self.enabled:
            return
        with self._lock:
            self.gauges[name] = float(value)

    # -- lifecycle ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Consistent copy of counters + gauges (for the endpoint/tests)."""
        with self._lock:
            return {"counters": dict(self.counters), "gauges": dict(self.gauges)}

    def flush(self) -> None:
        """Push buffered events to disk — called before ``os._exit`` kills."""
        if self.sink is not None:
            self.sink.flush()

    def close(self) -> None:
        """Emit a final counter snapshot ("C" event) and close the sink."""
        if not self.enabled:
            return
        snap = self.snapshot()
        self._emit(
            make_event(
                "counters", "C", self.proc, self.trace_id, "", None,
                {"counters": snap["counters"], "gauges": snap["gauges"]},
            )
        )
        if self.sink is not None:
            self.sink.close()


class _NullTracer(Tracer):
    """The shared disabled tracer: importable, falsy-enabled, state-free."""

    def __init__(self):
        super().__init__(sink=None, proc="null", trace_id="null", enabled=False)


#: Module-level disabled tracer. Instrumented code defaults its ``tracer``
#: attribute to this so hot paths read one ``self.tracer.enabled`` bool (or
#: pay a single early-returning call) and nothing else.
NULL_TRACER = _NullTracer()


def get_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Normalize an optional tracer argument to a real instance."""
    return tracer if tracer is not None else NULL_TRACER
