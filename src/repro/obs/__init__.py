"""Federation telemetry: structured events, tracing, export, metrics (PR 7).

See ``docs/observability.md``. The subsystem is zero-dependency (stdlib only)
and strictly read-only with respect to the aggregation math: enabling tracing
leaves every result bitwise unchanged (tested in ``tests/test_obs.py``).
"""
from .events import (
    EVENT_SCHEMA_VERSION,
    Event,
    JsonlSink,
    decode_event,
    encode_event,
    load_run,
    make_event,
    read_events,
    span_pairs,
)
from .export import chrome_trace, round_rollups, write_chrome_trace
from .metrics import MetricsServer, observe_staleness, render_metrics
from .report import check_run, dispatch_table, fault_audit, straggler_breakdown
from .tracer import NULL_TRACER, Tracer, get_tracer

__all__ = [
    "EVENT_SCHEMA_VERSION",
    "Event",
    "JsonlSink",
    "MetricsServer",
    "NULL_TRACER",
    "Tracer",
    "check_run",
    "chrome_trace",
    "dispatch_table",
    "fault_audit",
    "straggler_breakdown",
    "decode_event",
    "encode_event",
    "get_tracer",
    "load_run",
    "make_event",
    "observe_staleness",
    "read_events",
    "render_metrics",
    "round_rollups",
    "span_pairs",
    "write_chrome_trace",
]
