"""Chrome trace-event export + per-round rollups (PR 7 tentpole).

``chrome_trace`` converts one run's merged event timeline into the Chrome
trace-event JSON format — load the output at ``ui.perfetto.dev`` (or
``chrome://tracing``) to see the federation as a waterfall:

* one **process** row per runtime process (server, w0, w1, ...; respawned
  incarnations of one role share the row but keep distinct pids in args);
* on the server, one **track** (thread row) per population client slot —
  track 0 carries run/round/flush spans, track ``1 + client`` carries that
  client's dispatch spans, so K concurrently-leased slots render as K
  parallel bars exactly like the simulator's Gantt intuition;
* workers render pull → train → push as nested bars on their own row.

Timestamps: Chrome wants microseconds. Bar *placement* uses the wall clock
(the cross-process axis); bar *width* uses the same-process monotonic delta
(the only valid duration source) — see ``obs/events.py``. Unclosed spans
(crash, still-in-flight at exit without finalization) are emitted with the
remainder of their process's observed timeline as width and tagged
``"unclosed": true`` rather than dropped: a crashed worker's half-open
assignment bar IS the signal.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from .events import Event, span_pairs

#: attrs key that assigns a span to a display track (thread row).
TRACK_ATTR = "track"


def _track(ev_attrs: Dict[str, Any]) -> int:
    try:
        return int(ev_attrs.get(TRACK_ATTR, 0))
    except (TypeError, ValueError):
        return 0


def chrome_trace(events: Iterable[Event]) -> Dict[str, Any]:
    """Merged event list → Chrome trace-event JSON object."""
    events = list(events)
    procs: List[str] = []
    for ev in events:
        if ev.proc not in procs:
            procs.append(ev.proc)
    pid_of = {p: i + 1 for i, p in enumerate(procs)}

    out: List[Dict[str, Any]] = []
    # process / thread naming metadata
    tracks_seen: Dict[tuple, None] = {}
    for p in procs:
        out.append(
            {
                "ph": "M",
                "name": "process_name",
                "pid": pid_of[p],
                "tid": 0,
                "args": {"name": p},
            }
        )

    closed, unclosed = span_pairs(events)
    # end of each process's observed timeline — width for unclosed spans
    last_mono: Dict[tuple, float] = {}
    for ev in events:
        key = (ev.proc, ev.pid)
        last_mono[key] = max(last_mono.get(key, ev.mono), ev.mono)

    def slice_event(
        name: str,
        proc: str,
        ts: float,
        dur: float,
        attrs: Dict[str, Any],
        span: str,
        extra: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        tid = _track(attrs)
        tracks_seen[(pid_of[proc], tid)] = None
        args = {k: v for k, v in attrs.items() if k != TRACK_ATTR}
        args["span"] = span
        if extra:
            args.update(extra)
        return {
            "ph": "X",
            "name": name,
            "pid": pid_of[proc],
            "tid": tid,
            "ts": ts * 1e6,
            "dur": max(dur, 0.0) * 1e6,
            "cat": "fed",
            "args": args,
        }

    for sp in closed:
        out.append(
            slice_event(
                sp["name"], sp["proc"], sp["ts"], sp["dur"], sp["attrs"], sp["span"]
            )
        )
    for ev in unclosed:
        dur = last_mono.get((ev.proc, ev.pid), ev.mono) - ev.mono
        out.append(
            slice_event(
                ev.name, ev.proc, ev.ts, dur, ev.attrs, ev.span,
                extra={"unclosed": True, "pid_real": ev.pid},
            )
        )
    for ev in events:
        if ev.ph == "i":
            tid = _track(ev.attrs)
            tracks_seen[(pid_of[ev.proc], tid)] = None
            out.append(
                {
                    "ph": "i",
                    "s": "p",  # process-scoped instant marker
                    "name": ev.name,
                    "pid": pid_of[ev.proc],
                    "tid": tid,
                    "ts": ev.ts * 1e6,
                    "cat": "fed",
                    "args": {k: v for k, v in ev.attrs.items() if k != TRACK_ATTR},
                }
            )

    for (pid, tid) in sorted(tracks_seen):
        name = "main" if tid == 0 else f"slot c{tid - 1}"
        out.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": pid,
                "tid": tid,
                "args": {"name": name},
            }
        )

    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(events: Iterable[Event], path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(chrome_trace(events), f)


def round_rollups(events: Iterable[Event]) -> List[Dict[str, Any]]:
    """Per-round rollup rows from the server's ``flush`` instants.

    Each flush instant already carries the host-side flush metrics row
    (round, buffer fill, staleness stats, mean train loss, sim time, whether
    it was a deadline flush); the rollup adds the admissions that fed it.
    """
    rows: List[Dict[str, Any]] = []
    admits_since: List[Dict[str, Any]] = []
    for ev in sorted(events, key=lambda e: (e.ts, e.mono)):
        if ev.name == "admit" and ev.ph == "i":
            admits_since.append(ev.attrs)
        elif ev.name == "flush" and ev.ph == "i":
            row = dict(ev.attrs)
            accepted = [a for a in admits_since if a.get("accepted")]
            row["n_admitted"] = len(accepted)
            row["n_rejected"] = len(admits_since) - len(accepted)
            stal = [a.get("staleness", 0.0) for a in accepted]
            row["staleness_admitted_max"] = max(stal) if stal else 0.0
            rows.append(row)
            admits_since = []
    return rows
