"""Versioned structured-event schema + append-only JSONL sink (PR 7 tentpole).

One :class:`Event` is one fact about the federation runtime: a span boundary
(``ph`` = ``"B"``/``"E"``), an instant (``"i"``) or a counter snapshot
(``"C"``). Every event carries BOTH clocks:

* ``ts`` — wall-clock ``time.time()`` seconds. The only clock that is
  comparable ACROSS processes (all runtime processes share a host), so the
  merged timeline and the Chrome-trace export order events by it.
* ``mono`` — ``time.perf_counter()`` seconds. Monotonic but per-process, so it
  is the only clock DURATIONS may be computed from (span duration =
  ``E.mono − B.mono`` within one process; never across processes).

Identity: ``trace`` names the run (derived from the seed — every process of
one deployment shares it), ``span`` names the unit of work and ``parent``
links it upward. Span ids are DETERMINISTIC, keyed by the federation's own
coordinates rather than random uuids: the server's round span is
``u{version}``, a dispatched slot's span is ``d{index}`` (the dispatch cursor
— the same idempotency key the lease/redispatch machinery uses), and a
worker's execution of that slot is ``d{index}@{worker}``. Determinism is what
lets three processes' logs merge into one coherent tree with no id handshake:
the ids ride the wire (``runtime/transport`` frame meta) only so a worker
never has to re-derive them.

Durability discipline (the checkpoint module's atomic-write pattern, adapted
to an append-only log): ``os.replace`` cannot commit individual appends, so
the commit point moves to the LINE — each event is serialized to one complete
``\\n``-terminated line and handed to the OS in ONE buffered-write + flush.
A crash (chaos ``os._exit`` included) can therefore tear at most the final
line of a file; :func:`read_events` silently drops a torn TRAILING line but
raises loudly on a corrupt interior line, which can only mean real file
damage — the same "complete or absent, never silently wrong" contract the
checkpoint manifests give resume.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

#: Version tag of the event schema. Bump on incompatible layout changes;
#: :func:`decode_event` refuses a mismatched tag instead of misreading records.
EVENT_SCHEMA_VERSION = 1

#: Allowed event phases (Chrome-trace vocabulary, the subset we emit):
#: span begin / span end / instant / counter snapshot.
PHASES = ("B", "E", "i", "C")


@dataclass
class Event:
    name: str  # what happened ("dispatch", "flush", "fault", ...)
    ph: str  # phase: "B" | "E" | "i" | "C"
    ts: float  # wall clock (time.time) — cross-process ordering
    mono: float  # perf_counter — same-process durations ONLY
    proc: str  # process role ("server", "w0", ...)
    pid: int  # os pid: distinguishes respawned incarnations of one role
    trace: str  # run id (shared by every process of one deployment)
    span: str = ""  # span id ("" for bare instants)
    parent: Optional[str] = None  # parent span id
    attrs: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.ph not in PHASES:
            raise ValueError(f"event phase {self.ph!r} not in {PHASES}")


def encode_event(ev: Event) -> Dict[str, Any]:
    """Event → plain-JSON dict (schema-versioned)."""
    return {
        "v": EVENT_SCHEMA_VERSION,
        "name": ev.name,
        "ph": ev.ph,
        "ts": ev.ts,
        "mono": ev.mono,
        "proc": ev.proc,
        "pid": ev.pid,
        "trace": ev.trace,
        "span": ev.span,
        "parent": ev.parent,
        "attrs": ev.attrs,
    }


def decode_event(d: Dict[str, Any]) -> Event:
    """Inverse of :func:`encode_event`; refuses unknown schema versions."""
    v = d.get("v")
    if v != EVENT_SCHEMA_VERSION:
        raise ValueError(
            f"event schema version {v!r} != supported {EVENT_SCHEMA_VERSION}"
        )
    return Event(
        name=d["name"],
        ph=d["ph"],
        ts=float(d["ts"]),
        mono=float(d["mono"]),
        proc=d["proc"],
        pid=int(d["pid"]),
        trace=d["trace"],
        span=d.get("span", ""),
        parent=d.get("parent"),
        attrs=d.get("attrs", {}),
    )


def make_event(
    name: str,
    ph: str,
    proc: str,
    trace: str,
    span: str = "",
    parent: Optional[str] = None,
    attrs: Optional[Dict[str, Any]] = None,
) -> Event:
    """Stamp an event with both clocks and this process's pid."""
    return Event(
        name=name,
        ph=ph,
        ts=time.time(),
        mono=time.perf_counter(),
        proc=proc,
        pid=os.getpid(),
        trace=trace,
        span=span,
        parent=parent,
        attrs=attrs or {},
    )


class JsonlSink:
    """Append-only JSONL event sink, one complete line per event.

    Thread-safe (the socket server emits from accept/serve threads). Opened in
    append mode so a respawned worker incarnation extends the same file — the
    ``pid`` field keeps incarnations distinguishable. ``flush()`` pushes
    buffered lines to the OS; the chaos monkey calls it before ``os._exit`` so
    a kill's own fault event survives the kill.
    """

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._lock = threading.Lock()
        # line-buffered text append: one write() per complete line below
        self._f = open(path, "a", encoding="utf-8")

    def emit(self, ev: Event) -> None:
        line = json.dumps(encode_event(ev), separators=(",", ":")) + "\n"
        with self._lock:
            if self._f.closed:
                return  # post-close stragglers (daemon threads) drop silently
            self._f.write(line)  # ONE write: the line is the commit unit

    def flush(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                os.fsync(self._f.fileno())

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()


def read_events(path: str) -> List[Event]:
    """Parse one process's JSONL event log.

    A torn TRAILING line (crash mid-append — the one tear the line-commit
    discipline permits) is dropped silently; an unparseable INTERIOR line
    means real corruption and raises with the line number.
    """
    out: List[Event] = []
    with open(path, encoding="utf-8") as f:
        lines = f.read().split("\n")
    # a complete file ends with "\n" → last split element is ""; anything else
    # in the final slot is a torn tail
    body, tail = lines[:-1], lines[-1]
    for i, line in enumerate(body):
        if not line:
            continue
        try:
            out.append(decode_event(json.loads(line)))
        except (json.JSONDecodeError, KeyError, ValueError) as e:
            raise ValueError(f"{path}:{i + 1}: corrupt event line: {e}") from e
    if tail:
        try:
            out.append(decode_event(json.loads(tail)))
        except (json.JSONDecodeError, KeyError, ValueError):
            pass  # torn tail: the event never committed
    return out


def load_run(source: Union[str, Sequence[str]]) -> List[Event]:
    """Merge one run's event files into a single wall-clock-ordered timeline.

    ``source`` is a directory (every ``*.jsonl`` inside) or an explicit list
    of files. The sort is stable on (ts, mono) so same-process order survives
    wall-clock ties.
    """
    if isinstance(source, str):
        if os.path.isdir(source):
            paths = sorted(
                os.path.join(source, n)
                for n in os.listdir(source)
                if n.endswith(".jsonl")
            )
        else:
            paths = [source]
    else:
        paths = list(source)
    if not paths:
        raise FileNotFoundError(f"no .jsonl event files under {source!r}")
    events: List[Event] = []
    for p in paths:
        events.extend(read_events(p))
    events.sort(key=lambda e: (e.ts, e.mono))
    return events


def span_pairs(events: Iterable[Event]):
    """Pair B/E events into completed spans; return ``(closed, open)``.

    A closed span is a dict ``{name, span, parent, proc, pid, ts, dur, attrs}``
    with ``dur`` from the SAME process's monotonic clock and ``attrs`` the
    union of begin- and end-attrs (end wins — that is where outcomes land).
    Open spans are the unmatched B events. Spans are keyed by
    ``(proc, pid, span)``: a respawned incarnation re-opening a span id never
    closes its dead predecessor's.
    """
    opened: Dict[tuple, Event] = {}
    closed: List[Dict[str, Any]] = []
    for ev in events:
        key = (ev.proc, ev.pid, ev.span)
        if ev.ph == "B":
            opened[key] = ev
        elif ev.ph == "E":
            b = opened.pop(key, None)
            if b is None:
                continue  # E without B: dropped begin (pre-attach) — ignore
            closed.append(
                {
                    "name": b.name,
                    "span": b.span,
                    "parent": b.parent,
                    "proc": b.proc,
                    "pid": b.pid,
                    "ts": b.ts,
                    "dur": max(0.0, ev.mono - b.mono),
                    "attrs": {**b.attrs, **ev.attrs},
                }
            )
    return closed, list(opened.values())
