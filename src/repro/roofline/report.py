"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from results/dryrun/*.json."""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "granite-3-2b", "qwen3-1.7b", "mamba2-1.3b", "jamba-v0.1-52b", "deepseek-moe-16b",
    "llama4-scout-17b-a16e", "whisper-large-v3", "chameleon-34b", "deepseek-coder-33b",
    "gemma3-4b",
]


def load(results_dir: str, tag_filter: str = "", include_tagged: bool = False) -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        name = os.path.basename(p)[: -len(".json")]
        parts = name.split("__")
        is_tagged = len(parts) > 4 or (len(parts) == 4 and parts[3] not in ("federated", "centralized"))
        if is_tagged and not include_tagged:
            continue
        with open(p) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(p)
        if tag_filter and tag_filter not in r["_file"]:
            continue
        rows.append(r)
    return rows


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def _key(r):
    a = r.get("arch", "")
    s = r.get("shape", "")
    return (
        ARCH_ORDER.index(a) if a in ARCH_ORDER else 99,
        SHAPE_ORDER.index(s) if s in SHAPE_ORDER else 99,
        r.get("multi_pod", False),
        r.get("mode", ""),
    )


def dryrun_table(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | mesh | mode | per-dev peak mem | compile | collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=_key):
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        colls = " ".join(f"{k}:{int(v)}" for k, v in sorted(r.get("collective_counts", {}).items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r.get('mode','serve')} "
            f"| {fmt_bytes(r.get('peak_memory_per_device'))} "
            f"| {r.get('compile_s', r.get('meta', {}).get('compile_s', 0)):.0f}s | {colls} |"
        )
    return "\n".join(out)


def roofline_table(rows: List[Dict], single_pod_only: bool = True) -> str:
    out = [
        "| arch | shape | mode | t_compute | t_memory | t_collective | bottleneck "
        "| 6·N_act·D | useful-FLOP ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=_key):
        if single_pod_only and r.get("multi_pod"):
            continue
        ratio = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mode','serve')} "
            f"| {r['t_compute_s']:.4f}s | {r['t_memory_s']:.4f}s "
            f"| {r['t_collective_s']:.4f}s | **{r['bottleneck']}** "
            f"| {r.get('model_flops', 0):.2e} "
            f"| {ratio:.2f} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {r.get('mode','serve')} "
            f"| {r['t_compute_s']:.4f}s | {r['t_memory_s']:.4f}s "
            f"| {r['t_collective_s']:.4f}s | **{r['bottleneck']}** | - | - |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--which", default="both", choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    rows = load(args.dir)
    rows = [r for r in rows if "__" not in r["_file"].split(".json")[0].split("__", 4)[-1] or True]
    if args.which in ("dryrun", "both"):
        print("### Dry-run table\n")
        print(dryrun_table(rows))
        print()
    if args.which in ("roofline", "both"):
        print("### Roofline table (single-pod 16x16)\n")
        print(roofline_table(rows))


if __name__ == "__main__":
    main()
