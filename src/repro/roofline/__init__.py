from repro.roofline.analysis import (  # noqa: F401
    RooflineReport,
    analyze_compiled,
    model_flops_6nd,
    parse_collectives,
)
from repro.roofline.hlo_analyzer import analyze as analyze_hlo_text  # noqa: F401
