"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, so any graph with
``lax.scan`` (layer stacks, local-step loops, grad accumulation, chunked attention/CE)
is undercounted by the product of trip counts. This module parses the optimized HLO
text, builds the computation call graph, and accumulates FLOPs / bytes / collective
traffic with each while body weighted by its ``known_trip_count`` backend config.

Counting rules (validated against cost_analysis() on scan-free graphs in tests):
  dot          2 x prod(result dims) x prod(contracting dims)
  elementwise  1 x result elements (incl. transcendentals)
  reduce       1 x operand elements
  bytes        operand + result bytes of every non-trivial top-level op; fusion
               bodies contribute FLOPs but only their boundary contributes bytes
               (fusion boundaries are the buffers that actually hit HBM)
  collectives  result bytes (x2 for all-reduce: ring RS+AG), weighted by trip count
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(pred|[sufc]\d+|bf16)\[([\d,]*)\]")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh", "rsqrt",
    "sqrt", "negate", "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "compare", "select", "and", "or", "xor", "not",
    "clamp", "atan2", "cosine", "sine", "logistic", "cbrt", "erf", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "bitcast-convert",
    "after-all", "opt-barrier", "partition-id", "replica-id", "custom-call",
    "get-dimension-size",
}
COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
}


def _parse_arrays(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _ARRAY_RE.findall(type_str):
        shape = [int(d) for d in dims.split(",")] if dims else []
        out.append((dtype, shape))
    return out


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, shape in _parse_arrays(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _type_elems(type_str: str) -> float:
    total = 0.0
    for _, shape in _parse_arrays(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


@dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)  # name -> type
    instrs: List[Instruction] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # symbol table


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\((.*)\)\s*->\s*.+\{\s*$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_COMMENT = re.compile(r"/\*.*?\*/")


def _balanced(s: str, open_idx: int) -> int:
    """Index of the paren matching s[open_idx]."""
    depth = 0
    for i in range(open_idx, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_instr(line: str) -> Optional[Instruction]:
    s = line.strip()
    is_root = s.startswith("ROOT ")
    if is_root:
        s = s[5:].lstrip()
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip().lstrip("%")
    rest = s[eq + 3 :].lstrip()
    if rest.startswith("("):  # tuple result type
        close = _balanced(rest, 0)
        rtype, rest2 = rest[: close + 1], rest[close + 1 :].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype, rest2 = rest[:sp], rest[sp + 1 :].lstrip()
    par = rest2.find("(")
    if par <= 0:
        return None
    opcode = rest2[:par].strip()
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    close = _balanced(rest2, par)
    operands_str = rest2[par + 1 : close]
    attrs = rest2[close + 1 :]
    operands = [t.lstrip("%") for t in re.findall(r"%[\w\.\-]+", operands_str)]
    return Instruction(name, rtype, opcode, operands, attrs, is_root)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = _COMMENT.sub("", raw.rstrip())
        if cur is None:
            stripped = line.strip()
            m = _COMP_HEADER.match(stripped)
            if m:
                name = m.group(1).lstrip("%")
                cur = Computation(name=name)
                if stripped.startswith("ENTRY"):
                    entry = name
                for pm in re.finditer(
                    r"([\w\.\-]+)\s*:\s*((?:\([^)]*\)|[^,()]+))", m.group(2)
                ):
                    cur.params[pm.group(1)] = pm.group(2)
                    cur.types[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        instr = _parse_instr(line)
        if instr is not None:
            cur.instrs.append(instr)
            cur.types[instr.name] = instr.result_type
    return comps, entry


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)


def _dot_flops(instr: Instruction, comp: Computation) -> float:
    out_elems = _type_elems(instr.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    contract = 1.0
    if m and instr.operands:
        lhs_type = comp.types.get(instr.operands[0], "")
        arrays = _parse_arrays(lhs_type)
        if arrays:
            shape = arrays[0][1]
            for d in (m.group(1).split(",") if m.group(1) else []):
                di = int(d)
                if di < len(shape):
                    contract *= shape[di]
    return 2.0 * out_elems * contract


def _coll_multiplier(opcode: str) -> float:
    return 2.0 if opcode == "all-reduce" else 1.0


def analyze(text: str, debug_rows: Optional[list] = None) -> HloCost:
    comps, entry = parse_hlo(text)
    cache: Dict[Tuple[str, bool], HloCost] = {}

    def visit(name: str, inside_fusion: bool) -> HloCost:
        key = (name, inside_fusion)
        if key in cache:
            return cache[key]
        comp = comps.get(name)
        total = HloCost()
        if comp is None:
            cache[key] = total
            return total
        for ins in comp.instrs:
            op = ins.opcode
            base = op.replace("-start", "").replace("-done", "")
            if op.endswith("-done"):
                continue
            if base in COLLECTIVES:
                b = _type_bytes(ins.result_type) * _coll_multiplier(base)
                if base == "reduce-scatter" and ins.operands:
                    ob = _type_bytes(comp.types.get(ins.operands[0], ""))
                    b = ob if ob else b
                total.collective_bytes += b
                total.coll_by_kind[base] = total.coll_by_kind.get(base, 0.0) + b
                total.coll_counts[base] = total.coll_counts.get(base, 0.0) + 1
                total.bytes += _type_bytes(ins.result_type)
                continue
            if op == "while":
                trips = 1.0
                m = _TRIP.search(ins.attrs)
                if m:
                    trips = float(m.group(1))
                body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                if body:
                    sub = visit(body.group(1), False)
                    total.flops += trips * sub.flops
                    total.bytes += trips * sub.bytes
                    total.collective_bytes += trips * sub.collective_bytes
                    for k, v in sub.coll_by_kind.items():
                        total.coll_by_kind[k] = total.coll_by_kind.get(k, 0.0) + trips * v
                    for k, v in sub.coll_counts.items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0.0) + trips * v
                continue
            if op == "fusion":
                result_b = _type_bytes(ins.result_type)
                m = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
                if m:
                    sub = visit(m.group(1), True)
                    total.flops += sub.flops  # flops inside the fusion
                    # Fusions that thread a large buffer through the loop via
                    # dynamic-update-slice only touch the update slice: if any DUS
                    # inside produces a buffer ~the size of the fusion result, count
                    # the update slice instead of the whole buffer.
                    sub_comp = comps.get(m.group(1))
                    if sub_comp is not None:
                        for fi in sub_comp.instrs:
                            if (
                                fi.opcode == "dynamic-update-slice"
                                and len(fi.operands) > 1
                                and _type_bytes(fi.result_type) >= 0.5 * result_b
                            ):
                                upd = _type_bytes(sub_comp.types.get(fi.operands[1], ""))
                                result_b = min(result_b, max(upd, 1.0))
                                break
                # bytes at the fusion boundary; operands larger than 4x the result
                # are threaded/sliced buffers — count them as slice-sized.
                cap = max(result_b, 1.0) * 4.0
                total.bytes += result_b + sum(
                    min(_type_bytes(comp.types.get(o, "")), cap) for o in ins.operands
                )
                continue
            if op in ("call", "async-start"):
                m = re.search(r"(?:to_apply|called_computation)=%?([\w\.\-]+)", ins.attrs)
                if m:
                    sub = visit(m.group(1), inside_fusion)
                    total.flops += sub.flops
                    total.bytes += sub.bytes
                    total.collective_bytes += sub.collective_bytes
                    for k, v in sub.coll_by_kind.items():
                        total.coll_by_kind[k] = total.coll_by_kind.get(k, 0.0) + v
                    for k, v in sub.coll_counts.items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0.0) + v
                continue
            if op == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                if m:
                    subs = [visit(n.strip().lstrip("%"), inside_fusion)
                            for n in m.group(1).split(",")]
                    if subs:
                        best = max(subs, key=lambda s: s.flops)
                        total.flops += best.flops
                        total.bytes += best.bytes
                        total.collective_bytes += best.collective_bytes
                continue
            if op in ZERO_COST:
                continue
            # --- plain ops ---
            if op == "dot":
                total.flops += _dot_flops(ins, comp)
            elif op == "reduce" or op == "reduce-window":
                total.flops += sum(
                    _type_elems(comp.types.get(o, "")) for o in ins.operands[: 1]
                )
            elif op in ELEMENTWISE:
                total.flops += _type_elems(ins.result_type)
            # bytes: only at top level (inside fusions buffers stay in registers/VMEM)
            if not inside_fusion:
                if op == "dynamic-update-slice":
                    upd = (
                        _type_bytes(comp.types.get(ins.operands[1], ""))
                        if len(ins.operands) > 1
                        else 0.0
                    )
                    total.bytes += 2.0 * upd  # read + write the touched slice only
                elif op == "dynamic-slice":
                    total.bytes += 2.0 * _type_bytes(ins.result_type)
                else:
                    total.bytes += _type_bytes(ins.result_type) + sum(
                        _type_bytes(comp.types.get(o, "")) for o in ins.operands
                    )
        cache[key] = total
        return total

    if entry is None:
        return HloCost()
    return visit(entry, False)
