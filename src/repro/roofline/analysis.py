"""Roofline analysis from compiled XLA artifacts (no hardware required).

Extracts, per compiled step:
  - HLO FLOPs and bytes from ``compiled.cost_analysis()``
  - collective traffic by parsing the post-SPMD HLO text for
    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute ops

and derives the three roofline terms for TPU v5e:
    compute    = HLO_FLOPs / (chips x 197e12)
    memory     = HLO_bytes / (chips x 819e9)
    collective = collective_bytes / (chips x 50e9)

Byte conventions (documented; consistent across all rows so ratios are meaningful):
  all-reduce         2 x result bytes   (ring reduce-scatter + all-gather)
  all-gather         1 x result bytes
  reduce-scatter     1 x operand bytes  (== result x shards)
  all-to-all         1 x result bytes
  collective-permute 1 x result bytes

``cost_analysis()`` on an SPMD-partitioned module reports the PER-DEVICE program, so
``flops``/``bytes`` are per chip; the fleet totals multiply by ``chips``. The roofline
terms below therefore divide per-device quantities by per-chip peaks directly.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[sufbc]\w*?\d+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)

_MULTIPLIER = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of all array shapes in an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum weighted operand/result bytes of every collective in the HLO module.

    Skips -done ops (the -start carries the shape) to avoid double counting async
    pairs; plain (synchronous) ops are counted once.
    """
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str) * _MULTIPLIER[kind]
        if kind == "reduce-scatter":
            # convention: operand bytes; result bytes x shard count ~= operand.
            # parse the operand shapes from inside the parens instead
            inner = line[m.end():]
            ob = _shape_bytes(inner.split(")")[0])
            b = float(ob) if ob else b
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class RooflineReport:
    name: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_detail: Dict[str, float]
    collective_counts: Dict[str, int]
    model_flops: Optional[float] = None  # 6*N*D fleet-wide
    peak_memory_per_device: Optional[float] = None
    extra: Dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops is None:
            return None
        fleet = self.flops_per_device * self.chips
        return self.model_flops / fleet if fleet else None

    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_detail": self.collective_detail,
            "collective_counts": self.collective_counts,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_memory_per_device": self.peak_memory_per_device,
            **self.extra,
        }


def analyze_compiled(name: str, compiled, chips: int, model_flops: Optional[float] = None,
                     extra: Optional[Dict] = None) -> RooflineReport:
    from repro.roofline.hlo_analyzer import analyze as hlo_analyze

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some jax versions return [dict]
        cost = cost[0]
    text = compiled.as_text()
    hlo = hlo_analyze(text)  # trip-count-aware (XLA counts while bodies once)
    flops = hlo.flops
    byts = hlo.bytes
    stats = CollectiveStats(
        bytes_by_kind=dict(hlo.coll_by_kind),
        count_by_kind={k: int(v) for k, v in hlo.coll_counts.items()},
    )
    extra = dict(extra or {})
    extra["xla_cost_analysis"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    mem = compiled.memory_analysis()
    peak = None
    if mem is not None:
        peak = float(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "generated_code_size_in_bytes", 0)
        )
        # avoid double counting aliased (donated) buffers
        peak -= float(getattr(mem, "alias_size_in_bytes", 0))
    return RooflineReport(
        name=name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=stats.total_bytes,
        collective_detail=stats.bytes_by_kind,
        collective_counts=stats.count_by_kind,
        model_flops=model_flops,
        peak_memory_per_device=peak,
        extra=extra,
    )


def model_flops_6nd(n_params_active: int, n_tokens: int, train: bool = True) -> float:
    """6·N·D for a train step (fwd+bwd); 2·N·D for inference."""
    return (6.0 if train else 2.0) * n_params_active * n_tokens
