"""Asynchronous buffered aggregation — Photon's FedBuff-style aggregator.

The synchronous round (``core/federated.py``) discards every straggler's work at
the deadline: a client that misses the cut is masked to weight zero and its τ
local steps are wasted. Photon (arXiv 2411.02908) instead runs the aggregator
*asynchronously*: clients pull the current global model whenever they become
free, train at their own speed, and push their pseudo-gradient whenever they
finish — the server **buffers** incoming deltas and applies one outer update per
``M`` buffered deltas (FedBuff, Nguyen et al. 2022). Slow clients land in later
buffers instead of being dropped.

Mapping to Photon's aggregator, implemented here:

  ================================  =============================================
  Photon / FedBuff concept          This module
  ================================  =============================================
  model version ``t`` on server     ``state['round']`` — bumped once per flush
  client trains against version t'  delta *tag* ``client_round`` (the round the
                                    pseudo-gradient was computed against)
  staleness ``s = t − t'``          computed at admission, never trusted from the
                                    client (a flush mid-batch increases the
                                    staleness of later arrivals automatically)
  staleness discount                ``w̃ = w / (1 + s)^α`` (:func:`staleness_discount`,
                                    FedBuff's polynomial discount; α=0 disables)
  buffer of K deltas, update at K   fixed-capacity (M, ...) delta buffer +
                                    ``buf_count``; flush triggered at ``M``
  stale-update rejection            ``max_staleness`` — older deltas are refused
                                    at admission (their slot is never consumed)
  server update on the buffer       :func:`flush_buffer` → the *same*
                                    ``apply_aggregate`` as the sync round
  ================================  =============================================

Everything is a pure, jittable function of ``(state, deltas, tags, weights)``:
the buffer, its weights/staleness lanes and the fill counter live inside the
state pytree, so async training state round-trips through the checkpoint
manager and resume is exact — the same property the sync round gets from the
pure participation sampler.

Because :func:`flush_buffer` reuses ``apply_aggregate`` and clients run the
shared ``run_clients`` phase, the async path with ``buffer_size == K``,
``staleness_alpha == 0`` and all clients completing in-round reproduces the
synchronous ``federated_round`` *bitwise* (tested).

The host-side event loop (:class:`AsyncFederationDriver`) replays a simulated
timeline from the participation layer's persistent-speed straggler model
(:class:`~repro.core.sampler.AsyncTimeline`): the heap carries (completion-time,
params-snapshot) pairs, the jitted client phase runs when a client "finishes",
and the admission order — hence the whole run — is a deterministic function of
``(config, seed)``.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import Codec
from repro.core.federated import (
    FederatedConfig,
    apply_aggregate,
    init_federated_state,
    init_uplink_residuals,
    run_clients,
)
from repro.core.inner_opt import global_norm
from repro.core.sampler import AsyncTimeline, ParticipationConfig


@dataclass(frozen=True)
class AsyncAggConfig:
    buffer_size: int = 4  # M — deltas per outer update (FedBuff's K)
    staleness_alpha: float = 0.5  # discount exponent; 0 = no discount
    max_staleness: int = 0  # reject deltas older than this (0 = accept any age)

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.staleness_alpha < 0.0:
            raise ValueError(f"staleness_alpha must be >= 0, got {self.staleness_alpha}")
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got {self.max_staleness}")


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def init_async_state(
    fed: FederatedConfig,
    acfg: AsyncAggConfig,
    params,
    rng: Optional[jax.Array] = None,
) -> Dict[str, Any]:
    """Server state = the sync server state + the delta buffer lanes.

    ``round`` doubles as the server *model version*: it increments once per
    flush, and arriving deltas measure their staleness against it. Buffer slots
    beyond ``buf_count`` hold zero weight, so a partially filled buffer
    aggregates correctly and the whole state round-trips through
    ``checkpoint.save_pytree`` unchanged.
    """
    state = init_federated_state(
        replace(fed, keep_inner_state=False), params, rng
    )  # async clients are stateless (paper §7.8) — no persisted inner lanes
    m = acfg.buffer_size
    state["buffer"] = jax.tree_util.tree_map(
        lambda p: jnp.zeros((m,) + p.shape, jnp.float32), params
    )
    state["buf_weights"] = jnp.zeros((m,), jnp.float32)
    state["buf_staleness"] = jnp.zeros((m,), jnp.float32)
    state["buf_count"] = jnp.zeros((), jnp.int32)
    return state


def staleness_discount(weight, staleness, alpha: float):
    """FedBuff's polynomial staleness discount: w̃ = w / (1 + s)^α.

    Monotone non-increasing in s for α ≥ 0 (property-tested); α = 0 returns the
    weight bitwise-unchanged ((1+s)^0 = 1.0 exactly), which is what makes the
    sync-equivalence identity exact.
    """
    s = jnp.maximum(staleness.astype(jnp.float32), 0.0)
    return weight.astype(jnp.float32) / (1.0 + s) ** alpha


# ---------------------------------------------------------------------------
# Admission + flush — pure (state, deltas, tags, weights) → state
# ---------------------------------------------------------------------------


def flush_buffer(
    fed: FederatedConfig, acfg: AsyncAggConfig, state: Dict[str, Any]
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Apply one outer update from the buffered deltas and reset the buffer.

    Delegates to the sync round's ``apply_aggregate`` with the buffer as the
    client axis and the discounted weights as the elastic weight vector —
    weighted mean → optional DP noise → outer update → version += 1. Empty slots
    carry zero weight, so a partial (forced) flush aggregates only what arrived.
    """
    core = {k: state[k] for k in ("params", "outer", "round", "rng")}
    new_core, metrics = apply_aggregate(
        fed, core, state["buffer"], client_weights=state["buf_weights"]
    )
    count = state["buf_count"].astype(jnp.float32)
    metrics = dict(
        metrics,
        buffer_fill=count,
        buffer_occupancy=count / float(acfg.buffer_size),
        staleness_mean=jnp.sum(state["buf_staleness"]) / jnp.maximum(count, 1.0),
        staleness_max=jnp.max(state["buf_staleness"]),
    )
    new_state = dict(
        new_core,
        buffer=state["buffer"],  # stale rows are dead: their weights are zeroed
        buf_weights=jnp.zeros_like(state["buf_weights"]),
        buf_staleness=jnp.zeros_like(state["buf_staleness"]),
        buf_count=jnp.zeros_like(state["buf_count"]),
    )
    return new_state, metrics


def _zero_flush_metrics(fed, acfg, state):
    shapes = jax.eval_shape(lambda s: flush_buffer(fed, acfg, s)[1], state)
    return jax.tree_util.tree_map(lambda sh: jnp.zeros(sh.shape, sh.dtype), shapes)


def admit_delta(
    fed: FederatedConfig,
    acfg: AsyncAggConfig,
    state: Dict[str, Any],
    delta,  # pytree: params-shaped pseudo-gradient, or a codec payload (no client axis)
    client_round: jax.Array,  # () int32 — the model version the delta was computed against
    weight: jax.Array,  # () float32 — pre-discount aggregation weight (n_k or 1)
    auto_flush: bool = True,  # static: flush in-graph (lax.cond) when the buffer fills
    codec: Optional[Codec] = None,  # uplink codec; decodes the payload at admission
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Admit one client pseudo-gradient into the buffer; flush when it fills.

    With a ``codec`` the arrival is an ENCODED payload — exactly what
    ``run_clients`` emitted over the uplink — and is decoded to float32 here, at
    the server door, so the buffer lanes and every flush stay codec-agnostic.
    The client-side error-feedback residual never crosses the wire: it stays
    keyed by client id on the sender (``AsyncFederationDriver`` owns one row per
    population client), which is what keeps residuals intact across buffer
    flushes, staleness rejections, and redispatches.

    Staleness is derived from the round *tag*, s = server_round − client_round,
    so a flush that happens between two admissions of one batch automatically
    ages the later arrivals. Zero-weight arrivals (a failed client sent nothing
    useful) and deltas staler than ``max_staleness`` are rejected without
    consuming a slot. Pure and jittable: the flush is a ``lax.cond`` on the fill
    counter, so admission never recompiles as the buffer state varies.

    Returns ``(state, metrics)``; with ``auto_flush``, ``metrics['flushed']`` is
    1.0 on the admission that triggered an outer update and the flush metrics
    (pseudo_grad_norm, consensus, staleness stats, ...) are zero-filled
    otherwise.

    ``auto_flush=False`` admits without the in-graph flush; the caller watches
    ``buf_count`` and invokes :func:`flush_buffer` as its own jitted call. The
    event-loop driver uses this mode: a flush compiled under ``lax.cond``
    sits in a different XLA fusion context than the straight-line sync round and
    can drift from it by 1 ulp, while the standalone flush graph reproduces
    ``federated_round`` *bitwise* (the sync-equivalence identity in the tests).
    Buffers write exact copies either way — the two modes differ only in how the
    flush is compiled, never in which deltas it aggregates.
    """
    if codec is not None:
        delta = codec.decode(delta)
    staleness = jnp.maximum(
        (state["round"] - client_round).astype(jnp.float32), 0.0
    )
    disc = staleness_discount(weight, staleness, acfg.staleness_alpha)
    accept = weight > 0
    if acfg.max_staleness > 0:
        accept = jnp.logical_and(accept, staleness <= float(acfg.max_staleness))
    # a full buffer rejects (never silently overwrites a slot): with auto_flush
    # this is unreachable (the flush below resets the counter), without it the
    # caller must flush before admitting more — visible as accepted == 0
    accept = jnp.logical_and(accept, state["buf_count"] < acfg.buffer_size)

    def _write(st):
        idx = st["buf_count"]
        buffer = jax.tree_util.tree_map(
            lambda b, d: jax.lax.dynamic_update_index_in_dim(
                b, d.astype(b.dtype), idx, 0
            ),
            st["buffer"],
            delta,
        )
        return dict(
            st,
            buffer=buffer,
            buf_weights=st["buf_weights"].at[idx].set(disc),
            buf_staleness=st["buf_staleness"].at[idx].set(staleness),
            buf_count=st["buf_count"] + 1,
        )

    state = jax.lax.cond(accept, _write, lambda st: st, state)

    metrics = {
        "accepted": accept.astype(jnp.float32),
        "staleness": staleness,
        "discounted_weight": jnp.where(accept, disc, 0.0),
    }
    if auto_flush:
        zero_metrics = _zero_flush_metrics(fed, acfg, state)
        state, flush_metrics = jax.lax.cond(
            state["buf_count"] >= acfg.buffer_size,
            lambda st: flush_buffer(fed, acfg, st),
            lambda st: (st, zero_metrics),
            state,
        )
        metrics.update(flush_metrics)
        metrics["flushed"] = (flush_metrics["buffer_fill"] > 0).astype(jnp.float32)
    metrics["buf_count"] = state["buf_count"].astype(jnp.float32)
    return state, metrics


def admit_deltas(
    fed: FederatedConfig,
    acfg: AsyncAggConfig,
    state: Dict[str, Any],
    deltas,  # pytree, leaves (N, ...) — N arrivals (or codec payloads) in admission order
    client_rounds: jax.Array,  # (N,) int32 round tags
    weights: jax.Array,  # (N,) float32 pre-discount weights
    codec: Optional[Codec] = None,  # uplink codec; each arrival decoded at admission
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Admit a batch of arrivals in order — the ``(state, deltas, tags, weights)
    → state`` form of the aggregator. A ``lax.scan`` over the arrival axis, so
    multiple flushes can fire inside one jitted call (N > M is fine); returned
    metrics are stacked per-arrival, e.g. ``metrics['flushed']`` marks which
    admissions triggered outer updates.
    """

    def body(st, x):
        d, r, w = x
        return admit_delta(fed, acfg, st, d, r, w, codec=codec)

    return jax.lax.scan(
        body,
        state,
        (deltas, client_rounds.astype(jnp.int32), weights.astype(jnp.float32)),
    )


# ---------------------------------------------------------------------------
# Host-side event loop: the simulated asynchronous federation
# ---------------------------------------------------------------------------


class AsyncFederationDriver:
    """Event-driven simulator of the asynchronous federation (Photon §5.3 async).

    Holds ``K = pcfg.clients_per_round`` concurrent client slots. Each dispatch
    snapshots the current global params + version; the client "runs" for its
    simulated duration (τ local steps at 1/speed from the persistent straggler
    model) and, on completion, the jitted client phase computes its delta
    *against the snapshot* — slow clients therefore admit genuinely stale deltas
    into later buffers instead of being masked to zero. The schedule is a pure
    replay of :class:`~repro.core.sampler.AsyncTimeline`, so a run is a
    deterministic function of ``(configs, seed)``.

    ``make_batches(client_id) -> batches`` keeps the data plane outside: leaves
    must be (τ, 1, ...) — the client axis of the shared client phase is 1 here,
    one jitted computation reused for every completion (no recompiles).

    With a ``codec``, each completion uploads the ENCODED payload and the server
    decodes at admission. Error-feedback residuals are owned HERE, keyed by
    population client id (``self.residuals``, leaves (P, ...)): a client's row is
    gathered at its completion, consumed by its encode, and scattered back to the
    same id — so residuals survive redispatch, interleaved completions of other
    clients, and buffer flushes in between, and two clients can never share or
    clobber each other's feedback state. ``checkpoint_state()`` folds the store
    into the server-state pytree so it round-trips through the checkpoint
    manager with everything else.
    """

    def __init__(
        self,
        loss_fn: Callable,
        fed: FederatedConfig,
        acfg: AsyncAggConfig,
        pcfg: ParticipationConfig,
        make_batches: Callable[[int], Dict[str, jax.Array]],
        *,
        seed: int = 0,
        params=None,
        rng: Optional[jax.Array] = None,
        state: Optional[Dict[str, Any]] = None,
        codec: Optional[Codec] = None,
    ):
        self.fed = fed
        self.acfg = acfg
        self.codec = codec
        self.make_batches = make_batches
        fed1 = replace(fed, clients_per_round=1, keep_inner_state=False)
        stateful = codec is not None and codec.stateful
        # with a codec the dispatched state carries a per-dispatch rng lane, so
        # stochastic-rounding noise decorrelates across the buffer's deltas
        # (M correlated quantization errors would not average out in the flush)
        if stateful:
            self._client_fn = jax.jit(
                lambda p, r, b, e, k: run_clients(
                    loss_fn, fed1, {"params": p, "round": r, "rng": k}, b,
                    codec=codec, residuals=e,
                )
            )
        elif codec is not None:
            self._client_fn = jax.jit(
                lambda p, r, b, k: run_clients(
                    loss_fn, fed1, {"params": p, "round": r, "rng": k}, b,
                    codec=codec,
                )
            )
        else:
            self._client_fn = jax.jit(
                lambda p, r, b: run_clients(
                    loss_fn, fed1, {"params": p, "round": r}, b
                )
            )
        # write-only admits + a standalone jitted flush: the flush then compiles
        # in the same fusion context as the sync server phase, keeping the
        # buffer_size==K staleness_alpha==0 path bitwise-equal to federated_round
        self._admit_fn = jax.jit(
            lambda st, d, r, w: admit_delta(
                fed, acfg, st, d, r, w, auto_flush=False, codec=codec
            )
        )
        self._flush_fn = jax.jit(lambda st: flush_buffer(fed, acfg, st))
        if state is None:
            state = init_async_state(fed, acfg, params, rng)
        else:
            state = dict(state)  # may carry 'uplink_residuals' from a checkpoint
        self.residuals = state.pop("uplink_residuals", None)
        self.state = state
        if self.residuals is not None and not stateful:
            raise ValueError(
                "restored state carries per-client error-feedback residuals but "
                "the driver's codec is not stateful — pass the codec the "
                "checkpoint was written with, or strip 'uplink_residuals' to "
                "deliberately discard the clients' accumulated feedback"
            )
        if stateful and self.residuals is None:
            self.residuals = init_uplink_residuals(
                codec, self.state["params"], pcfg.population
            )
        if stateful:
            # population-id gather/scatter as two tiny jits (traced cid — one
            # compile each, reused for every completion)
            self._res_gather = jax.jit(
                lambda store, cid: jax.tree_util.tree_map(
                    lambda r: r[cid][None], store
                )
            )
            self._res_scatter = jax.jit(
                lambda store, cid, new: jax.tree_util.tree_map(
                    lambda r, n: r.at[cid].set(n[0]), store, new
                )
            )
            self._res_norm_fn = jax.jit(global_norm)
        self._bytes_per_upload = (
            float(codec.nbytes(self.state["params"])) if codec is not None
            else 4.0 * sum(
                x.size for x in jax.tree_util.tree_leaves(self.state["params"])
            )
        )
        if codec is not None:
            # derived, never consumed: the server rng lane stays untouched
            self._uplink_rng = jax.random.fold_in(self.state["rng"], 0x55504C4B)
        self.uplink_bytes_total = 0.0  # bytes actually uploaded (incl. rejected)
        self.timeline = AsyncTimeline(pcfg, seed)
        self.sim_time = 0.0
        self.work_completed = 0.0  # simulated client-time that reached the buffer
        self.work_wasted = 0.0  # dropout / rejected-staleness client-time
        self.n_dispatched = 0
        self._heap: List[Tuple[float, int, Any, Any, int]] = []
        self._busy: set = set()  # population client ids currently holding a slot
        self._losses: List[float] = []  # client train losses since last flush
        self._staleness: List[float] = []  # admitted staleness since last flush
        self._res_norms: List[float] = []  # EF residual norms since last flush
        for _ in range(pcfg.clients_per_round):
            self._dispatch()

    def _dispatch(self) -> None:
        # a client can only run in one slot at a time: skip timeline entries for
        # clients already in flight (zero simulated cost — the scheduler simply
        # picks the next free client from the sampler stream). Termination: at
        # refill time at most K−1 clients are busy and every wave holds K
        # distinct clients, so a free client appears within two waves.
        for _ in range(64 * self.timeline.cfg.clients_per_round):
            ev = self.timeline.dispatch(self.n_dispatched)
            self.n_dispatched += 1
            if ev.client not in self._busy:
                break
        else:  # pragma: no cover — unreachable by the argument above
            raise RuntimeError("async dispatch starved: every client busy")
        # every dispatch holds its client for the event duration — including an
        # unavailable client's connect probe, during which no other slot should
        # be contacting it either
        self._busy.add(ev.client)
        # snapshot by reference: jax arrays are immutable, so holding the params
        # of up to K in-flight versions costs no copies
        snapshot = self.state["params"] if ev.completes else None
        version = int(self.state["round"])
        heapq.heappush(
            self._heap, (self.sim_time + ev.duration, ev.index, ev, snapshot, version)
        )

    def step(self) -> Optional[Dict[str, float]]:
        """Advance the timeline by one completion event; dispatch a replacement.

        Returns the flush metrics row when this event's admission triggered an
        outer update, else None.
        """
        finish, _, ev, snapshot, version = heapq.heappop(self._heap)
        self.sim_time = max(self.sim_time, finish)
        self._busy.discard(ev.client)
        row = None
        if ev.completes:
            # the client trained and consumed its data either way — but when the
            # server is certain to reject the upload (staleness is known at pop
            # time: no flush can intervene), skip the simulation's τ-step compute.
            # Not with an error-feedback codec: the client compresses and uploads
            # before learning of the rejection, so its residual must advance —
            # run the client phase and let admission refuse the payload.
            staleness = int(self.state["round"]) - version
            rejected = 0 < self.acfg.max_staleness < staleness
            batches = self.make_batches(ev.client)
            if rejected and self.residuals is None:
                self.work_wasted += ev.duration
            else:
                if self.codec is not None:
                    # unique per dispatch: fold_in by the event's dispatch index
                    enc_key = jax.random.fold_in(self._uplink_rng, ev.index)
                if self.residuals is not None:
                    cid = jnp.asarray(ev.client, jnp.int32)
                    cohort_res = self._res_gather(self.residuals, cid)
                    deltas, aux = self._client_fn(
                        snapshot, jnp.asarray(version, jnp.int32), batches,
                        cohort_res, enc_key,
                    )
                    # the residual belongs to the client regardless of what the
                    # server decides about this upload
                    self.residuals = self._res_scatter(
                        self.residuals, cid, aux["residuals"]
                    )
                    self._res_norms.append(float(self._res_norm_fn(aux["residuals"])))
                elif self.codec is not None:
                    deltas, aux = self._client_fn(
                        snapshot, jnp.asarray(version, jnp.int32), batches, enc_key
                    )
                else:
                    deltas, aux = self._client_fn(
                        snapshot, jnp.asarray(version, jnp.int32), batches
                    )
                delta = jax.tree_util.tree_map(lambda d: d[0], deltas)
                self.uplink_bytes_total += self._bytes_per_upload
                self.state, m = self._admit_fn(
                    self.state,
                    delta,
                    jnp.asarray(version, jnp.int32),
                    jnp.asarray(ev.weight, jnp.float32),
                )
                if float(m["accepted"]) > 0:
                    self.work_completed += ev.duration
                    self._staleness.append(float(m["staleness"]))
                    self._losses.append(float(aux["step_metrics"]["loss"][-1]))
                else:  # rejected at admission: must not skew the flush row
                    self.work_wasted += ev.duration
            if int(self.state["buf_count"]) >= self.acfg.buffer_size:
                self.state, fm = self._flush_fn(self.state)
                row = self._flush_row(fm)
        else:
            self.work_wasted += ev.duration
        self._dispatch()
        return row

    def _flush_row(self, flush_metrics) -> Dict[str, float]:
        row = {k: float(v) for k, v in flush_metrics.items()}
        row["sim_time"] = self.sim_time
        row["train_loss_mean"] = (
            float(jnp.mean(jnp.asarray(self._losses))) if self._losses else 0.0
        )
        row["admitted_staleness"] = list(self._staleness)
        row["uplink_bytes_total"] = self.uplink_bytes_total
        if self.residuals is not None:
            row["uplink_residual_norm"] = (
                sum(self._res_norms) / len(self._res_norms) if self._res_norms else 0.0
            )
        self._losses, self._staleness, self._res_norms = [], [], []
        return row

    def checkpoint_state(self) -> Dict[str, Any]:
        """Server state + the per-client error-feedback store as ONE pytree with
        a fixed structure, so it round-trips through ``CheckpointManager`` /
        ``save_pytree`` like any other state (restore by passing it back as
        ``state=``). Without a stateful codec this is just ``self.state``."""
        if self.residuals is None:
            return self.state
        return dict(self.state, uplink_residuals=self.residuals)

    def force_flush(self) -> Optional[Dict[str, float]]:
        """Apply a final outer update from a partially filled buffer (end of
        run). Returns a row shaped exactly like ``step()``'s flush rows."""
        if int(self.state["buf_count"]) == 0:
            return None
        self.state, m = self._flush_fn(self.state)
        return self._flush_row(m)

    def run_updates(
        self,
        n_updates: int,
        on_update: Optional[Callable[[int, Dict[str, float]], None]] = None,
        max_events: Optional[int] = None,
    ) -> List[Dict[str, float]]:
        """Run the event loop until ``n_updates`` outer updates have been applied.

        Raises if the event budget runs out first (pathologically offline
        populations or aggressive ``max_staleness`` rejection) — a silently
        truncated history would corrupt any wall-clock-to-loss comparison.
        """
        history: List[Dict[str, float]] = []
        budget = max_events if max_events is not None else 1000 * max(1, n_updates)
        while len(history) < n_updates and budget > 0:
            budget -= 1
            row = self.step()
            if row is not None:
                row["update"] = len(history)
                history.append(row)
                if on_update is not None:
                    on_update(len(history) - 1, row)
        if len(history) < n_updates:
            raise RuntimeError(
                f"async event budget exhausted after {len(history)}/{n_updates} "
                f"outer updates (buffer admits too rarely: mostly-offline "
                f"population, zero weights, or max_staleness rejecting "
                f"everything) — raise max_events or loosen the configuration"
            )
        return history
