"""Asynchronous buffered aggregation — Photon's FedBuff-style aggregator.

The synchronous round (``core/federated.py``) discards every straggler's work at
the deadline: a client that misses the cut is masked to weight zero and its τ
local steps are wasted. Photon (arXiv 2411.02908) instead runs the aggregator
*asynchronously*: clients pull the current global model whenever they become
free, train at their own speed, and push their pseudo-gradient whenever they
finish — the server **buffers** incoming deltas and applies one outer update per
``M`` buffered deltas (FedBuff, Nguyen et al. 2022). Slow clients land in later
buffers instead of being dropped.

Mapping to Photon's aggregator, implemented here:

  ================================  =============================================
  Photon / FedBuff concept          This module
  ================================  =============================================
  model version ``t`` on server     ``state['round']`` — bumped once per flush
  client trains against version t'  delta *tag* ``client_round`` (the round the
                                    pseudo-gradient was computed against)
  staleness ``s = t − t'``          computed at admission, never trusted from the
                                    client (a flush mid-batch increases the
                                    staleness of later arrivals automatically)
  staleness discount                ``w̃ = w / (1 + s)^α`` (:func:`staleness_discount`,
                                    FedBuff's polynomial discount; α=0 disables)
  buffer of K deltas, update at K   fixed-capacity (M, ...) delta buffer +
                                    ``buf_count``; flush triggered at ``M``
  stale-update rejection            ``max_staleness`` — older deltas are refused
                                    at admission (their slot is never consumed)
  server update on the buffer       :func:`flush_buffer` → the *same*
                                    ``apply_aggregate`` as the sync round
  ================================  =============================================

Everything is a pure, jittable function of ``(state, deltas, tags, weights)``:
the buffer, its weights/staleness lanes and the fill counter live inside the
state pytree, so async training state round-trips through the checkpoint
manager and resume is exact — the same property the sync round gets from the
pure participation sampler.

Because :func:`flush_buffer` reuses ``apply_aggregate`` and clients run the
shared ``run_clients`` phase, the async path with ``buffer_size == K``,
``staleness_alpha == 0`` and all clients completing in-round reproduces the
synchronous ``federated_round`` *bitwise* (tested).

This module owns only the PURE aggregation functions. The server-side state
machine that wraps them — admission policy, fractional/staleness weight
policy, the dispatch cursor and in-flight slot table, and the canonical
resumable checkpoint schema — is ``core/aggregator.AsyncBufferAggregator``,
and the host event loop that replays the simulated
:class:`~repro.core.sampler.AsyncTimeline` over it is the thin
``core/aggregator.AsyncFederationDriver``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import Codec
from repro.core.federated import (
    FederatedConfig,
    apply_aggregate,
    init_federated_state,
)
from repro.core.inner_opt import global_norm


@dataclass(frozen=True)
class AsyncAggConfig:
    buffer_size: int = 4  # M — deltas per outer update (FedBuff's K)
    staleness_alpha: float = 0.5  # discount exponent; 0 = no discount
    max_staleness: int = 0  # reject deltas older than this (0 = accept any age)

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError(f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.staleness_alpha < 0.0:
            raise ValueError(f"staleness_alpha must be >= 0, got {self.staleness_alpha}")
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got {self.max_staleness}")


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def init_async_state(
    fed: FederatedConfig,
    acfg: AsyncAggConfig,
    params,
    rng: Optional[jax.Array] = None,
) -> Dict[str, Any]:
    """Server state = the sync server state + the delta buffer lanes.

    ``round`` doubles as the server *model version*: it increments once per
    flush, and arriving deltas measure their staleness against it. Buffer slots
    beyond ``buf_count`` hold zero weight, so a partially filled buffer
    aggregates correctly and the whole state round-trips through
    ``checkpoint.save_pytree`` unchanged.
    """
    state = init_federated_state(
        replace(fed, keep_inner_state=False), params, rng
    )  # async clients are stateless (paper §7.8) — no persisted inner lanes
    m = acfg.buffer_size
    state["buffer"] = jax.tree_util.tree_map(
        lambda p: jnp.zeros((m,) + p.shape, jnp.float32), params
    )
    state["buf_weights"] = jnp.zeros((m,), jnp.float32)
    state["buf_staleness"] = jnp.zeros((m,), jnp.float32)
    state["buf_count"] = jnp.zeros((), jnp.int32)
    return state


def staleness_discount(weight, staleness, alpha: float):
    """FedBuff's polynomial staleness discount: w̃ = w / (1 + s)^α.

    Monotone non-increasing in s for α ≥ 0 (property-tested); α = 0 returns the
    weight bitwise-unchanged ((1+s)^0 = 1.0 exactly), which is what makes the
    sync-equivalence identity exact.
    """
    s = jnp.maximum(staleness.astype(jnp.float32), 0.0)
    return weight.astype(jnp.float32) / (1.0 + s) ** alpha


# ---------------------------------------------------------------------------
# Admission + flush — pure (state, deltas, tags, weights) → state
# ---------------------------------------------------------------------------


def flush_buffer(
    fed: FederatedConfig,
    acfg: AsyncAggConfig,
    state: Dict[str, Any],
    apply_fn: Optional[Any] = None,  # server-phase override (fused Pallas path)
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Apply one outer update from the buffered deltas and reset the buffer.

    Delegates to the sync round's ``apply_aggregate`` with the buffer as the
    client axis and the discounted weights as the elastic weight vector —
    weighted mean → optional DP noise → outer update → version += 1. Empty slots
    carry zero weight, so a partial (forced) flush aggregates only what arrived.
    ``apply_fn`` swaps in a drop-in server phase (the ``--fused-server``
    flat-buffer pass over the (M, N) buffer), exactly as in ``federated_round``.

    Flushing an EMPTY buffer is a no-op on the core lanes: a zero-delta outer
    step would still decay FedMom/FedAdam statistics and bump the model version
    (aging every in-flight client's staleness for a round in which nothing
    aggregated). The guard is a straight-line per-leaf ``jnp.where`` on
    ``buf_count > 0`` — NOT ``lax.cond`` — because ``where(True, new, old)``
    returns ``new`` bitwise, preserving the sync≡async flush identity, while a
    cond-compiled flush drifts 1 ulp (see ``admit_delta``). The runtime's
    deadline-triggered partial flushes are what hit the empty path in practice.
    """
    core = {k: state[k] for k in ("params", "outer", "round", "rng")}
    new_core, metrics = (apply_fn or apply_aggregate)(
        fed, core, state["buffer"], client_weights=state["buf_weights"]
    )
    nonempty = state["buf_count"] > 0
    new_core = jax.tree_util.tree_map(
        lambda new, old: jnp.where(nonempty, new, old), new_core, core
    )
    count = state["buf_count"].astype(jnp.float32)
    metrics = dict(
        metrics,
        buffer_fill=count,
        buffer_occupancy=count / float(acfg.buffer_size),
        staleness_mean=jnp.sum(state["buf_staleness"]) / jnp.maximum(count, 1.0),
        staleness_max=jnp.max(state["buf_staleness"]),
    )
    new_state = dict(
        new_core,
        buffer=state["buffer"],  # stale rows are dead: their weights are zeroed
        buf_weights=jnp.zeros_like(state["buf_weights"]),
        buf_staleness=jnp.zeros_like(state["buf_staleness"]),
        buf_count=jnp.zeros_like(state["buf_count"]),
    )
    return new_state, metrics


def _zero_flush_metrics(fed, acfg, state, apply_fn=None):
    shapes = jax.eval_shape(
        lambda s: flush_buffer(fed, acfg, s, apply_fn=apply_fn)[1], state
    )
    return jax.tree_util.tree_map(lambda sh: jnp.zeros(sh.shape, sh.dtype), shapes)


def admit_delta(
    fed: FederatedConfig,
    acfg: AsyncAggConfig,
    state: Dict[str, Any],
    delta,  # pytree: params-shaped pseudo-gradient, or a codec payload (no client axis)
    client_round: jax.Array,  # () int32 — the model version the delta was computed against
    weight: jax.Array,  # () float32 — pre-discount aggregation weight (n_k or 1)
    auto_flush: bool = True,  # static: flush in-graph (lax.cond) when the buffer fills
    codec: Optional[Codec] = None,  # uplink codec; decodes the payload at admission
    apply_fn: Optional[Any] = None,  # server-phase override for the in-graph flush
    screen: bool = False,  # static: delta screen at the door (core/robust.py)
    norm_bound: Optional[jax.Array] = None,  # () traced admission norm bound
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Admit one client pseudo-gradient into the buffer; flush when it fills.

    With a ``codec`` the arrival is an ENCODED payload — exactly what
    ``run_clients`` emitted over the uplink — and is decoded to float32 here, at
    the server door, so the buffer lanes and every flush stay codec-agnostic.
    The client-side error-feedback residual never crosses the wire: it stays
    keyed by client id on the sender (``AsyncFederationDriver`` owns one row per
    population client), which is what keeps residuals intact across buffer
    flushes, staleness rejections, and redispatches.

    Staleness is derived from the round *tag*, s = server_round − client_round,
    so a flush that happens between two admissions of one batch automatically
    ages the later arrivals. Zero-weight arrivals (a failed client sent nothing
    useful) and deltas staler than ``max_staleness`` are rejected without
    consuming a slot. Pure and jittable: the flush is a ``lax.cond`` on the fill
    counter, so admission never recompiles as the buffer state varies.

    Returns ``(state, metrics)``; with ``auto_flush``, ``metrics['flushed']`` is
    1.0 on the admission that triggered an outer update and the flush metrics
    (pseudo_grad_norm, consensus, staleness stats, ...) are zero-filled
    otherwise.

    ``auto_flush=False`` admits without the in-graph flush; the caller watches
    ``buf_count`` and invokes :func:`flush_buffer` as its own jitted call. The
    event-loop driver uses this mode: a flush compiled under ``lax.cond``
    sits in a different XLA fusion context than the straight-line sync round and
    can drift from it by 1 ulp, while the standalone flush graph reproduces
    ``federated_round`` *bitwise* (the sync-equivalence identity in the tests).
    Buffers write exact copies either way — the two modes differ only in how the
    flush is compiled, never in which deltas it aggregates.

    ``screen`` (static) arms the payload defense at the door: a non-finite
    decoded delta is always refused (its slot is never consumed, so it cannot
    poison a flush), and with a finite ``norm_bound`` an over-norm delta is
    refused too — the host derives the bound from the trailing admitted norms
    (``core/robust.RobustState.norm_bound``) and passes it as a traced scalar,
    so the bound tightening over time never recompiles the door. Screened
    admissions report ``delta_norm`` and ``screened`` in the metrics; the
    default path's metrics (and graph) are unchanged.
    """
    if codec is not None:
        delta = codec.decode(delta)
    staleness = jnp.maximum(
        (state["round"] - client_round).astype(jnp.float32), 0.0
    )
    disc = staleness_discount(weight, staleness, acfg.staleness_alpha)
    accept = weight > 0
    if acfg.max_staleness > 0:
        accept = jnp.logical_and(accept, staleness <= float(acfg.max_staleness))
    screen_metrics = {}
    if screen:
        dn = global_norm(delta)
        ok = jnp.isfinite(dn)  # NaN/inf payloads never reach a buffer slot
        if norm_bound is not None:
            # NaN <= bound is False, inf <= inf is True — hence the isfinite
            # conjunct above even when the bound is still +inf (warmup)
            ok = jnp.logical_and(ok, dn <= norm_bound)
        accept = jnp.logical_and(accept, ok)
        screen_metrics = {
            "delta_norm": dn,
            "screened": jnp.logical_not(ok).astype(jnp.float32),
        }
    # a full buffer rejects (never silently overwrites a slot): with auto_flush
    # this is unreachable (the flush below resets the counter), without it the
    # caller must flush before admitting more — visible as accepted == 0
    accept = jnp.logical_and(accept, state["buf_count"] < acfg.buffer_size)

    def _write(st):
        idx = st["buf_count"]
        buffer = jax.tree_util.tree_map(
            lambda b, d: jax.lax.dynamic_update_index_in_dim(
                b, d.astype(b.dtype), idx, 0
            ),
            st["buffer"],
            delta,
        )
        return dict(
            st,
            buffer=buffer,
            buf_weights=st["buf_weights"].at[idx].set(disc),
            buf_staleness=st["buf_staleness"].at[idx].set(staleness),
            buf_count=st["buf_count"] + 1,
        )

    state = jax.lax.cond(accept, _write, lambda st: st, state)

    metrics = {
        "accepted": accept.astype(jnp.float32),
        "staleness": staleness,
        "discounted_weight": jnp.where(accept, disc, 0.0),
        **screen_metrics,
    }
    if auto_flush:
        zero_metrics = _zero_flush_metrics(fed, acfg, state, apply_fn=apply_fn)
        state, flush_metrics = jax.lax.cond(
            state["buf_count"] >= acfg.buffer_size,
            lambda st: flush_buffer(fed, acfg, st, apply_fn=apply_fn),
            lambda st: (st, zero_metrics),
            state,
        )
        metrics.update(flush_metrics)
        metrics["flushed"] = (flush_metrics["buffer_fill"] > 0).astype(jnp.float32)
    metrics["buf_count"] = state["buf_count"].astype(jnp.float32)
    return state, metrics


def admission_record(metrics: Dict[str, jax.Array]) -> Dict[str, float]:
    """Host-side view of one admission's outcome for telemetry/logging.

    Converts exactly the scalars :func:`admit_delta` reports into plain floats
    (one device sync, paid only when the caller is actually tracing) plus the
    derived ``accepted`` bool — the record the tracer's ``admit`` instant and
    the report CLI's staleness breakdown share. Deliberately read-only: the
    admission math itself never changes whether this is called or not.
    """
    rec = {
        "accepted": bool(float(metrics["accepted"]) > 0),
        "staleness": float(metrics["staleness"]),
        "discounted_weight": float(metrics["discounted_weight"]),
    }
    if "buf_count" in metrics:
        rec["buf_count"] = float(metrics["buf_count"])
    return rec


def admit_deltas(
    fed: FederatedConfig,
    acfg: AsyncAggConfig,
    state: Dict[str, Any],
    deltas,  # pytree, leaves (N, ...) — N arrivals (or codec payloads) in admission order
    client_rounds: jax.Array,  # (N,) int32 round tags
    weights: jax.Array,  # (N,) float32 pre-discount weights
    codec: Optional[Codec] = None,  # uplink codec; each arrival decoded at admission
    apply_fn: Optional[Any] = None,  # server-phase override for in-graph flushes
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Admit a batch of arrivals in order — the ``(state, deltas, tags, weights)
    → state`` form of the aggregator. A ``lax.scan`` over the arrival axis, so
    multiple flushes can fire inside one jitted call (N > M is fine); returned
    metrics are stacked per-arrival, e.g. ``metrics['flushed']`` marks which
    admissions triggered outer updates.
    """

    def body(st, x):
        d, r, w = x
        return admit_delta(fed, acfg, st, d, r, w, codec=codec, apply_fn=apply_fn)

    return jax.lax.scan(
        body,
        state,
        (deltas, client_rounds.astype(jnp.int32), weights.astype(jnp.float32)),
    )
