"""Server-side (outer) optimizers operating on aggregated pseudo-gradients.

The paper evaluates FedAvg (η_s = 1, no momentum — recommended, §7.8), server-side
Nesterov momentum "FedMom" [47] (Table 3 uses η_s ∈ {0.1..0.7}, μ_s = 0.9), and the
FedOPT family; we implement FedAvg, FedMomentum (Nesterov), and FedAdam.

Convention: pseudo-gradient Δ = θ_global − mean_k θ_k  (Algorithm 1, L.7–9), so the
update moves θ in −Δ direction: θ ← θ − η_s · f(Δ).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OuterOptConfig:
    name: str = "fedavg"  # 'fedavg' | 'fedmom' | 'fedadam'
    lr: float = 1.0  # η_s (paper Table 3: 0.7 for fedmom at most scales)
    momentum: float = 0.9  # μ_s
    nesterov: bool = True
    beta2: float = 0.99  # fedadam
    eps: float = 1e-8


def init_outer_state(cfg: OuterOptConfig, params) -> Dict[str, Any]:
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    if cfg.name == "fedavg":
        return {"round": jnp.zeros((), jnp.int32)}
    if cfg.name == "fedmom":
        return {"momentum": zeros(), "round": jnp.zeros((), jnp.int32)}
    if cfg.name == "fedadam":
        return {"m": zeros(), "v": zeros(), "round": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def outer_update(
    cfg: OuterOptConfig,
    global_params,
    pseudo_grad,  # Δ = θ_global − mean_k θ_k   (same pytree as params)
    state: Dict[str, Any],
) -> Tuple[Any, Dict[str, Any]]:
    rnd = state["round"] + 1
    if cfg.name == "fedavg":
        new_params = jax.tree_util.tree_map(
            lambda p, d: (p - cfg.lr * d).astype(p.dtype), global_params, pseudo_grad
        )
        return new_params, {"round": rnd}

    if cfg.name == "fedmom":
        new_mom = jax.tree_util.tree_map(
            lambda b, d: cfg.momentum * b + d.astype(b.dtype), state["momentum"], pseudo_grad
        )
        if cfg.nesterov:
            upd = jax.tree_util.tree_map(
                lambda b, d: cfg.momentum * b + d.astype(b.dtype), new_mom, pseudo_grad
            )
        else:
            upd = new_mom
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p - cfg.lr * u).astype(p.dtype), global_params, upd
        )
        return new_params, {"momentum": new_mom, "round": rnd}

    if cfg.name == "fedadam":
        c = rnd.astype(jnp.float32)
        new_m = jax.tree_util.tree_map(
            lambda m, d: cfg.momentum * m + (1 - cfg.momentum) * d.astype(m.dtype),
            state["m"],
            pseudo_grad,
        )
        new_v = jax.tree_util.tree_map(
            lambda v, d: cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(d.astype(v.dtype)),
            state["v"],
            pseudo_grad,
        )
        b1c = 1.0 - cfg.momentum**c
        b2c = 1.0 - cfg.beta2**c
        new_params = jax.tree_util.tree_map(
            lambda p, m, v: (p - cfg.lr * (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)).astype(p.dtype),
            global_params,
            new_m,
            new_v,
        )
        return new_params, {"m": new_m, "v": new_v, "round": rnd}

    raise ValueError(cfg.name)
