"""Client participation subsystem (Algorithm 1, L.4 + paper §7 robustness claims).

The paper argues federated pre-training is robust to *partial participation* and to
*statistical and hardware heterogeneity*. This module provides the machinery behind
those claims as a set of pure, seeded functions — every quantity for round ``r`` is a
function of ``(seed, r, config)`` alone, never of execution history, so runs are
exactly resumable and round ``r`` samples identically whether or not rounds
``0..r-1`` were ever executed (paper §6.1 "reproducible sampling").

Layers, composed by :func:`plan_round`:

  1. **Availability models** — who *could* participate this round:
     ``uniform`` (everyone), ``dirichlet`` (skewed per-client popularity, a fixed
     Dirichlet draw — some publishers show up far more often than others), and
     ``markov`` (per-client on/off chains — clients leave and rejoin the federation
     in correlated streaks, Photon's volunteer-compute regime).
  2. **Cohort selection** — K-of-P sampling among the available clients; slots left
     over when fewer than K are available are padded with masked (zero-weight)
     clients so the jitted round always sees a fixed client axis.
  3. **Mid-round dropout** — each selected client independently fails with
     ``dropout_rate`` probability (process crash, network partition).
  4. **Straggler simulation** — persistent per-client speed multipliers (hardware
     heterogeneity); with a round deadline, clients whose simulated wall-clock
     exceeds it are masked out of the aggregate.
  5. **Aggregation weights** — FedAvg data-size weighting from per-client example
     counts (or uniform), zeroed for every masked slot.

The resulting :class:`ParticipationPlan` feeds ``federated_round`` as a weight
vector: dropped/straggling clients contribute zero-weight deltas inside the *same*
jitted computation, so the effective cohort K_eff ≤ K varies per round with no
recompilation.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional

import numpy as np

# Fixed integer tags decorrelate the per-purpose random streams under one user seed.
# Every tagged stream is seeded as (seed, TAG, index): the tag always sits in the
# same position and the entropy length (3) differs from the untagged legacy
# ``sample_round`` sequence (seed, round_idx), so no two streams can collide.
_TAG_SELECT = 0x5EED0001
_TAG_DATA = 0x5EED0002
_TAG_POPULARITY = 0x5EED0003
_TAG_MARKOV = 0x5EED0004
_TAG_DROPOUT = 0x5EED0005
_TAG_SPEED = 0x5EED0006
_TAG_PAD = 0x5EED0007


def _rng(seed: int, tag: int, index: int = 0) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, tag, index]))


# ---------------------------------------------------------------------------
# Cohort sampling (the seed repo's API, extended with popularity weights)
# ---------------------------------------------------------------------------


def sample_round(
    seed: int,
    round_idx: int,
    population: int,
    k: int,
    probs: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Deterministic K-of-P sample for a given round, optionally popularity-weighted."""
    if k > population:
        raise ValueError(f"cannot sample {k} of {population}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, round_idx]))
    return np.sort(rng.choice(population, size=k, replace=False, p=probs))


def participation_counts(
    seed: int,
    n_rounds: int,
    population: int,
    k: int,
    probs: Optional[np.ndarray] = None,
) -> np.ndarray:
    counts = np.zeros(population, np.int64)
    for r in range(n_rounds):
        counts[sample_round(seed, r, population, k, probs)] += 1
    return counts


# ---------------------------------------------------------------------------
# Statistical heterogeneity: data sizes and popularity
# ---------------------------------------------------------------------------


def client_example_counts(
    seed: int, population: int, median: int = 2048, log_sigma: float = 0.6
) -> np.ndarray:
    """Per-client dataset sizes (log-normal around ``median``) — the n_k of the
    FedAvg weighted average. Fixed for the run: a client's corpus does not change
    between rounds."""
    rng = _rng(seed, _TAG_DATA)
    counts = median * rng.lognormal(0.0, log_sigma, population)
    return np.maximum(1, counts).astype(np.int64)


def dirichlet_popularity(seed: int, population: int, alpha: float = 0.3) -> np.ndarray:
    """A fixed Dirichlet(α) draw over the population: per-round selection
    probabilities. Small α → heavy skew (a few clients dominate participation, the
    long-tail publishers of Fig 1); α → ∞ recovers uniform sampling."""
    rng = _rng(seed, _TAG_POPULARITY)
    p = rng.dirichlet(np.full(population, alpha, np.float64))
    p = p + 1e-9  # keep every client reachable for without-replacement draws
    return p / p.sum()


# ---------------------------------------------------------------------------
# Availability: Markov on/off chains
# ---------------------------------------------------------------------------


def markov_availability(
    seed: int,
    round_idx: int,
    population: int,
    p_drop: float = 0.2,
    p_join: float = 0.5,
) -> np.ndarray:
    """Boolean availability of every client at round ``round_idx`` under independent
    per-client two-state Markov chains (on --p_drop--> off, off --p_join--> on),
    started from the stationary distribution.

    Pure in ``(seed, round_idx)``: the chain is replayed from round 0 with per-round
    seeded innovations, so the answer for round r never depends on which rounds were
    actually executed (exact-resume requirement). O(r·P) vectorized — negligible next
    to a training round.
    """
    stationary_on = p_join / max(p_join + p_drop, 1e-12)
    state = _rng(seed, _TAG_MARKOV, 0).random(population) < stationary_on
    for r in range(1, round_idx + 1):
        u = _rng(seed, _TAG_MARKOV, r).random(population)
        state = np.where(state, u >= p_drop, u < p_join)
    return state


# ---------------------------------------------------------------------------
# Hardware heterogeneity: stragglers
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StragglerProfile:
    """Persistent per-client speed heterogeneity plus an optional round deadline.

    Speeds are log-normal multipliers (1.0 = median hardware); a client's simulated
    round time is 1/speed in units of the median client's round. With ``deadline``
    > 0, clients whose time exceeds it are masked out of the aggregate (the
    synchronous-round straggler cut of Photon §5.3)."""

    name: str = "none"
    speed_log_sigma: float = 0.0
    deadline: float = 0.0  # in median-round units; 0 = wait for everyone


STRAGGLER_PROFILES: Dict[str, StragglerProfile] = {
    "none": StragglerProfile("none", 0.0, 0.0),
    "mild": StragglerProfile("mild", 0.35, 2.0),
    "heavy": StragglerProfile("heavy", 0.8, 1.5),
}


def client_speeds(seed: int, population: int, log_sigma: float) -> np.ndarray:
    """Fixed per-client relative speed multipliers (hardware doesn't change per round)."""
    if log_sigma <= 0.0:
        return np.ones(population, np.float64)
    rng = _rng(seed, _TAG_SPEED)
    return rng.lognormal(0.0, log_sigma, population)


# ---------------------------------------------------------------------------
# The participation plan: one round's elastic cohort
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParticipationConfig:
    population: int
    clients_per_round: int  # K — the fixed client-axis width of the jitted round
    model: str = "uniform"  # uniform | dirichlet | markov
    dirichlet_alpha: float = 0.3
    markov_p_drop: float = 0.2  # on → off per round
    markov_p_join: float = 0.5  # off → on per round
    dropout_rate: float = 0.0  # seeded mid-round client failure probability
    straggler: StragglerProfile = field(
        default_factory=lambda: STRAGGLER_PROFILES["none"]
    )
    weighting: str = "uniform"  # uniform | examples (FedAvg data-size weights)
    examples_median: int = 2048
    examples_log_sigma: float = 0.6
    # Straggler PARTIAL PROGRESS (FedProx/FedNova tradition, ROADMAP item 1):
    # instead of cutting a client that misses the deadline, credit the τ_i =
    # min(τ, ⌊τ·speed_i·deadline⌋) local steps it actually finished. The plan
    # then carries per-slot realized step counts (``ParticipationPlan.local_steps``)
    # and the aggregator's weight policy scales each delta by τ_i/τ
    # (``core/aggregator.partial_progress_weights``).
    partial_progress: bool = False
    local_steps: int = 0  # τ — required (> 0) when partial_progress is on

    def __post_init__(self):
        if self.model not in ("uniform", "dirichlet", "markov"):
            raise ValueError(f"unknown availability model {self.model!r}")
        if self.weighting not in ("uniform", "examples"):
            raise ValueError(f"unknown weighting {self.weighting!r}")
        if self.clients_per_round > self.population:
            raise ValueError(
                f"cannot sample {self.clients_per_round} of {self.population}"
            )
        if self.partial_progress and self.local_steps < 1:
            raise ValueError(
                "partial_progress needs the round's τ (local_steps > 0) to derive "
                "per-client realized step counts"
            )


@dataclass(frozen=True)
class ParticipationPlan:
    """One round's resolved cohort. ``selected`` always has length K (the jitted
    round's client axis); ``mask``/``weights`` carry the elasticity."""

    selected: np.ndarray  # (K,) int64 — distinct client ids bound to the client axis
    mask: np.ndarray  # (K,) bool — contributes to the aggregate
    weights: np.ndarray  # (K,) float32 — aggregation weights, 0 where masked
    speeds: np.ndarray  # (K,) float64 — relative hardware speed of each slot
    unavailable: np.ndarray  # (K,) bool — padded slots the availability model ruled out
    dropped: np.ndarray  # (K,) bool — mid-round dropout casualties
    stragglers: np.ndarray  # (K,) bool — missed the round deadline
    round_time: float  # simulated wall-clock, median-client-round units
    times: np.ndarray = None  # (K,) float64 — UNCAPPED per-slot completion time
    # (τ local steps at 1/speed, median-client-round units). The sync round caps
    # this at the deadline and discards the tail; the async aggregator replays it
    # as an event timeline, so slow clients land in later buffers instead.
    local_steps: np.ndarray = None  # (K,) int64 — realized per-slot step counts
    # τ_i under partial progress (None when partial_progress is off): the τ-mask
    # input of the jitted round. 0 where masked; τ for full-speed clients.

    @property
    def effective_k(self) -> int:
        return int(self.mask.sum())

    @property
    def n_dropped(self) -> int:
        return int(self.dropped.sum())

    @property
    def n_stragglers(self) -> int:
        return int(self.stragglers.sum())


def plan_round(cfg: ParticipationConfig, seed: int, round_idx: int) -> ParticipationPlan:
    """Resolve one round's participation: availability → cohort → dropout →
    straggler cut → weights. Pure in ``(cfg, seed, round_idx)``.

    At least one client always survives (the fastest of the round's starters): a
    fully-empty aggregate would make the round's weighted mean ill-defined, and a
    real aggregator would simply rerun such a round.
    """
    P, K = cfg.population, cfg.clients_per_round

    # 1. availability model → candidate pool (+ optional popularity weights)
    probs = None
    if cfg.model == "dirichlet":
        probs = dirichlet_popularity(seed, P, cfg.dirichlet_alpha)
        available = np.ones(P, bool)
    elif cfg.model == "markov":
        available = markov_availability(
            seed, round_idx, P, cfg.markov_p_drop, cfg.markov_p_join
        )
    else:
        available = np.ones(P, bool)

    # 2. cohort selection: K distinct ids; prefer available clients, pad the rest
    #    with masked unavailable ones so the client axis stays K-wide.
    avail_ids = np.flatnonzero(available)
    if len(avail_ids) == P and probs is None:
        selected = sample_round(seed, round_idx, P, K)  # legacy-identical cohorts
        mask = np.ones(K, bool)
    elif len(avail_ids) >= K:
        if probs is not None:
            selected = sample_round(seed, round_idx, P, K, probs)
        else:
            rng = _rng(seed, _TAG_SELECT, round_idx)
            selected = np.sort(rng.choice(avail_ids, size=K, replace=False))
        mask = np.ones(K, bool)
    else:
        off_ids = np.flatnonzero(~available)
        n_pad = K - len(avail_ids)
        pad = _rng(seed, _TAG_PAD, round_idx).choice(off_ids, size=n_pad, replace=False)
        order = np.argsort(np.concatenate([avail_ids, pad]))
        selected = np.concatenate([avail_ids, pad])[order]
        mask = np.concatenate([np.ones(len(avail_ids), bool), np.zeros(n_pad, bool)])[
            order
        ]
    unavailable = ~mask

    # 3. seeded mid-round dropout
    u = _rng(seed, _TAG_DROPOUT, round_idx).random(K)
    dropped = mask & (u < cfg.dropout_rate)
    mask = mask & ~dropped

    # 4. straggler handling: per-client wall-clock = 1/speed (median units).
    #    Deadline-cut (legacy): clients past the deadline are masked out.
    #    Partial progress: a slow client is credited the τ_i = min(τ,
    #    ⌊τ·speed_i·deadline⌋) local steps it realized by the deadline; only a
    #    client too slow to finish even ONE step is still cut.
    deadline = cfg.straggler.deadline
    speeds = client_speeds(seed, P, cfg.straggler.speed_log_sigma)[selected]
    times = 1.0 / speeds
    started = mask.copy()
    stragglers = np.zeros(K, bool)
    local_steps = None
    if cfg.partial_progress:
        tau = cfg.local_steps
        if deadline > 0.0:
            tau_i = np.minimum(tau, np.floor(tau * speeds * deadline)).astype(np.int64)
        else:  # no deadline: everyone runs to full τ
            tau_i = np.full(K, tau, np.int64)
        stragglers = mask & (tau_i < 1)
        mask = mask & ~stragglers
        local_steps = np.where(mask, tau_i, 0)
    elif deadline > 0.0:
        stragglers = mask & (times > deadline)
        mask = mask & ~stragglers
    if started.any():
        capped = times if deadline <= 0 else np.minimum(times, deadline)
        if local_steps is not None and cfg.local_steps > 0:
            # a partial client uploads as soon as its τ_i-th step lands
            capped = np.where(mask, (local_steps / cfg.local_steps) * times, capped)
        round_time = float(capped[started].max())
    else:
        round_time = 0.0

    # 5. never let the aggregate go empty: resurrect the fastest starter
    if not mask.any():
        idx = int(np.argmax(np.where(started, speeds, -np.inf))) if started.any() else 0
        mask[idx] = True
        dropped[idx] = False
        stragglers[idx] = False
        unavailable[idx] = False
        if local_steps is not None:
            # restore the rescued client's real realized budget (its row was
            # zeroed with the rest of the masked slots), floored at one step
            local_steps[idx] = max(1, int(tau_i[idx]))

    # 6. aggregation weights (FedAvg n_k weighting or uniform), zeroed where
    #    masked. Deliberately NOT scaled by τ_i/τ here: the fractional-progress
    #    weight policy is owned by the Aggregator seam
    #    (core/aggregator.partial_progress_weights), which composes it for both
    #    the sync round and async admission.
    if cfg.weighting == "examples":
        n_k = client_example_counts(
            seed, P, cfg.examples_median, cfg.examples_log_sigma
        )[selected].astype(np.float32)
    else:
        n_k = np.ones(K, np.float32)
    weights = n_k * mask.astype(np.float32)

    return ParticipationPlan(
        selected=selected.astype(np.int64),
        mask=mask,
        weights=weights,
        speeds=speeds,
        unavailable=unavailable,
        dropped=dropped,
        stragglers=stragglers,
        round_time=round_time,
        times=times,
        local_steps=local_steps,
    )


# ---------------------------------------------------------------------------
# Asynchronous dispatch schedule (FedBuff-style aggregation, core/async_agg.py)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DispatchEvent:
    """One simulated client dispatch resolved by :class:`AsyncTimeline`."""

    index: int  # global dispatch counter n
    wave: int  # participation wave (= plan_round index) this slot came from
    slot: int  # slot within the wave's cohort
    client: int  # population client id
    weight: float  # pre-discount FedAvg aggregation weight (n_k or 1) — NOT
    # τ_i/τ-scaled: fractional-progress scaling is the aggregator's weight policy
    duration: float  # simulated busy time, median-client-round units
    completes: bool  # False: never produced a delta (unavailable / dropped out)
    local_steps: int = 0  # realized τ_i under partial progress (0 = full τ)


class AsyncTimeline:
    """Deterministic dispatch schedule for the async aggregator.

    The async server keeps ``K = clients_per_round`` client slots busy: whenever
    a slot frees (its client completed, dropped out, or was unavailable), the
    next client is dispatched. Dispatch ``n`` resolves through the *same* pure
    participation layer as the sync round — wave ``n // K`` is ``plan_round(cfg,
    seed, n // K)``, slot ``n % K`` — so the n-th dispatch is a function of
    ``(cfg, seed, n)`` alone and a resumed run replays the identical timeline.

    The sync round's straggler deadline is deliberately stripped: under async
    aggregation a slow client *finishes late* (its completion time comes from the
    uncapped ``plan.times``) rather than being cut, which is the whole point of
    buffered aggregation. Speed heterogeneity, availability, data-size weights
    and mid-round dropout all still apply. Unavailable slots cost a small
    connection-attempt time so a mostly-offline population cannot spin the event
    loop at zero simulated cost.

    With ``cfg.partial_progress`` the deadline is kept but reinterpreted as a
    per-dispatch time *budget*: a slow client trains for τ_i = min(τ,
    ⌊τ·speed·deadline⌋) steps, uploads early (``duration`` shrinks to
    (τ_i/τ)·time), and the event carries ``local_steps`` so the aggregator can
    admit the delta at the fractional τ_i/τ weight. A client too slow for even
    one step holds its slot until the budget expires and produces nothing.
    """

    CONNECT_COST = 0.05  # failed-dispatch probe, median-client-round units

    def __init__(self, cfg: ParticipationConfig, seed: int):
        if cfg.partial_progress:
            # keep the deadline: plan_round turns it into per-client τ_i budgets
            self.cfg = cfg
        else:
            self.cfg = replace(cfg, straggler=replace(cfg.straggler, deadline=0.0))
        self.seed = seed
        self._plan_cache: Dict[int, ParticipationPlan] = {}

    def plan(self, wave: int) -> ParticipationPlan:
        if wave not in self._plan_cache:
            if len(self._plan_cache) > 4:  # slots free in order: old waves are dead
                self._plan_cache.clear()
            self._plan_cache[wave] = plan_round(self.cfg, self.seed, wave)
        return self._plan_cache[wave]

    def dispatch(self, n: int) -> DispatchEvent:
        wave, slot = divmod(n, self.cfg.clients_per_round)
        plan = self.plan(wave)
        client = int(plan.selected[slot])
        if plan.unavailable[slot]:
            return DispatchEvent(n, wave, slot, client, 0.0, self.CONNECT_COST, False)
        if plan.dropped[slot]:
            # mid-run failure: the slot is held for half the client's duration,
            # then freed with nothing to show for it
            return DispatchEvent(
                n, wave, slot, client, 0.0, 0.5 * float(plan.times[slot]), False
            )
        if plan.local_steps is not None:  # partial progress: deadline = budget
            tau_i = int(plan.local_steps[slot])
            if tau_i < 1:  # can't finish one step inside the budget: nothing
                return DispatchEvent(
                    n, wave, slot, client, 0.0,
                    float(self.cfg.straggler.deadline), False, 0,
                )
            duration = float(plan.times[slot]) * tau_i / self.cfg.local_steps
            return DispatchEvent(
                n, wave, slot, client,
                float(plan.weights[slot]), duration, True, tau_i,
            )
        return DispatchEvent(
            n, wave, slot, client,
            float(plan.weights[slot]), float(plan.times[slot]), True,
        )
