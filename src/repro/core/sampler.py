"""Reproducible client sampling (Algorithm 1, L.4): each round the server samples K
clients uniformly without replacement from the population P. Seeded and stateless —
`sample_round(seed, round, P, K)` is a pure function so runs are exactly resumable
(paper §6.1 "reproducible sampling").
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def sample_round(seed: int, round_idx: int, population: int, k: int) -> np.ndarray:
    """Deterministic K-of-P sample for a given round."""
    if k > population:
        raise ValueError(f"cannot sample {k} of {population}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, round_idx]))
    return np.sort(rng.choice(population, size=k, replace=False))


def participation_counts(seed: int, n_rounds: int, population: int, k: int) -> np.ndarray:
    counts = np.zeros(population, np.int64)
    for r in range(n_rounds):
        counts[sample_round(seed, r, population, k)] += 1
    return counts
