"""The unified server-side ``Aggregator`` seam (Photon's Aggregator, §4.1/§5.3).

Before this module, three code paths each owned an ad-hoc slice of server
state: the sync deadline round (``launch/train.py``'s loop), the async buffer
(``AsyncFederationDriver``'s event loop) and the checkpoint code all decided
independently who is admitted, at what weight, and what survives a restart.
That made the paper's resilience claims half-reproducible: a straggler's
partial work could not be credited anywhere, and async training could not be
resumed at all. This module centralizes the three server-side policies behind
one abstraction:

  (a) **admission rule** — who contributes to the next outer update.
      Sync: the ``ParticipationPlan`` mask (availability → dropout → deadline
      cut, or the partial-progress τ_i ≥ 1 rule). Async: the buffer door —
      zero-weight and over-``max_staleness`` arrivals are refused, everything
      else lands in a slot (``core/async_agg.admit_delta``).
  (b) **weight policy** — what an admitted delta counts for.
      Sync: FedAvg data-size weights scaled by the realized fraction τ_i/τ
      (:func:`partial_progress_weights` — the FedProx/FedNova-tradition
      fractional credit). Async: the same fractional weight, then the FedBuff
      staleness discount w/(1+s)^α at admission.
  (c) **canonical checkpoint schema** — what a resumable server IS.
      ``checkpoint()`` returns ``(state_pytree, manifest)``: the pytree holds
      every array lane (params, outer state, rng, buffer lanes, per-client
      error-feedback residuals, in-flight params snapshots) and the JSON-able
      manifest holds the host-side dispatch machine (cursor, per-slot
      completion times / dispatch indices / version tags) whose floats must
      round-trip exactly (JSON reprs do; float32 npz casts would not).

:class:`SyncAggregator` and :class:`AsyncBufferAggregator` implement the
seam; ``federated_round`` / ``federated_round_with_uplink`` stay the pure
jitted kernels underneath, and :class:`AsyncFederationDriver` is now a thin
event-loop shell over the async aggregator — it owns no state of its own.

Async resume (ROADMAP item 2) falls out of (c): the dispatch timeline is pure
in ``(cfg, seed, n)`` (``core/sampler.AsyncTimeline``), so persisting the
dispatch cursor plus each in-flight slot's ``(finish_time, dispatch_index,
version_tag, params_snapshot)`` is sufficient to replay the event loop from a
checkpoint *bitwise* — every future event, admission, flush and rng draw comes
out identical to the uninterrupted run (tested). The cost is explicit: a
checkpoint carries up to K in-flight params snapshots (leaves ``(K, ...)``).
"""
from __future__ import annotations

import heapq
from dataclasses import replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_agg import (
    AsyncAggConfig,
    admission_record,
    admit_delta,
    flush_buffer,
    init_async_state,
)
from repro.core.compression import Codec
from repro.core.federated import (
    FederatedConfig,
    SparseResidualStore,
    _finish_aggregate,
    _weigh_clients,
    apply_aggregate_partial,
    combine_tile_metrics,
    federated_round,
    federated_round_with_uplink,
    init_federated_state,
    run_client_tile,
    run_clients,
    tile_rng,
    trace_attrs,
)
from repro.core.inner_opt import global_norm
from repro.core.robust import (
    RobustAggConfig,
    RobustState,
    make_robust_apply_fn,
    normclip_scale,
    sanitize_deltas,
    tile_fold_finish,
    tile_fold_init,
    tile_fold_size,
    tile_fold_update,
)
from repro.obs.metrics import observe_staleness
from repro.obs.tracer import get_tracer
from repro.core.sampler import (
    AsyncTimeline,
    ParticipationConfig,
    ParticipationPlan,
    plan_round,
)

#: Version tag of the canonical checkpoint schema. Bump when the (pytree,
#: manifest) layout changes incompatibly; restore refuses a mismatched tag
#: instead of silently replaying a different state machine.
AGGREGATOR_SCHEMA_VERSION = 1


def _own(tree):
    """Copy a pytree's arrays so the aggregator exclusively owns them.

    Aggregators DONATE their state to the round/flush jits (in-place updates of
    the params-sized lanes instead of double-buffering). Donation invalidates
    the input arrays, so state built from caller-held arrays (the initial
    ``params``, a restored checkpoint pytree) must be copied once at
    construction — otherwise the first donated call would delete arrays the
    caller still references. Every later state is a jit output the aggregator
    owns outright."""
    return jax.tree_util.tree_map(jnp.array, tree)


# ---------------------------------------------------------------------------
# (b) the weight policy, shared by both aggregators
# ---------------------------------------------------------------------------


def partial_progress_weights(weights, local_steps, tau: int) -> np.ndarray:
    """Fractional-credit weight policy for straggler partial progress:
    w_i = n_k,i · τ_i/τ (zero where masked).

    A client that realized τ_i of the τ requested local steps contributed a
    proportionally smaller pseudo-gradient; scaling its FedAvg data-size weight
    by τ_i/τ keeps the aggregate an unbiased convex combination of per-step
    progress (the FedNova normalization, property-tested). With τ_i = τ for
    every client the scale is 1.0 exactly, so the policy is bitwise the plain
    FedAvg weight vector — the partial-progress round then reproduces the
    deadline round bit for bit.
    """
    w = np.asarray(weights, np.float32)
    if local_steps is None:
        return w
    frac = np.asarray(local_steps, np.float32) / np.float32(tau)
    return (w * frac).astype(np.float32)


# ---------------------------------------------------------------------------
# The seam
# ---------------------------------------------------------------------------


class Aggregator:
    """Base of the server-side aggregation seam.

    A concrete aggregator is a serializable state machine owning (a) the
    admission rule, (b) the weight policy and (c) the canonical checkpoint
    schema; the drivers (the sync training loop, the async event loop) only
    move data and never decide policy. ``checkpoint()`` returns
    ``(state_pytree, manifest)`` — the pytree goes through
    ``checkpoint.save_pytree`` (exact array round-trip), the manifest through
    the JSON round-side manifest (exact float64 round-trip).
    """

    kind = "base"
    #: optional :class:`repro.control.FederationController` closing the loop
    #: between observed metrics and this aggregator's knobs; ``None`` (or a
    #: static controller) keeps every code path bitwise the uncontrolled run
    controller = None

    def checkpoint(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        raise NotImplementedError

    # --- closed-loop control (docs/control.md) -----------------------------
    def apply_knobs(self, update) -> None:
        """Apply a :class:`~repro.control.KnobUpdate` to this aggregator's
        configuration. Only ever called between jitted steps (a round/flush
        boundary), so a knob change is a host-side config replace + jit
        rebuild at the new bucketed shape — never a mid-graph mutation."""
        raise NotImplementedError

    def control_step(self, row: Dict[str, Any]):
        """Feed one boundary metrics row to the attached controller and apply
        whatever it returns. A no-op without an active controller — the
        control seam costs the uncontrolled run nothing (bitwise, tested).
        Returns the applied ``KnobUpdate`` or ``None``."""
        c = self.controller
        if c is None or not c.enabled:
            return None
        update = c.observe(row)
        if update is None:
            return None
        self.apply_knobs(update)
        self._trace_knob_update(update)
        return update

    def _trace_knob_update(self, update) -> None:
        """Emit the applied update as an obs instant (with its evidence) and
        refresh the ``control_*`` gauges the metrics endpoint exports."""
        t = self.tracer
        if not t.enabled:
            return
        attrs: Dict[str, Any] = {
            f"knob_{k}": v for k, v in update.knob_dict().items()
        }
        attrs.update({f"evidence_{k}": v for k, v in update.evidence.items()})
        t.point("knob_update", parent=getattr(self, "_round_span", None), **attrs)
        t.count("knob_updates")
        for k, v in self.controller.knobs().items():
            t.gauge(f"control_{k}", float(v))

    @staticmethod
    def validate_manifest(manifest: Dict[str, Any], kind: str) -> None:
        """Refuse to restore from a manifest of the wrong kind or schema
        version — a silent mismatch would replay a different state machine."""
        if not isinstance(manifest, dict) or manifest.get("kind") != kind:
            raise ValueError(
                f"aggregator manifest kind {manifest.get('kind') if isinstance(manifest, dict) else manifest!r} "
                f"does not match this aggregator ({kind!r})"
            )
        if int(manifest.get("schema", -1)) != AGGREGATOR_SCHEMA_VERSION:
            raise ValueError(
                f"aggregator checkpoint schema {manifest.get('schema')!r} != "
                f"supported version {AGGREGATOR_SCHEMA_VERSION}"
            )

    def _manifest_header(self) -> Dict[str, Any]:
        return {"schema": AGGREGATOR_SCHEMA_VERSION, "kind": self.kind}


class SyncAggregator(Aggregator):
    """Synchronous federated aggregation as a state machine.

    Owns the server state pytree and the three policies:

      (a) admission — the ``ParticipationPlan``'s mask: availability → dropout
          → straggler handling. With ``partial_progress`` a slow client is
          admitted with the τ_i = min(τ, ⌊τ·speed·deadline⌋) steps it realized
          (cut only when τ_i < 1) instead of being dropped at the deadline.
      (b) weight policy — FedAvg data-size weights, scaled by τ_i/τ under
          partial progress (:func:`partial_progress_weights`).
      (c) checkpoint schema — the state pytree (params/outer/round/rng, plus a
          sparse ``uplink_residuals`` lane for stateful codecs: the rows of
          every ever-selected client, stacked in sorted-id order, with the id
          list in the manifest) and a ``{"schema", "kind", "round"
          [, "uplink_ids"]}`` manifest.

    ``run_round`` drives the pure jitted kernel (``federated_round``); weights,
    cohort residual rows and the τ-mask all enter as traced arguments, so
    per-round participation and per-client realized step counts never trigger
    a recompile. Error-feedback residuals live OUTSIDE the jitted state in a
    :class:`~repro.core.federated.SparseResidualStore` — the host gathers the
    cohort's rows before the round and scatters the updated rows back after,
    bitwise what the in-graph dense take/set did, with memory
    O(#ever-selected · N) instead of O(P · N).

    ``cohort_tile`` streams the cohort through the client phase ``C_tile``
    clients at a time (two-tier aggregation: Σ wΔ per tile, ONE divide) so the
    (C, N) delta buffer is bounded by C_tile; a single tile (C_tile == C) is
    bitwise the flat round (tested).
    """

    kind = "sync"

    def __init__(
        self,
        loss_fn: Callable,
        fed: FederatedConfig,
        pcfg: ParticipationConfig,
        *,
        codec: Optional[Codec] = None,
        seed: int = 0,
        partial_progress: bool = False,
        params=None,
        rng: Optional[jax.Array] = None,
        state: Optional[Dict[str, Any]] = None,
        shard_clients: Optional[Callable] = None,
        fused_server: bool = False,
        cohort_tile: Optional[int] = None,
        donate: bool = True,
        tracer=None,
        controller=None,
        robust: Optional[RobustAggConfig] = None,
    ):
        self.tracer = get_tracer(tracer)
        self.controller = controller
        if robust is not None and robust.active and fused_server:
            raise ValueError(
                "--fused-server is a plain weighted-mean flat-buffer pass and "
                "cannot host a robust rule or the delta screen — drop one of "
                "--fused-server / --robust-agg / --screen"
            )
        if robust is not None and cohort_tile is not None:
            if robust.screen:
                raise ValueError(
                    "the median/MAD delta screen needs the whole cohort's "
                    "norms in one pass and cannot compose with --cohort-tile "
                    "(tiles fold before the cohort median exists) — drop "
                    "--screen or --cohort-tile"
                )
            if robust.rule == "normclip" and robust.clip_norm <= 0.0:
                raise ValueError(
                    "adaptive norm-clipping (clip_norm=0) needs the cohort "
                    "median norm before any tile folds — use an absolute "
                    "--clip-norm with --cohort-tile"
                )
        self.robust = robust
        self.robust_state = (
            RobustState(robust) if robust is not None and robust.stateful else None
        )
        if partial_progress or pcfg.partial_progress:
            # the aggregator owns the policy: it teaches the participation
            # layer the round's τ so plan_round can derive per-client τ_i
            pcfg = replace(pcfg, partial_progress=True, local_steps=fed.local_steps)
        self.fed = fed
        self.pcfg = pcfg
        self.codec = codec
        self.seed = seed
        self.partial_progress = pcfg.partial_progress
        self.fused_server = fused_server
        if cohort_tile is not None:
            cohort_tile = int(cohort_tile)
            if cohort_tile < 1:
                raise ValueError(f"cohort_tile must be >= 1, got {cohort_tile}")
            if fed.keep_inner_state:
                raise ValueError(
                    "cohort tiling cannot keep per-client inner state across "
                    "rounds (the (K, ...)-shaped inner store is the memory "
                    "term tiling removes) — drop --keep-opt or --cohort-tile"
                )
            if fused_server:
                raise ValueError(
                    "--fused-server consumes the full (C, N) delta buffer with "
                    "pre-normalized weights, not the tiled partial-sum layout "
                    "— drop one of --fused-server / --cohort-tile"
                )
        self.cohort_tile = cohort_tile
        self.donate = donate
        self.residual_store = SparseResidualStore.create(
            codec, params if params is not None else (state or {}).get("params")
        )
        apply_fn = None
        if fused_server:
            # deferred: kernels/fedcore imports core modules for the seam types
            from repro.kernels.fedcore import fused_apply_aggregate

            apply_fn = fused_apply_aggregate
        elif robust is not None and robust.active and cohort_tile is None:
            # the robust server phase is a drop-in at the same apply_fn seam
            # the fused phase uses; the tiled path composes differently (a
            # per-tile order-statistic fold, built in _build_round_fn)
            apply_fn = make_robust_apply_fn(fed, robust)
        self._loss_fn = loss_fn
        self._shard_clients = shard_clients
        self._apply_fn = apply_fn
        if state is None:
            state = init_federated_state(fed, params, rng)
            # take ownership: the round jit donates the state (see _own)
            self.state = _own(state) if donate else state
        else:
            self.restore(state, None)
        self._build_round_fn()

    def _build_round_fn(self) -> None:
        """(Re)build the jitted round from the CURRENT ``self.fed``/codec.

        Called at construction and again by :meth:`apply_knobs` when the
        cohort-size knob changes: the round jit closes over ``fed`` (the
        cohort broadcast width), so a new K needs a fresh closure — XLA then
        retraces once at the new bucketed cohort shape.

        Flat path: one jit per round — ``(state, batches, weights[, residuals]
        [, tau])``. The cohort's error-feedback rows enter as a traced argument
        (the host gathers them from the sparse store), NOT via an in-state
        ``(P, ...)`` array, so the jitted computation never sees the
        population. Tiled path (``cohort_tile``): a tile jit replayed per
        C_tile slice plus the partial-sum server jit."""
        loss_fn, fed, codec = self._loss_fn, self.fed, self.codec
        shard_clients, apply_fn = self._shard_clients, self._apply_fn
        stateful = codec is not None and codec.stateful
        # the aggregator exclusively owns its state pytree (params, outer
        # lanes, rng — and the inner states under keep_inner_state), and every
        # round replaces it wholesale: donating it lets XLA update the
        # params-sized lanes in place instead of double-buffering them (a no-op
        # on backends without donation support). The gathered residual rows are
        # freshly stacked per round and replaced by the round's output rows, so
        # they donate too.
        if self.cohort_tile is not None:
            fed_tile = replace(fed, clients_per_round=self.cohort_tile)
            donate_kw = {"donate_argnums": (3,)} if self.donate else {}
            robust = self.robust
            robust_tiled = robust is not None and robust.active
            # the robust fold needs the tile's decoded per-client deltas (order
            # statistics cannot be recovered from the weighted partial sum);
            # the default path keeps the memory-minimal partial-sum-only output
            return_deltas = robust_tiled

            def _tile(s, b, w, res, tau):
                return run_client_tile(
                    loss_fn, fed_tile, s, b, w, shard_clients=shard_clients,
                    codec=codec, residuals=res, tau_steps=tau,
                    return_deltas=return_deltas,
                )

            self._tile_fn = jax.jit(_tile, **donate_kw)
            # donate the server state only: the Σ wΔ partial sums feed the
            # pseudo-gradient metrics as well as the update, so XLA cannot
            # alias their buffers (donating them would just warn)
            self._apply_partial_fn = jax.jit(
                lambda s, dsum, w, dn: apply_aggregate_partial(fed, s, dsum, w, dn),
                **({"donate_argnums": (0,)} if self.donate else {}),
            )
            self._fold_update_fn = self._fold_finish_fn = None
            self._tile_clip_fn = None
            if robust_tiled and robust.rule in ("trimmed", "median"):
                rule, trim = robust.rule, robust.trim_fraction

                def _fold_update(fold, deltas, norms, w):
                    admit = (w > 0) & jnp.isfinite(norms)
                    return tile_fold_update(
                        fold, sanitize_deltas(deltas, jnp.isfinite(norms)), admit
                    )

                def _fold_finish(fold, s, dn, w):
                    pg = tile_fold_finish(fold, rule, trim)
                    return _finish_aggregate(fed, s, pg, dn, w)

                self._fold_update_fn = jax.jit(
                    _fold_update,
                    **({"donate_argnums": (0,)} if self.donate else {}),
                )
                self._fold_finish_fn = jax.jit(
                    _fold_finish,
                    **({"donate_argnums": (1,)} if self.donate else {}),
                )
            elif robust_tiled and robust.rule == "normclip":
                tau_clip = float(robust.clip_norm)  # absolute-only with tiles

                def _clip_sum(deltas, norms, w):
                    admit = (w > 0) & jnp.isfinite(norms)
                    scale = normclip_scale(
                        norms, admit, jnp.asarray(tau_clip, jnp.float32)
                    )
                    clean = sanitize_deltas(deltas, jnp.isfinite(norms))
                    return jax.tree_util.tree_map(
                        lambda x: jnp.sum(
                            _weigh_clients(x, w.astype(jnp.float32) * scale),
                            axis=0,
                        ),
                        clean,
                    )

                self._tile_clip_fn = jax.jit(_clip_sum)
            self._round_fn = None
            return
        self._tile_fn = self._apply_partial_fn = None
        self._fold_update_fn = self._fold_finish_fn = self._tile_clip_fn = None
        donate = (0, 3) if stateful else (0,)
        donate_kw = {"donate_argnums": donate} if self.donate else {}
        if self.partial_progress and stateful:
            self._round_fn = jax.jit(
                lambda s, b, w, res, tau: federated_round(
                    loss_fn, fed, s, b, client_weights=w, codec=codec,
                    residuals=res, shard_clients=shard_clients, tau_steps=tau,
                    apply_fn=apply_fn,
                ),
                **donate_kw,
            )
        elif self.partial_progress:
            self._round_fn = jax.jit(
                lambda s, b, w, tau: federated_round(
                    loss_fn, fed, s, b, client_weights=w, codec=codec,
                    shard_clients=shard_clients, tau_steps=tau, apply_fn=apply_fn,
                ),
                **donate_kw,
            )
        elif stateful:
            self._round_fn = jax.jit(
                lambda s, b, w, res: federated_round(
                    loss_fn, fed, s, b, client_weights=w, codec=codec,
                    residuals=res, shard_clients=shard_clients, apply_fn=apply_fn,
                ),
                **donate_kw,
            )
        else:
            self._round_fn = jax.jit(
                lambda s, b, w: federated_round(
                    loss_fn, fed, s, b, client_weights=w, codec=codec,
                    shard_clients=shard_clients, apply_fn=apply_fn,
                ),
                **donate_kw,
            )

    def apply_knobs(self, update) -> None:
        """Apply a sync :class:`KnobUpdate` between rounds.

        The deadline is a host-side planning scalar (free); a new
        ``clients_per_round`` changes the cohort broadcast width, so both the
        participation config and the federated config move together and the
        round jit is rebuilt (one retrace per bucketed K)."""
        if update.staleness_alpha is not None or update.buffer_size is not None:
            raise ValueError(
                "sync aggregator has no async knobs (staleness_alpha/"
                "buffer_size belong to --aggregation async)"
            )
        if update.deadline is not None:
            self.pcfg = replace(
                self.pcfg,
                straggler=replace(
                    self.pcfg.straggler, deadline=float(update.deadline)
                ),
            )
        if update.clients_per_round is not None:
            k = int(update.clients_per_round)
            if self.fed.keep_inner_state:
                raise ValueError(
                    "cohort control cannot resize the keep_inner_state lanes "
                    "(the persisted inner optimizer state is (K, ...)-shaped) "
                    "— drop --keep-opt or use --control static"
                )
            self.pcfg = replace(self.pcfg, clients_per_round=k)
            self.fed = replace(self.fed, clients_per_round=k)
            self._build_round_fn()

    # --- (a) admission ---------------------------------------------------
    def plan(self, round_idx: int) -> ParticipationPlan:
        """Resolve the round's admission decisions — pure in (cfg, seed, r)."""
        return plan_round(self.pcfg, self.seed, round_idx)

    # --- (b) weight policy -----------------------------------------------
    def round_weights(self, plan: ParticipationPlan) -> np.ndarray:
        """(K,) aggregation weights for the plan's cohort under this
        aggregator's policy (fractional τ_i/τ credit when partial progress)."""
        return partial_progress_weights(
            plan.weights, plan.local_steps, self.fed.local_steps
        )

    def tau_steps(self, plan: ParticipationPlan) -> Optional[np.ndarray]:
        """The (K,) τ-mask handed to the jitted round. Masked (zero-weight)
        slots keep the FULL τ so their lanes compute exactly what the
        non-partial round computed (their output is weight-masked anyway) —
        this is what keeps 'everyone at full speed' bitwise identical even
        when dropout masks part of the cohort."""
        if plan.local_steps is None:
            return None
        return np.where(
            plan.mask, plan.local_steps, self.fed.local_steps
        ).astype(np.int32)

    # --- the round -------------------------------------------------------
    def run_round(self, batches, plan: ParticipationPlan) -> Dict[str, jax.Array]:
        """One full round under this aggregator's policies; advances the
        owned state and returns the jitted round's metrics."""
        t = self.tracer
        rs = self.robust_state
        if t.enabled or rs is not None:
            rid = int(self.state["round"])
        if t.enabled:
            t.begin("round", span_id=f"r{rid}", round=rid,
                    effective_k=float(plan.effective_k), track=0)
        w = jnp.asarray(self.round_weights(plan))
        if rs is not None and rs.quarantine:
            # quarantined population ids are zero-weighted for this round —
            # the same masked-round mechanism dropout uses, so no recompiles.
            # Skipped entirely when the table is empty (bitwise-neutral).
            q = np.asarray(
                [rs.is_quarantined(int(c), rid) for c in np.asarray(plan.selected)]
            )
            if q.any():
                w = jnp.where(jnp.asarray(q), 0.0, w)
        if self.cohort_tile is not None:
            metrics = self._run_round_tiled(batches, plan, w)
        else:
            metrics = self._run_round_flat(batches, plan, w)
        metrics = dict(metrics)
        screen_mask = metrics.pop("screen_mask", None)
        if screen_mask is not None and rs is not None:
            flagged = np.nonzero(np.asarray(screen_mask) > 0)[0]
            if len(flagged):
                sel = np.asarray(plan.selected)
                cids = [int(sel[i]) for i in flagged]
                rs.note_screen_rejects(len(cids))
                rs.add_quarantine(cids, rid)
                if t.enabled:
                    for cid in cids:
                        t.point("screen_reject", parent=f"r{rid}",
                                client=cid, round=rid)
                        t.count("screen_rejects")
        if t.enabled:
            attrs = trace_attrs(metrics)  # the one device sync tracing pays
            t.end(f"r{rid}", **attrs)
            t.count("rounds")
            t.gauge("round", rid + 1)
            for k, v in attrs.items():
                t.gauge(k, v)
        return metrics

    def _run_round_flat(self, batches, plan: ParticipationPlan, w) -> Dict[str, jax.Array]:
        """One cohort-wide jitted round; host gather/scatter of the cohort's
        error-feedback rows around it (bitwise the old in-graph dense
        take/set — the gathered values are identical)."""
        stateful = self.residual_store is not None
        args = [self.state, batches, w]
        if stateful:
            args.append(self.residual_store.gather(plan.selected))
        if self.partial_progress:
            args.append(jnp.asarray(self.tau_steps(plan), jnp.int32))
        self.state, metrics = self._round_fn(*args)
        if stateful:
            # `federated_round` returns the cohort's updated rows in-state;
            # they belong in the population store, not the jitted state
            self.residual_store.scatter(
                plan.selected, self.state.pop("uplink_residuals")
            )
        return metrics

    def _run_round_tiled(self, batches, plan: ParticipationPlan, w) -> Dict[str, jax.Array]:
        """Streamed round: the cohort crosses the client phase ``cohort_tile``
        clients at a time; each tile folds into Σ wΔ partial sums
        (:func:`run_client_tile`), and :func:`apply_aggregate_partial` performs
        the single server-side divide — the ``hierarchical_mean`` algebra, so
        the (C, N) delta buffer never materializes. The last tile pads to the
        tile width with zero-weight slots (zero batch, zero residual row);
        pads add exact zeros everywhere and never touch the residual store.

        One tile (``cohort_tile == C``) is bitwise the flat round: tile 0 runs
        on the round's own rng lane and the partial divide/DP-noise/outer
        sequence mirrors ``apply_aggregate`` op for op."""
        C = self.fed.clients_per_round
        ct = self.cohort_tile
        n_tiles = -(-C // ct)
        stateful = self.residual_store is not None
        w_np = np.asarray(w, np.float32)
        tau_np = (
            np.asarray(self.tau_steps(plan), np.int32)
            if self.partial_progress else None
        )
        w_full = np.zeros(n_tiles * ct, np.float32)
        w_full[:C] = w_np
        core = {"params": self.state["params"], "round": self.state["round"]}
        base_rng = self.state["rng"]
        delta_sum = None
        delta_norms = []
        tile_outs = []
        fold = None
        if self._fold_update_fn is not None:
            k = tile_fold_size(
                self.robust.rule, self.robust.trim_fraction, n_tiles * ct
            )
            fold = tile_fold_init(self.state["params"], k)
        for t_idx in range(n_tiles):
            lo, hi = t_idx * ct, min((t_idx + 1) * ct, C)
            n_real = hi - lo

            def _pad(x, axis=0):
                if n_real == ct:
                    return x
                shape = list(x.shape)
                shape[axis] = ct - n_real
                return jnp.concatenate(
                    [x, jnp.zeros(shape, x.dtype)], axis=axis
                )

            b_t = jax.tree_util.tree_map(
                lambda x: _pad(x[:, lo:hi], axis=1), batches
            )
            w_t = jnp.asarray(w_full[t_idx * ct:(t_idx + 1) * ct])
            res_t = None
            if stateful:
                res_t = jax.tree_util.tree_map(
                    _pad, self.residual_store.gather(plan.selected[lo:hi])
                )
            tau_t = None
            if tau_np is not None:
                # pad slots take the FULL τ (the tau_steps() discipline: their
                # output is weight-masked anyway, and full-τ lanes keep the
                # non-partial bitwise identity)
                tau_t = jnp.asarray(
                    np.concatenate(
                        [tau_np[lo:hi],
                         np.full(ct - n_real, self.fed.local_steps, np.int32)]
                    )
                )
            s_t = dict(core, rng=tile_rng(base_rng, t_idx))
            out = self._tile_fn(s_t, b_t, w_t, res_t, tau_t)
            if stateful:
                rows = out.pop("residuals")
                self.residual_store.scatter(
                    plan.selected[lo:hi],
                    jax.tree_util.tree_map(lambda x: x[:n_real], rows),
                )
            ds = out.pop("delta_sum")
            dn_t = out.pop("delta_norms")
            if self._fold_update_fn is not None:
                # robust tiled (trimmed/median): fold per-tile order-statistic
                # moments instead of the weighted partial sum
                fold = self._fold_update_fn(fold, out.pop("deltas"), dn_t, w_t)
            elif self._tile_clip_fn is not None:
                # robust tiled normclip: clip each client within its tile at
                # the absolute τ, then the standard Σ wΔ accumulation
                ds = self._tile_clip_fn(out.pop("deltas"), dn_t, w_t)
                delta_sum = ds if delta_sum is None else jax.tree_util.tree_map(
                    jnp.add, delta_sum, ds
                )
            else:
                delta_sum = ds if delta_sum is None else jax.tree_util.tree_map(
                    jnp.add, delta_sum, ds
                )
            delta_norms.append(dn_t)
            tile_outs.append(out)
        if self._fold_update_fn is not None:
            new_state, agg_metrics = self._fold_finish_fn(
                fold, self.state, jnp.concatenate(delta_norms),
                jnp.asarray(w_full),
            )
        else:
            new_state, agg_metrics = self._apply_partial_fn(
                self.state, delta_sum, jnp.asarray(w_full),
                jnp.concatenate(delta_norms),
            )
        self.state = new_state
        return dict(combine_tile_metrics(tile_outs), **agg_metrics)

    # --- (c) checkpoint schema -------------------------------------------
    def checkpoint(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        # a COPY, not the live state: the round jit donates self.state, so a
        # caller that serializes the checkpoint after the next round would
        # otherwise hold deleted arrays
        manifest = dict(self._manifest_header(), round=int(self.state["round"]))
        tree = _own(self.state)
        if self.residual_store is not None:
            # sparse lane: every ever-selected client's row, stacked in
            # sorted-id order; the id list rides the manifest so the load
            # template can be sized without touching the npz
            manifest["uplink_ids"] = self.residual_store.ids()
            tree["uplink_residuals"] = _own(self.residual_store.stacked())
        if self.controller is not None and self.controller.enabled:
            # controller state rides the manifest (JSON floats round-trip
            # exactly); absent entirely for static/None, keeping the default
            # checkpoint byte-identical to the uncontrolled schema
            manifest["control"] = self.controller.state_dict()
        if self.robust_state is not None:
            # defense state (quarantine table, guard window, counters) rides
            # the manifest like the controller's — absent when the defense is
            # off, keeping the undefended checkpoint byte-identical to PR-9's
            manifest["robust"] = self.robust_state.state_dict()
        return tree, manifest

    def adopt_model(self, tree: Dict[str, Any]) -> None:
        """Adopt a rolled-back ``{params, outer}`` subset (divergence rollback):
        the model and outer-optimizer lanes rewind to the blessed checkpoint
        while ``round`` and ``rng`` keep advancing monotonically — a resumed
        run replays the same rollback at the same round, bitwise, and the
        round counter can never livelock."""
        self.state = dict(
            self.state, params=_own(tree["params"]), outer=_own(tree["outer"])
        )

    def restore(self, state: Dict[str, Any], manifest: Optional[Dict[str, Any]] = None) -> None:
        """Adopt a restored checkpoint pytree (+ its aggregator manifest).

        The ``uplink_residuals`` lane is routed into the sparse store: with
        ``manifest['uplink_ids']`` it is the sparse stacked layout; without
        (a legacy dense checkpoint) a ``(population, ...)`` lane converts via
        ``from_dense`` — all-zero (never-selected) rows stay unmaterialized,
        which is how a PR-8 dense checkpoint resumes bitwise with flat memory.
        """
        state = dict(state)
        res = state.pop("uplink_residuals", None)
        stateful = self.codec is not None and self.codec.stateful
        if res is not None and not stateful:
            raise ValueError(
                "restored state carries per-client error-feedback residuals "
                "but this aggregator's codec is not stateful — pass the codec "
                "the checkpoint was written with"
            )
        if res is not None:
            params_like = state["params"]
            ids = manifest.get("uplink_ids") if isinstance(manifest, dict) else None
            leading = jax.tree_util.tree_leaves(res)[0].shape[0]
            if ids is not None:
                self.residual_store = SparseResidualStore.from_stacked(
                    params_like, ids, res
                )
            elif leading == self.pcfg.population:
                self.residual_store = SparseResidualStore.from_dense(
                    params_like, res
                )
            else:
                raise ValueError(
                    f"uplink_residuals lane has leading dim {leading}, which "
                    f"matches neither the manifest's uplink_ids (absent) nor "
                    f"the dense (population={self.pcfg.population}, ...) layout"
                )
        if (
            self.robust_state is not None
            and isinstance(manifest, dict)
            and "robust" in manifest
        ):
            # a legacy (PR-9) manifest simply has no 'robust' key: the defense
            # starts from a clean slate, and the restored lanes are untouched
            self.robust_state.load_state_dict(manifest["robust"])
        self.state = _own(state) if self.donate else state

    @classmethod
    def checkpoint_template(
        cls,
        fed: FederatedConfig,
        pcfg: ParticipationConfig,
        params_like,
        codec: Optional[Codec] = None,
        uplink_ids=None,
    ) -> Dict[str, Any]:
        """Abstract state pytree matching ``checkpoint()[0]`` — the ``like``
        argument for ``checkpoint.load_pytree``.

        ``uplink_ids`` (the manifest's recorded id set) sizes the sparse
        residual lane; ``None`` falls back to the legacy dense ``(P, ...)``
        layout. Either way the lane is ``jax.ShapeDtypeStruct`` leaves — a
        template never allocates the store it describes."""
        state = init_federated_state(fed, params_like, jax.random.PRNGKey(0))
        if codec is not None and codec.stateful:
            n = pcfg.population if uplink_ids is None else len(uplink_ids)
            state["uplink_residuals"] = jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(
                    (n,) + tuple(p.shape), jnp.float32
                ),
                params_like,
            )
        return state


class AsyncBufferAggregator(Aggregator):
    """Asynchronous (FedBuff-style) buffered aggregation as a state machine.

    Everything the old event-loop driver used to own now lives here, split by
    the seam's three concerns:

      (a) admission — ``admit()``: the jitted buffer door (staleness tagged
          against the server version, zero-weight / over-``max_staleness``
          arrivals refused without consuming a slot) plus the dispatch-side
          rule that a population client holds at most one slot at a time.
      (b) weight policy — ``event_weight()`` credits a completion its
          fractional τ_i/τ under partial progress; the staleness discount
          w/(1+s)^α is applied in-graph at admission.
      (c) checkpoint schema — ``checkpoint()``: the server pytree (buffer
          lanes included), the per-client error-feedback residual store, the
          K in-flight params snapshots (stacked ``(K, ...)``) and the
          host-side dispatch manifest (cursor, per-slot finish/index/version).
          Because the timeline is pure in ``(cfg, seed, n)``, restoring these
          replays the run bitwise from the checkpoint.

    The event loop (``step``/``run_updates``) lives in the thin
    :class:`AsyncFederationDriver` subclass; this class never touches data or
    loss functions.
    """

    kind = "async"

    def __init__(
        self,
        fed: FederatedConfig,
        acfg: AsyncAggConfig,
        pcfg: ParticipationConfig,
        *,
        seed: int = 0,
        params=None,
        rng: Optional[jax.Array] = None,
        state: Optional[Dict[str, Any]] = None,
        codec: Optional[Codec] = None,
        dispatch: Optional[Dict[str, Any]] = None,
        fused_server: bool = False,
        tracer=None,
        controller=None,
        robust: Optional[RobustAggConfig] = None,
    ):
        self.fed = fed
        self.acfg = acfg
        self.pcfg = pcfg
        self.codec = codec
        self.seed = seed
        self.fused_server = fused_server
        self.tracer = get_tracer(tracer)
        self.controller = controller
        if robust is not None and robust.active and fused_server:
            raise ValueError(
                "--fused-server is a plain weighted-mean flat-buffer pass and "
                "cannot host a robust rule or the delta screen — drop one of "
                "--fused-server / --robust-agg / --screen"
            )
        self.robust = robust
        self.robust_state = (
            RobustState(robust) if robust is not None and robust.stateful else None
        )
        #: optional host hook corrupting a delta before admission — the
        #: Byzantine-client simulator for benches (``make_byzantine_fn``);
        #: None on every honest run
        self.corrupt_fn = None
        if pcfg.partial_progress and pcfg.local_steps != fed.local_steps:
            raise ValueError(
                "pcfg.local_steps must equal fed.local_steps under partial "
                f"progress (got {pcfg.local_steps} vs {fed.local_steps})"
            )
        stateful = codec is not None and codec.stateful
        self._stateful = stateful
        apply_fn = None
        if fused_server:
            from repro.kernels.fedcore import fused_apply_aggregate

            apply_fn = fused_apply_aggregate
        elif robust is not None and robust.rule != "none":
            # the robust rule guards each FLUSH over the buffer lanes; the
            # screen is enforced earlier, at the admission door, so the flush
            # phase runs with screening off (the buffer only holds admitted
            # deltas — but may still hold pre-warmup poison, which sanitize
            # and the NaN-aware metrics inside the robust phase absorb)
            apply_fn = make_robust_apply_fn(fed, replace(robust, screen=False))
        self._apply_fn = apply_fn
        self._build_agg_fns()
        if state is None:
            state = init_async_state(fed, acfg, params, rng)
        else:
            state = dict(state)  # may carry residuals/in-flight lanes
        inflight = state.pop("inflight_params", None)
        uplink_rng = state.pop("uplink_rng", None)
        restored_res = state.pop("uplink_residuals", None)
        # take ownership of everything the admit/flush jits donate (every lane
        # but params — params is aliased by in-flight snapshots, never donated)
        self.state = dict(
            state, **_own({k: v for k, v in state.items() if k != "params"})
        )
        if restored_res is not None and not stateful:
            raise ValueError(
                "restored state carries per-client error-feedback residuals but "
                "the driver's codec is not stateful — pass the codec the "
                "checkpoint was written with, or strip 'uplink_residuals' to "
                "deliberately discard the clients' accumulated feedback"
            )
        # the residual store is SPARSE: an empty id→row map at a fresh start
        # (flat memory in P — a row materializes the first time its client is
        # dispatched), rebuilt from the checkpoint's recorded id set on resume
        self.residuals: Optional[SparseResidualStore] = None
        if stateful:
            params_like = self.state["params"]
            if restored_res is None:
                self.residuals = SparseResidualStore(params_like)
            else:
                ids = (
                    dispatch.get("uplink_ids")
                    if isinstance(dispatch, dict) else None
                )
                leading = jax.tree_util.tree_leaves(restored_res)[0].shape[0]
                if ids is not None:
                    self.residuals = SparseResidualStore.from_stacked(
                        params_like, ids, restored_res
                    )
                elif leading == pcfg.population:
                    # legacy PR-3 dense (P, ...) layout: all-zero rows stay
                    # unmaterialized, so the resume is bitwise AND flat-memory
                    self.residuals = SparseResidualStore.from_dense(
                        params_like, restored_res
                    )
                else:
                    raise ValueError(
                        f"uplink_residuals lane has leading dim {leading}, "
                        f"which matches neither the dispatch manifest's "
                        f"uplink_ids (absent) nor the dense "
                        f"(population={pcfg.population}, ...) layout"
                    )
            self._res_norm_fn = jax.jit(global_norm)
        self._bytes_per_upload = (
            float(codec.nbytes(self.state["params"])) if codec is not None
            else 4.0 * sum(
                x.size for x in jax.tree_util.tree_leaves(self.state["params"])
            )
        )
        if codec is not None:
            # derived once per RUN from the then-current rng, never consumed in
            # graph — restored verbatim from the checkpoint so a resumed run's
            # stochastic-rounding draws match the uninterrupted run's
            self._uplink_rng = (
                uplink_rng if uplink_rng is not None
                else jax.random.fold_in(self.state["rng"], 0x55504C4B)
            )
        else:
            self._uplink_rng = None
        self.uplink_bytes_total = 0.0  # bytes actually uploaded (incl. rejected)
        self.timeline = AsyncTimeline(pcfg, seed)
        self.sim_time = 0.0
        self.work_completed = 0.0  # simulated client-time that reached the buffer
        self.work_wasted = 0.0  # dropout / rejected-staleness client-time
        self.n_dispatched = 0  # the dispatch CURSOR — serialized for resume
        self._heap: List[Tuple[float, int, Any, Any, int]] = []
        self._busy: set = set()  # population client ids currently holding a slot
        self._losses: List[float] = []  # client train losses since last flush
        self._staleness: List[float] = []  # admitted staleness since last flush
        self._res_norms: List[float] = []  # EF residual norms since last flush
        # the server-side round span: dispatch spans of version v parent into
        # "u{v}"; _flush_row rotates it when a flush bumps the version
        self._round_span = f"u{int(self.state['round'])}" if self.tracer.enabled else None
        if self.tracer.enabled:
            self.tracer.begin("round", span_id=self._round_span,
                              round=int(self.state["round"]), track=0)
        if dispatch is not None:
            if self.robust_state is not None and "robust" in dispatch:
                # a legacy (PR-9) manifest has no 'robust' key: the defense
                # starts from a clean slate over the restored lanes
                self.robust_state.load_state_dict(dispatch["robust"])
            self._restore_dispatch(dispatch, inflight)
        else:
            for _ in range(pcfg.clients_per_round):
                self._dispatch()

    def _build_agg_fns(self) -> None:
        """(Re)build the admission/flush jits from the CURRENT ``self.acfg``.

        Called at construction and again by :meth:`apply_knobs`: both jits
        close over ``acfg`` (α enters the staleness discount in-graph, M fixes
        the buffer-lane shapes), so a knob change needs fresh closures — the
        governor's bucketed grids (α on 1/16 steps, M on powers of two) bound
        the retraces to a handful per run.

        (a) admission + flush as standalone jits: the flush then compiles in
        the same fusion context as the sync server phase, keeping the
        buffer_size==K / α==0 path bitwise-equal to federated_round.
        DONATION: the buffer lanes, outer state and rng are exclusively owned
        and replaced on every call, so they donate — but ``params`` must NOT:
        the in-flight dispatch slots snapshot the params pytree BY REFERENCE,
        and donating it would invalidate those snapshots. The state splits
        into (params, rest) at each call so only ``rest`` donates."""
        fed, acfg, codec = self.fed, self.acfg, self.codec
        apply_fn = self._apply_fn
        self._screen = self.robust is not None and self.robust.screen
        if self._screen:
            # the screened door: non-finite rejection always, plus the
            # adaptive norm bound (a traced scalar — the host recomputes it
            # from the admitted-norm history, so no recompiles as it tightens)
            self._admit_fn = jax.jit(
                lambda p, rest, d, r, w, nb: admit_delta(
                    fed, acfg, dict(rest, params=p), d, r, w, auto_flush=False,
                    codec=codec, screen=True, norm_bound=nb,
                ),
                donate_argnums=(1,),
            )
        else:
            self._admit_fn = jax.jit(
                lambda p, rest, d, r, w: admit_delta(
                    fed, acfg, dict(rest, params=p), d, r, w, auto_flush=False,
                    codec=codec,
                ),
                donate_argnums=(1,),
            )
        self._flush_fn = jax.jit(
            lambda p, rest: flush_buffer(
                fed, acfg, dict(rest, params=p), apply_fn=apply_fn
            ),
            donate_argnums=(1,),
        )

    def apply_knobs(self, update) -> None:
        """Apply an async :class:`KnobUpdate` at a flush boundary.

        ``staleness_alpha`` changes the in-graph discount (jit rebuild);
        ``buffer_size`` additionally reshapes the buffer lanes, which is only
        sound when the buffer is EMPTY — every flush drains it, and
        ``control_step`` runs inside ``_flush_row``, so the invariant holds by
        construction (and is asserted here against misuse). The dispatch
        timeline is pure in ``(pcfg, seed)`` and neither knob touches it, so a
        governed run stays exactly resumable."""
        if update.clients_per_round is not None or update.deadline is not None:
            raise ValueError(
                "async control drives staleness_alpha/buffer_size only: the "
                "dispatch timeline is pure in (participation config, seed) "
                "and cannot change mid-run (cohort/deadline are sync knobs)"
            )
        acfg = self.acfg
        if update.staleness_alpha is not None:
            acfg = replace(acfg, staleness_alpha=float(update.staleness_alpha))
        if (
            update.buffer_size is not None
            and int(update.buffer_size) != acfg.buffer_size
        ):
            if int(self.state["buf_count"]) != 0:
                raise RuntimeError(
                    f"buffer resize with {int(self.state['buf_count'])} "
                    f"buffered deltas — knob updates must land at a flush "
                    f"boundary (the buffer drains at every flush)"
                )
            m = int(update.buffer_size)
            acfg = replace(acfg, buffer_size=m)
            params = self.state["params"]
            self.state = dict(
                self.state,
                buffer=jax.tree_util.tree_map(
                    lambda p: jnp.zeros((m,) + p.shape, jnp.float32), params
                ),
                buf_weights=jnp.zeros((m,), jnp.float32),
                buf_staleness=jnp.zeros((m,), jnp.float32),
            )
        if acfg != self.acfg:
            self.acfg = acfg
            self._build_agg_fns()
        self._notify_knobs(update)

    def _notify_knobs(self, update) -> None:
        """Hook fired after a knob update is applied server-side; the
        cross-process runtime overrides this to expose the live knob values
        through the backend's metrics extras."""

    # --- dispatch machinery (serialized state) ----------------------------
    def _dispatch(self) -> None:
        # a client can only run in one slot at a time: skip timeline entries for
        # clients already in flight (zero simulated cost — the scheduler simply
        # picks the next free client from the sampler stream). Termination: at
        # refill time at most K−1 clients are busy and every wave holds K
        # distinct clients, so a free client appears within two waves.
        for _ in range(64 * self.timeline.cfg.clients_per_round):
            ev = self.timeline.dispatch(self.n_dispatched)
            self.n_dispatched += 1
            if ev.client not in self._busy:
                break
        else:  # pragma: no cover — unreachable by the argument above
            raise RuntimeError("async dispatch starved: every client busy")
        # every dispatch holds its client for the event duration — including an
        # unavailable client's connect probe, during which no other slot should
        # be contacting it either
        self._busy.add(ev.client)
        # snapshot by reference: jax arrays are immutable, so holding the params
        # of up to K in-flight versions costs no copies
        snapshot = self.state["params"] if ev.completes else None
        version = int(self.state["round"])
        heapq.heappush(
            self._heap, (self.sim_time + ev.duration, ev.index, ev, snapshot, version)
        )
        self._on_dispatch(ev, snapshot, version)
        self._trace_dispatch(ev, version)

    # --- telemetry (read-only: never touches the aggregation math) ---------
    def _trace_dispatch(self, ev, version: int) -> None:
        """Open the dispatch span ``d{index}`` under the round span of the
        version its params snapshot was taken at. One display track per
        population client so concurrent slots render as parallel bars."""
        if not self.tracer.enabled:
            return
        self.tracer.begin(
            "dispatch", span_id=f"d{ev.index}", parent=f"u{version}",
            index=ev.index, client=int(ev.client), version=version,
            completes=bool(ev.completes), track=1 + int(ev.client),
        )
        self.tracer.count("dispatches")

    def _trace_complete(self, ev, outcome: str, staleness=None) -> None:
        """Close a dispatch span with its terminal outcome."""
        if not self.tracer.enabled:
            return
        attrs: Dict[str, Any] = {"outcome": outcome}
        if staleness is not None:
            attrs["staleness"] = float(staleness)
        self.tracer.end(f"d{ev.index}", **attrs)
        self.tracer.count(f"outcome_{outcome}")

    def _trace_admit(self, ev, metrics) -> Dict[str, Any]:
        """Record one admission decision (instant + counters + histogram) and
        return the host-side record; ``{}`` when tracing is off."""
        if not self.tracer.enabled:
            return {}
        rec = admission_record(metrics)
        self.tracer.point("admit", parent=f"d{ev.index}", index=ev.index,
                          client=int(ev.client), **rec)
        if rec["accepted"]:
            self.tracer.count("admits")
            observe_staleness(self.tracer, rec["staleness"])
        else:
            self.tracer.count("admit_rejects")
        self.tracer.gauge(
            "buffer_occupancy", rec.get("buf_count", 0.0) / self.acfg.buffer_size
        )
        return rec

    def _on_dispatch(self, ev, snapshot, version: int) -> None:
        """Hook fired once per dispatched slot — including replayed slots on
        restore. The cross-process runtime overrides this to hand the slot's
        fully self-describing work assignment (params snapshot, version tag,
        residual row, per-dispatch rng) to a client backend; the in-process
        simulator needs nothing."""

    def _pop_completion(self):
        finish, _, ev, snapshot, version = heapq.heappop(self._heap)
        self.sim_time = max(self.sim_time, finish)
        self._busy.discard(ev.client)
        return ev, snapshot, version

    # --- per-client error-feedback rows (sparse store accessors) ----------
    @staticmethod
    def _res_gather(store: SparseResidualStore, cid):
        """One client's EF row as a (1, ...) tree — what the old dense
        ``r[cid][None]`` jit returned; a never-dispatched client reads zeros
        (the dense store's initial value, bitwise)."""
        return jax.tree_util.tree_map(lambda r: r[None], store.row(int(cid)))

    @staticmethod
    def _res_scatter(store: SparseResidualStore, cid, new):
        """Write a client's updated (1, ...) row back, materializing it on
        first touch; returns the store (the old donating-jit calling
        convention, so the drivers' ``self.residuals = _res_scatter(...)``
        call sites read identically)."""
        store.scatter([int(cid)], new)
        return store

    # --- (a)/(b): admission + weight policy -------------------------------
    def event_weight(self, ev) -> float:
        """Pre-discount credit of a completion: the plan's FedAvg weight,
        scaled by the realized fraction τ_i/τ under partial progress (the
        staleness discount is applied in-graph at admission)."""
        if self.pcfg.partial_progress and ev.local_steps:
            return float(ev.weight) * ev.local_steps / self.pcfg.local_steps
        return float(ev.weight)

    def _split_state(self):
        """(params, rest): params is aliased by in-flight snapshots and never
        donated; everything else is exclusively owned and donates."""
        return (
            self.state["params"],
            {k: v for k, v in self.state.items() if k != "params"},
        )

    def admit(self, delta, version: int, weight: float) -> Dict[str, jax.Array]:
        """Admit one (decoded-at-the-door) upload tagged with the model version
        it was computed against; rejected arrivals consume nothing."""
        params, rest = self._split_state()
        args = (
            params, rest, delta,
            jnp.asarray(version, jnp.int32), jnp.asarray(weight, jnp.float32),
        )
        if self._screen:
            bound = (
                self.robust_state.norm_bound()
                if self.robust_state is not None else float("inf")
            )
            args = args + (jnp.asarray(bound, jnp.float32),)
        self.state, m = self._admit_fn(*args)
        return m

    def _note_admission(self, ev, m) -> None:
        """Host-side defense bookkeeping for one admission outcome. Every
        finite norm seen at the door — admitted or screened — feeds the
        adaptive bound: median/MAD is contamination-robust as long as
        attackers stay a minority of recent traffic, and learning only from
        accepted norms would freeze the bound the moment it started rejecting
        honest drift. Screen rejections are traced as ``screen_reject``
        instants; only *non-finite* payloads quarantine the sender — a single
        norm-bound miss is weak temporal evidence, and quarantine release is
        round-indexed, so quarantining the honest majority would halt round
        progress and never expire."""
        rs = self.robust_state
        if rs is None or "delta_norm" not in m:
            return
        norm = float(m["delta_norm"])
        finite = norm == norm and abs(norm) != float("inf")
        if finite:
            rs.observe_norm(norm)
        if float(m["accepted"]) <= 0 and float(m.get("screened", 0.0)) > 0:
            rs.note_screen_rejects()
            if not finite:
                rs.add_quarantine([int(ev.client)], int(self.state["round"]))
            if self.tracer.enabled:
                self.tracer.point(
                    "screen_reject", parent=f"d{ev.index}", index=ev.index,
                    client=int(ev.client), norm=norm if finite else -1.0,
                )
                self.tracer.count("screen_rejects")

    def flush(self) -> Dict[str, jax.Array]:
        """One outer update from the buffered deltas; bumps the version."""
        params, rest = self._split_state()
        self.state, m = self._flush_fn(params, rest)
        return m

    def should_flush(self) -> bool:
        return int(self.state["buf_count"]) >= self.acfg.buffer_size

    def _flush_row(self, flush_metrics, deadline: bool = False) -> Dict[str, float]:
        row = {k: float(v) for k, v in flush_metrics.items()}
        row["sim_time"] = self.sim_time
        row["train_loss_mean"] = (
            float(jnp.mean(jnp.asarray(self._losses))) if self._losses else 0.0
        )
        row["admitted_staleness"] = list(self._staleness)
        row["uplink_bytes_total"] = self.uplink_bytes_total
        if self.residuals is not None:
            row["uplink_residual_norm"] = (
                sum(self._res_norms) / len(self._res_norms) if self._res_norms else 0.0
            )
        self._losses, self._staleness, self._res_norms = [], [], []
        self._trace_flush(row, deadline)
        # the flush boundary is the async control point: the buffer just
        # drained, so a knob update (α rebuild, buffer resize) is always safe
        # here. Applied knobs are echoed into the row for the CSV/bench trail.
        update = self.control_step(row)
        if update is not None:
            for k, v in update.knob_dict().items():
                row[f"knob_{k}"] = v
        return row

    def _trace_flush(self, row: Dict[str, Any], deadline: bool) -> None:
        """Record a flush instant and rotate the round span when the flush
        actually bumped the model version (an empty deadline flush does not)."""
        t = self.tracer
        if not t.enabled:
            return
        new_round = int(self.state["round"])
        attrs = {
            "round": new_round,
            "deadline": deadline,
            "sim_time": row["sim_time"],
            "train_loss": row["train_loss_mean"],
        }
        for k in ("buffer_fill", "staleness_mean", "staleness_max"):
            if k in row:
                attrs[k] = row[k]
        t.point("flush", parent=self._round_span, **attrs)
        t.count("deadline_flushes" if deadline else "flushes")
        if f"u{new_round}" != self._round_span:
            t.end(self._round_span, **{k: v for k, v in attrs.items()
                                       if k != "round"})
            self._round_span = f"u{new_round}"
            t.begin("round", span_id=self._round_span, round=new_round, track=0)
        t.gauge("round", new_round)
        t.gauge("sim_time", row["sim_time"])
        t.gauge("train_loss", row["train_loss_mean"])
        t.gauge("uplink_bytes_total", row["uplink_bytes_total"])
        if "buffer_fill" in row:
            t.gauge("last_flush_fill", row["buffer_fill"])

    def finalize_trace(self) -> None:
        """End-of-run span hygiene: K slots are by construction still in
        flight when a run stops, and the current round span is open — close
        them with the ``inflight_at_exit`` outcome so the report CLI's
        "all spans closed" check distinguishes a clean exit from a leak."""
        if not self.tracer.enabled:
            return
        for _, _, ev, _, _ in sorted(self._heap):
            self._trace_complete(ev, "inflight_at_exit")
        self.tracer.end(self._round_span)

    def force_flush(self) -> Optional[Dict[str, float]]:
        """Apply a final outer update from a partially filled buffer (end of
        run). Returns a row shaped exactly like the drivers' flush rows."""
        if int(self.state["buf_count"]) == 0:
            return None
        return self._flush_row(self.flush())

    # --- (c) canonical checkpoint schema ----------------------------------
    def checkpoint_state(self) -> Dict[str, Any]:
        """Server state + the per-client error-feedback store as ONE pytree
        with a fixed structure (the legacy PR-3 schema, kept for buffer-only
        round-trips): the residual lane is the DENSE ``(P, ...)`` expansion of
        the sparse store — use :meth:`checkpoint` for the population-scale
        sparse lane. Returns a COPY: the admit/flush jits donate the non-params
        lanes, so a checkpoint held past the next event must not alias them."""
        if self.residuals is None:
            return _own(self.state)
        return dict(
            _own(self.state),
            uplink_residuals=self.residuals.to_dense(self.pcfg.population),
        )

    def checkpoint(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """The canonical resumable checkpoint: ``(state_pytree, manifest)``.

        The pytree holds the server state, the SPARSE error-feedback lane (the
        ever-dispatched clients' rows stacked in sorted-id order — the id list
        rides the manifest as ``uplink_ids``, never a dense ``(P, ...)``
        expansion), ``inflight_params`` (the K in-flight slots' params
        snapshots, stacked ``(K, ...)`` in manifest slot order) and, with a
        codec, the run's ``uplink_rng`` lane. The manifest carries the host
        floats that must round-trip exactly (finish times, sim clock) plus the
        dispatch cursor and per-slot ``(index, version)`` tags — everything
        else about an in-flight event is recomputed from the pure timeline at
        restore.
        """
        entries = sorted(self._heap)  # (finish, index, ...): deterministic order
        tree = _own(self.state)
        if self.residuals is not None:
            tree["uplink_residuals"] = _own(self.residuals.stacked())
        snaps = [
            snap if snap is not None else self.state["params"]  # non-completing
            for _, _, _, snap, _ in entries                     # slot: unused filler
        ]
        tree["inflight_params"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *snaps
        )
        if self._uplink_rng is not None:
            tree["uplink_rng"] = self._uplink_rng
        manifest = dict(
            self._manifest_header(),
            cursor=int(self.n_dispatched),
            sim_time=float(self.sim_time),
            work_completed=float(self.work_completed),
            work_wasted=float(self.work_wasted),
            uplink_bytes_total=float(self.uplink_bytes_total),
            slots=[
                {"finish": float(finish), "index": int(index), "version": int(ver)}
                for finish, index, _, _, ver in entries
            ],
        )
        if self.residuals is not None:
            manifest["uplink_ids"] = self.residuals.ids()
        if self.controller is not None and self.controller.enabled:
            # controller state rides the manifest (JSON floats round-trip
            # exactly); absent entirely for static/None, keeping the default
            # checkpoint byte-identical to the uncontrolled schema
            manifest["control"] = self.controller.state_dict()
        if self.robust_state is not None:
            # defense state rides the manifest like the controller's — absent
            # when the defense is off (undefended schema byte-identical)
            manifest["robust"] = self.robust_state.state_dict()
        return tree, manifest

    def adopt_model(self, tree: Dict[str, Any]) -> None:
        """Adopt a rolled-back ``{params, outer}`` subset (divergence
        rollback). Beyond the sync semantics (model/outer rewind; round, rng
        and the dispatch machinery keep advancing), the async rollback also
        DRAINS the buffer: buffered deltas were computed against — and
        admitted into — the poisoned trajectory, and flushing them onto the
        restored model would re-apply the damage. In-flight snapshots keep
        their old params references; their uploads age normally against the
        (monotone) version counter."""
        m = self.acfg.buffer_size
        params = _own(tree["params"])
        self.state = dict(
            self.state,
            params=params,
            outer=_own(tree["outer"]),
            buffer=jax.tree_util.tree_map(
                lambda p: jnp.zeros((m,) + p.shape, jnp.float32), params
            ),
            buf_weights=jnp.zeros((m,), jnp.float32),
            buf_staleness=jnp.zeros((m,), jnp.float32),
            buf_count=jnp.zeros((), jnp.int32),
        )

    def _restore_dispatch(self, manifest: Dict[str, Any], inflight) -> None:
        self.validate_manifest(manifest, self.kind)
        slots = manifest["slots"]
        K = self.pcfg.clients_per_round
        if len(slots) != K:
            raise ValueError(
                f"dispatch manifest has {len(slots)} in-flight slots but this "
                f"configuration runs {K} — resume with the checkpoint's "
                f"clients_per_round"
            )
        if inflight is None:
            raise ValueError(
                "dispatch manifest given but the state pytree carries no "
                "'inflight_params' — load through the aggregator's "
                "checkpoint_template"
            )
        self.n_dispatched = int(manifest["cursor"])
        self.sim_time = float(manifest["sim_time"])
        self.work_completed = float(manifest["work_completed"])
        self.work_wasted = float(manifest["work_wasted"])
        self.uplink_bytes_total = float(manifest["uplink_bytes_total"])
        for pos, slot in enumerate(slots):
            # the event itself is pure in (cfg, seed, index): replay it
            ev = self.timeline.dispatch(int(slot["index"]))
            snapshot = (
                jax.tree_util.tree_map(lambda x, p=pos: x[p], inflight)
                if ev.completes else None
            )
            heapq.heappush(
                self._heap,
                (float(slot["finish"]), ev.index, ev, snapshot, int(slot["version"])),
            )
            self._busy.add(ev.client)
            self._on_dispatch(ev, snapshot, int(slot["version"]))
            self._trace_dispatch(ev, int(slot["version"]))

    @classmethod
    def checkpoint_template(
        cls,
        fed: FederatedConfig,
        acfg: AsyncAggConfig,
        pcfg: ParticipationConfig,
        params_like,
        codec: Optional[Codec] = None,
        uplink_ids=None,
    ) -> Dict[str, Any]:
        """Abstract state pytree matching ``checkpoint()[0]`` — the ``like``
        argument for ``checkpoint.load_pytree`` when resuming.

        ``uplink_ids`` (the dispatch manifest's recorded id set) sizes the
        sparse residual lane; ``None`` falls back to the legacy dense
        ``(P, ...)`` layout. Both it and the in-flight lane are built as
        ``jax.ShapeDtypeStruct`` leaves — a template never allocates the
        stores it describes (at P=100k the dense fallback would otherwise
        materialize P params-sized rows just to name their shapes)."""
        state = init_async_state(fed, acfg, params_like, jax.random.PRNGKey(0))
        if codec is not None and codec.stateful:
            n = pcfg.population if uplink_ids is None else len(uplink_ids)
            state["uplink_residuals"] = jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(
                    (n,) + tuple(p.shape), jnp.float32
                ),
                params_like,
            )
        K = pcfg.clients_per_round
        state["inflight_params"] = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct((K,) + tuple(p.shape), p.dtype),
            params_like,
        )
        if codec is not None:
            state["uplink_rng"] = jax.random.PRNGKey(0)
        return state


class AsyncFederationDriver(AsyncBufferAggregator):
    """Event-driven simulator of the asynchronous federation (Photon §5.3) —
    now a THIN driver over :class:`AsyncBufferAggregator`.

    The driver owns only the data/compute plane: the jitted client phase
    (``run_clients`` at C=1 against each dispatch's params snapshot) and the
    per-update metric rows. Every policy decision and every byte of resumable
    state — buffer lanes, residual store, dispatch cursor, in-flight slots —
    belongs to the aggregator base, so ``checkpoint()``/``dispatch`` restore
    replays a killed run bitwise.

    ``make_batches(client_id) -> batches`` keeps the data plane outside:
    leaves must be (τ, 1, ...) — the client axis of the shared client phase is
    1 here, one jitted computation reused for every completion (no
    recompiles). With ``pcfg.partial_progress`` the completion's realized τ_i
    rides in as a traced (1,) τ-mask and the admission weight is scaled by
    τ_i/τ (the aggregator's weight policy).
    """

    def __init__(
        self,
        loss_fn: Callable,
        fed: FederatedConfig,
        acfg: AsyncAggConfig,
        pcfg: ParticipationConfig,
        make_batches: Callable[[int], Dict[str, jax.Array]],
        *,
        seed: int = 0,
        params=None,
        rng: Optional[jax.Array] = None,
        state: Optional[Dict[str, Any]] = None,
        codec: Optional[Codec] = None,
        dispatch: Optional[Dict[str, Any]] = None,
        fused_server: bool = False,
        tracer=None,
        controller=None,
        robust: Optional[RobustAggConfig] = None,
    ):
        super().__init__(
            fed, acfg, pcfg, seed=seed, params=params, rng=rng, state=state,
            codec=codec, dispatch=dispatch, fused_server=fused_server,
            tracer=tracer, controller=controller, robust=robust,
        )
        self.make_batches = make_batches
        fed1 = replace(fed, clients_per_round=1, keep_inner_state=False)
        stateful, partial = self._stateful, pcfg.partial_progress

        # one client phase for every (codec, partial) shape: the optional lanes
        # (per-dispatch rng for stochastic rounding, the client's EF residual
        # row, the (1,) τ-mask) ride in a dict of traced extras
        def _client(p, r, b, extra):
            st = {"params": p, "round": r}
            kw: Dict[str, Any] = {}
            if codec is not None:
                st["rng"] = extra["rng"]
            if stateful:
                kw["residuals"] = extra["res"]
            if partial:
                kw["tau_steps"] = extra["tau"]
            return run_clients(loss_fn, fed1, st, b, codec=codec, **kw)

        self._client_fn = jax.jit(_client)

    def step(self) -> Optional[Dict[str, float]]:
        """Advance the timeline by one completion event; dispatch a replacement.

        Returns the flush metrics row when this event's admission triggered an
        outer update, else None.
        """
        ev, snapshot, version = self._pop_completion()
        row = None
        rs = self.robust_state
        if (
            ev.completes
            and rs is not None
            and rs.is_quarantined(int(ev.client), int(self.state["round"]))
        ):
            # a quarantined client never runs its phase: its slot's simulated
            # time is wasted work and the dispatch machinery moves on
            self.work_wasted += ev.duration
            self._trace_complete(ev, "quarantined")
            self._dispatch()
            return None
        if ev.completes:
            # the client trained and consumed its data either way — but when the
            # server is certain to reject the upload (staleness is known at pop
            # time: no flush can intervene), skip the simulation's τ-step compute.
            # Not with an error-feedback codec: the client compresses and uploads
            # before learning of the rejection, so its residual must advance —
            # run the client phase and let admission refuse the payload.
            staleness = int(self.state["round"]) - version
            rejected = 0 < self.acfg.max_staleness < staleness
            batches = self.make_batches(ev.client)
            if rejected and self.residuals is None:
                self.work_wasted += ev.duration
                self._trace_complete(ev, "rejected_stale", staleness=staleness)
            else:
                extra: Dict[str, Any] = {}
                if self.codec is not None:
                    # unique per dispatch: fold_in by the event's dispatch index
                    extra["rng"] = jax.random.fold_in(self._uplink_rng, ev.index)
                if self.pcfg.partial_progress:
                    extra["tau"] = jnp.asarray(
                        [ev.local_steps or self.fed.local_steps], jnp.int32
                    )
                if self.residuals is not None:
                    cid = jnp.asarray(ev.client, jnp.int32)
                    extra["res"] = self._res_gather(self.residuals, cid)
                deltas, aux = self._client_fn(
                    snapshot, jnp.asarray(version, jnp.int32), batches, extra
                )
                if self.residuals is not None:
                    # the residual belongs to the client regardless of what the
                    # server decides about this upload
                    self.residuals = self._res_scatter(
                        self.residuals, cid, aux["residuals"]
                    )
                    self._res_norms.append(float(self._res_norm_fn(aux["residuals"])))
                delta = jax.tree_util.tree_map(lambda d: d[0], deltas)
                if self.corrupt_fn is not None:
                    # Byzantine-client simulation: corrupt the honest delta at
                    # the (virtual) push side, before the admission door
                    delta = self.corrupt_fn(int(ev.client), int(ev.index), delta)
                self.uplink_bytes_total += self._bytes_per_upload
                m = self.admit(delta, version, self.event_weight(ev))
                self._note_admission(ev, m)
                rec = self._trace_admit(ev, m)
                if float(m["accepted"]) > 0:
                    self.work_completed += ev.duration
                    self._staleness.append(float(m["staleness"]))
                    self._losses.append(float(aux["step_metrics"]["loss"][-1]))
                    self._trace_complete(ev, "admitted",
                                         staleness=rec.get("staleness"))
                else:  # rejected at admission: must not skew the flush row
                    self.work_wasted += ev.duration
                    self._trace_complete(ev, "rejected",
                                         staleness=rec.get("staleness"))
            if self.should_flush():
                row = self._flush_row(self.flush())
        else:
            self.work_wasted += ev.duration
            self._trace_complete(ev, "no_show")
        self._dispatch()
        return row

    def run_updates(
        self,
        n_updates: int,
        on_update: Optional[Callable[[int, Dict[str, float]], None]] = None,
        max_events: Optional[int] = None,
    ) -> List[Dict[str, float]]:
        """Run the event loop until ``n_updates`` outer updates have been applied.

        Raises if the event budget runs out first (pathologically offline
        populations or aggressive ``max_staleness`` rejection) — a silently
        truncated history would corrupt any wall-clock-to-loss comparison.
        """
        history: List[Dict[str, float]] = []
        budget = max_events if max_events is not None else 1000 * max(1, n_updates)
        while len(history) < n_updates and budget > 0:
            budget -= 1
            row = self.step()
            if row is not None:
                row["update"] = len(history)
                history.append(row)
                if on_update is not None:
                    on_update(len(history) - 1, row)
        if len(history) < n_updates:
            raise RuntimeError(
                f"async event budget exhausted after {len(history)}/{n_updates} "
                f"outer updates (buffer admits too rarely: mostly-offline "
                f"population, zero weights, or max_staleness rejecting "
                f"everything) — raise max_events or loosen the configuration"
            )
        return history
