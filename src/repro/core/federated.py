"""The paper's core contribution: federated generative pre-training rounds (Photon).

One *round* (Algorithm 1) executes, inside a single jitted computation:

  1. broadcast θ_global to a client axis C (sharded over ('pod','data') on the mesh),
  2. τ local AdamW steps per client via ``lax.scan`` — NO cross-client collectives,
  3. pseudo-gradients Δ_k = θ_global − θ_k, per-client DP post-processing,
  4. ONE aggregation (mean over the client axis → a single all-reduce per round),
  5. outer-optimizer update of θ_global (FedAvg / FedMom / FedAdam).

This is the TPU-native mapping of Photon's client/server architecture: the client axis
is a leading parameter dimension, so per-device memory matches replicated DDP while the
round-boundary collective is the only cross-client traffic — the paper's τ×
communication reduction, visible directly in the compiled HLO.

The round is factored into two pure phases so synchronous and asynchronous
aggregation share one client code path:

  - :func:`run_clients`   — steps 1–3 (broadcast → τ local steps → post-processed
    pseudo-gradients). Used verbatim by the sync round and by the FedBuff-style
    async buffer (``core/async_agg``), whose clients train against stale params.
  - :func:`apply_aggregate` — steps 4–5 (ONE weighted aggregation → optional DP
    noise → outer update). The async buffer's flush calls this same function on
    its buffered, staleness-discounted deltas.
  - :func:`federated_round` — the two recomposed; with all-ones (or ``None``)
    weights this is bitwise-identical to the pre-refactor flat-mean round.

The client→server uplink between the two phases is where compression plugs in
(``core/compression.Codec``): with a ``codec``, ``run_clients`` emits *encoded*
payloads (the wire format) plus each client's updated error-feedback residual,
and ``apply_aggregate`` decodes under the participation weight vector before the
one collective. The identity codec keeps the whole pipeline bitwise-transparent
(rng and DP-noise lanes included — tested), so every elastic/async equivalence
guarantee survives compression being threaded through. Error-feedback residuals
are PER-CLIENT state keyed by population client id: :func:`init_uplink_residuals`
builds the (P, ...) store and :func:`federated_round_with_uplink` gathers the
round's cohort rows and scatters them back, masked so a client that did not
upload keeps its residual untouched.

Population scale (P ≈ 100k and beyond) removes both dense memory terms behind
the same seams: :class:`SparseResidualStore` keeps EF rows only for clients that
were ever selected (bitwise the dense store through its gather/scatter
contract), and :func:`run_client_tile` + :func:`apply_aggregate_partial` stream
a large cohort through fixed-size C_tile tiles, folding each tile into weighted
partial sums (the :func:`hierarchical_mean` algebra: Σ wΔ per tile, ONE divide
at the server) — bitwise the flat round when C_tile == C.

The same functions drive the single-host simulator (tests, benchmarks) and the
multi-pod dry-run (launch/dryrun.py); only the jit shardings differ.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import Codec
from repro.core.inner_opt import (
    InnerOptConfig,
    global_norm,
    init_inner_state,
    inner_update,
)
from repro.core.outer_opt import OuterOptConfig, init_outer_state, outer_update


@dataclass(frozen=True)
class FederatedConfig:
    clients_per_round: int = 8  # K — the client axis size of the jitted round
    local_steps: int = 500  # τ (paper §6.5)
    inner: InnerOptConfig = field(default_factory=InnerOptConfig)
    outer: OuterOptConfig = field(default_factory=OuterOptConfig)
    keep_inner_state: bool = False  # paper Fig 10 'FedAvg-KeepOpt' (not recommended)
    grad_accum: int = 1  # micro-batches per local step (paper §2.1.1 device batch size)
    pre_split_micro: bool = False  # batches carry (τ, C, grad_accum, B_micro, ...)
    fedprox_mu: float = 0.0  # FedProx proximal term strength
    dp_clip: float = 0.0  # per-client pseudo-gradient clip (0 = off)
    dp_noise: float = 0.0  # Gaussian noise std on the aggregate (0 = off)
    pseudo_grad_dtype: str = "float32"  # 'bfloat16' = beyond-paper compressed uplink


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def init_federated_state(
    fed: FederatedConfig, params, rng: Optional[jax.Array] = None
) -> Dict[str, Any]:
    state: Dict[str, Any] = {
        "params": params,
        "outer": init_outer_state(fed.outer, params),
        "round": jnp.zeros((), jnp.int32),
        "rng": rng if rng is not None else jax.random.PRNGKey(0),
    }
    if fed.keep_inner_state:
        inner = init_inner_state(fed.inner, params)
        state["inner"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (fed.clients_per_round,) + x.shape),
            inner,
        )
    return state


# ---------------------------------------------------------------------------
# Round step
# ---------------------------------------------------------------------------


def _broadcast_clients(tree, c: int):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (c,) + x.shape), tree
    )


def _mean_clients(tree):
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), tree)


def _weigh_clients(x, weights):
    """Broadcast a (C,) weight vector over a (C, ...) leaf: x_k ← w_k x_k."""
    return x * weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)


def _safe_weight_sum(weights):
    return jnp.maximum(jnp.sum(weights), 1e-12)  # all-masked round → zero update


def _weighted_mean_clients(tree, weights):
    """Σ_k w_k x_k / Σ_k w_k over the leading client axis. With all-ones weights this
    is bitwise-identical to ``_mean_clients`` (x·1.0 is exact, Σ1 = C exactly), which
    is what lets the elastic round subsume the legacy flat-mean round."""
    w_sum = _safe_weight_sum(weights)

    def wmean(x):
        return jnp.sum(_weigh_clients(x, weights), axis=0) / w_sum.astype(x.dtype)

    return jax.tree_util.tree_map(wmean, tree)


def _accum_value_and_grad(loss_fn, params, batch, n_micro: int, pre_split: bool = False):
    """value_and_grad with gradient accumulation over ``n_micro`` micro-batches,
    bounding activation memory like DDP micro-batching. With ``pre_split`` the batch
    leaves already carry a leading (n_micro, ...) dim — required on the mesh, where
    reshaping a sharded batch dim would break GSPMD sharding propagation."""
    if n_micro <= 1:
        if pre_split:  # (1, B, ...) -> (B, ...)
            batch = jax.tree_util.tree_map(lambda x: x[0], batch)
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    if pre_split:
        micro = batch
    else:
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]), batch
        )

    def body(carry, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc_grads, acc_loss, acc_metrics = carry
        acc_grads = jax.tree_util.tree_map(lambda a, g: a + g / n_micro, acc_grads, grads)
        acc_metrics = jax.tree_util.tree_map(
            lambda a, m: a + m / n_micro, acc_metrics, metrics
        )
        return (acc_grads, acc_loss + loss / n_micro, acc_metrics), None

    zeros_g = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    mb0 = jax.tree_util.tree_map(lambda x: x[0], micro)
    _, m0 = jax.eval_shape(lambda p, b: loss_fn(p, b), params, mb0)
    zeros_m = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
    (grads, loss, metrics), _ = jax.lax.scan(
        body, (zeros_g, jnp.zeros((), jnp.float32), zeros_m), micro
    )
    return (loss, metrics), grads


def run_clients(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics_dict)
    fed: FederatedConfig,
    state: Dict[str, Any],  # needs 'params', 'round' (+ 'inner' when keep_inner_state)
    batches: Dict[str, jax.Array],  # leaves (τ, C, ...) — per-step per-client batches
    client_weights: Optional[jax.Array] = None,  # (C,) elastic participation weights
    shard_clients: Optional[Callable] = None,  # sharding-constraint hook (mesh runs)
    codec: Optional[Codec] = None,  # uplink codec; encodes the emitted deltas
    residuals: Optional[Any] = None,  # (C, ...) per-client error-feedback residuals
    tau_steps: Optional[jax.Array] = None,  # (C,) int32 realized per-client steps τ_i
) -> Tuple[Any, Dict[str, Any]]:
    """Client phase of a federated round (Algorithm 1, L.4–7): broadcast θ_global
    over the client axis, τ local inner-optimizer steps per client (no cross-client
    collectives), then per-client pseudo-gradients Δ_k = θ_global − θ_k with DP
    clipping and uplink compression applied.

    ``tau_steps`` is the straggler PARTIAL-PROGRESS mask: a traced (C,) vector of
    realized step counts τ_i ≤ τ. The scan still runs all τ iterations, but a
    client whose budget is spent (t ≥ τ_i) holds its params and inner state
    frozen via an in-graph ``where`` — so a slow client's delta reflects exactly
    the τ_i steps it finished, no recompile happens when the τ_i vector changes
    round to round, and an all-full vector (τ_i = τ everywhere) is bitwise
    identical to ``tau_steps=None`` (``where(True, new, old)`` returns ``new``
    exactly — the same discipline as the elastic weight mask).

    Pure in ``(state, batches, weights, residuals)``; shared verbatim by the
    synchronous round and the async buffered path (``core/async_agg``), so the two
    aggregation schedules can never drift apart in client semantics. In the async
    path the caller passes a *stale* ``state`` (the params snapshot the client was
    dispatched with), which is exactly how a buffered delta acquires staleness.

    With a ``codec`` the emitted deltas are ENCODED payloads (the uplink wire
    format; ``apply_aggregate`` decodes them) and, for stateful codecs,
    ``residuals`` must be each cohort member's own error-feedback state —
    ``aux['residuals']`` returns the updated rows, with zero-weight (masked)
    clients keeping their old residual bitwise (they never uploaded). The identity
    codec encodes/decodes as exact no-ops, so ``codec=IdentityCodec()`` is bitwise
    ``codec=None``.

    Returns ``(deltas, aux)``: without a codec, ``deltas`` leaves are (C, ...)
    float32 pseudo-gradients ready for aggregation; ``aux`` carries the per-client
    inner states plus the client-side metric pieces consumed by
    ``federated_round``.
    """
    C = fed.clients_per_round
    elastic = client_weights is not None
    if elastic:
        w = client_weights.astype(jnp.float32)
        part = (w > 0).astype(jnp.float32)  # participation mask (C,)
        eff_k = jnp.maximum(jnp.sum(part), 1.0)
        metric_w = part / eff_k
    global_params = state["params"]
    client_params = _broadcast_clients(global_params, C)
    if shard_clients is not None:
        client_params = shard_clients(client_params)

    if fed.keep_inner_state:
        inner_states = state["inner"]
    else:
        inner_states = jax.vmap(lambda p: init_inner_state(fed.inner, p))(client_params)

    seq_step0 = state["round"].astype(jnp.int32) * fed.local_steps

    def local_step(carry, batch_t):
        params_c, inner_c, t = carry

        def one_client(params, inner, batch):
            (loss, metrics), grads = _accum_value_and_grad(
                loss_fn, params, batch, fed.grad_accum, pre_split=fed.pre_split_micro
            )
            if fed.fedprox_mu > 0.0:
                grads = jax.tree_util.tree_map(
                    lambda g, p, gp: g + fed.fedprox_mu * (p - gp),
                    grads,
                    params,
                    global_params,
                )
            new_params, new_inner, opt_metrics = inner_update(
                fed.inner, params, grads, inner, seq_step0 + t
            )
            metrics = dict(metrics, **opt_metrics)
            return new_params, new_inner, metrics

        new_params_c, new_inner_c, metrics_c = jax.vmap(one_client)(
            params_c, inner_c, batch_t
        )
        if tau_steps is not None:
            # partial progress: clients whose step budget is spent hold their
            # params/inner state (the masked scan lanes still execute, their
            # results are discarded — exactly the elastic-weights discipline)
            active = t < tau_steps.astype(jnp.int32)  # (C,)

            def _hold(new, old):
                return jnp.where(
                    active.reshape((-1,) + (1,) * (new.ndim - 1)), new, old
                )

            new_params_c = jax.tree_util.tree_map(_hold, new_params_c, params_c)
            new_inner_c = jax.tree_util.tree_map(_hold, new_inner_c, inner_c)
            act = active.astype(jnp.float32)
            # metrics weighted over the clients actually stepping at time t
            # (all-active: part·1.0 ≡ part, so this recomputes metric_w exactly)
            raw_w = part * act if elastic else act
            n_active = jnp.sum(raw_w)
            step_w = raw_w / jnp.maximum(n_active, 1.0)
            step_metrics = {k: jnp.sum(v * step_w) for k, v in metrics_c.items()}
            step_metrics["_n_active"] = n_active
        elif elastic:  # don't let masked clients' losses pollute the round metrics
            step_metrics = {k: jnp.sum(v * metric_w) for k, v in metrics_c.items()}
        else:
            step_metrics = {k: jnp.mean(v) for k, v in metrics_c.items()}
        return (new_params_c, new_inner_c, t + 1), step_metrics

    (client_params, inner_states, _), step_metrics = jax.lax.scan(
        local_step, (client_params, inner_states, jnp.zeros((), jnp.int32)), batches
    )
    if tau_steps is not None:
        # DEAD steps — every weighted client past its τ_i — reduced over an
        # empty set above: forward-fill each such step from the last step that
        # had an active client, so step_metrics[-1] is "the last training
        # signal observed" and the per-step series is never zero-diluted. With
        # every client at full τ no step is dead and the gather returns the
        # series untouched (bitwise — the tau_steps=None identity survives).
        n_active = step_metrics.pop("_n_active")  # (τ,)
        t_idx = jnp.arange(n_active.shape[0], dtype=jnp.int32)
        last_live = jax.lax.cummax(jnp.where(n_active > 0, t_idx, -1))
        last_live = jnp.maximum(last_live, 0)  # step 0 is always live (τ_i ≥ 1)
        step_metrics = {k: v[last_live] for k, v in step_metrics.items()}

    if fed.keep_inner_state and elastic:
        # masked clients never actually ran this round: keep their previous inner
        # state instead of the τ steps of stale-data Adam statistics the masked
        # lanes of the scan just produced. (All-ones weights: where(True, new, _)
        # returns `new` exactly, preserving the bitwise flat-round identity.)
        keep = client_weights > 0

        def _restore(new, old):
            return jnp.where(keep.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)

        inner_states = jax.tree_util.tree_map(_restore, inner_states, state["inner"])

    # ---- pseudo-gradients + post-processing (Algorithm 1, L.7 & L.26) ----
    deltas = jax.tree_util.tree_map(
        lambda g, c: g[None].astype(jnp.float32) - c.astype(jnp.float32),
        global_params,
        client_params,
    )

    if fed.dp_clip > 0.0:
        norms = jax.vmap(global_norm)(deltas)  # (C,)
        scale = jnp.minimum(1.0, fed.dp_clip / (norms + 1e-9))
        deltas = jax.tree_util.tree_map(
            lambda d: d * scale.reshape((-1,) + (1,) * (d.ndim - 1)), deltas
        )

    new_residuals = None
    if codec is not None:  # encoded uplink: deltas leave as codec payloads
        enc_keys = None
        if codec.needs_rng:
            # derived, never consumed: fold_in leaves the server rng lane
            # untouched, so stochastic rounding can't perturb the DP-noise draw
            base = state["rng"] if "rng" in state else jax.random.PRNGKey(0)
            per_round = jax.random.fold_in(base, state["round"].astype(jnp.uint32))
            enc_keys = jax.random.split(per_round, C)
        if codec.stateful:
            if residuals is None:  # first-ever upload for this cohort
                residuals = jax.vmap(codec.init_residual)(deltas)
            if codec.needs_rng:
                deltas, new_residuals = jax.vmap(
                    lambda d, e, k: codec.encode(d, e, rng=k)
                )(deltas, residuals, enc_keys)
            else:
                deltas, new_residuals = jax.vmap(
                    lambda d, e: codec.encode(d, e)
                )(deltas, residuals)
            if elastic:
                # a masked client never uploaded: its dropped-mass residual must
                # stay bitwise untouched (all-ones weights: where(True, new, _)
                # is exact, preserving the identity-codec bitwise guarantee)
                keep = client_weights > 0

                def _keep_old(new, old):
                    return jnp.where(
                        keep.reshape((-1,) + (1,) * (new.ndim - 1)), new, old
                    )

                new_residuals = jax.tree_util.tree_map(
                    _keep_old, new_residuals, residuals
                )
        elif codec.needs_rng:
            deltas = jax.vmap(lambda d, k: codec.encode(d, rng=k)[0])(deltas, enc_keys)
        else:
            deltas = jax.vmap(lambda d: codec.encode(d)[0])(deltas)
    elif fed.pseudo_grad_dtype != "float32":  # legacy flat-cast compressed uplink
        dt = jnp.dtype(fed.pseudo_grad_dtype)
        deltas = jax.tree_util.tree_map(
            lambda d: d.astype(dt).astype(jnp.float32), deltas
        )

    # client-side metric pieces (paper Figs 7, 8)
    client_norms = jax.vmap(global_norm)(client_params)  # (C,)
    if elastic:
        client_norm_mean = jnp.sum(client_norms * metric_w)
        avg_client_norm = global_norm(_weighted_mean_clients(client_params, w))
    else:
        client_norm_mean = jnp.mean(client_norms)
        avg_client_norm = global_norm(_mean_clients(client_params))

    aux = {
        "inner": inner_states,
        "step_metrics": step_metrics,
        "client_model_norm_mean": client_norm_mean,
        "avg_client_model_norm": avg_client_norm,
    }
    if new_residuals is not None:
        res_norms = jax.vmap(global_norm)(new_residuals)  # (C,) EF telemetry
        aux["residuals"] = new_residuals
        aux["uplink_residual_norm"] = (
            jnp.sum(res_norms * metric_w) if elastic else jnp.mean(res_norms)
        )
    return deltas, aux


def aggregation_metrics(
    delta_norms: jax.Array,  # (C,) per-client delta norms
    pg_norm: jax.Array,  # () norm of the aggregated (post-noise) pseudo-gradient
    client_weights: Optional[jax.Array],  # (C,) or None (flat mean)
) -> Dict[str, jax.Array]:
    """The scalar aggregation monitors (paper Figs 7, 8), shared by the jnp
    reference server phase and the fused flat-buffer phase
    (``kernels/fedcore.fused_apply_aggregate``) — ONE formula set, fed either
    from per-leaf norm passes (ref) or from in-kernel accumulators (fused), so
    the two paths can never drift apart on a metrics fix.

    Weighted consensus: Σw_k d_k = W·pg, so the cross terms are
    ||pg||²W² − Σ(w_k||d_k||)², normalized over the off-diagonal weight mass.
    The off-diagonal mass vanishes at K_eff=1 — the 0/ε there would amplify fp
    rounding into garbage, and a lone client trivially agrees with itself.
    """
    c = delta_norms.shape[0]
    elastic = client_weights is not None
    # NaN defense: a single non-finite client norm must not poison every
    # reduction below. Non-finite lanes are masked out of participation and
    # zeroed in the norm sums (0·NaN = NaN, so a zero *weight* alone is not
    # enough — the norm itself is rewritten), and surface as a dedicated
    # ``nonfinite_deltas`` count instead. All-finite cohorts take the same
    # ops through all-True masks, so the healthy path stays bitwise.
    finite = jnp.isfinite(delta_norms)
    dn = jnp.where(finite, delta_norms, 0.0)
    if elastic:
        w = jnp.where(finite, client_weights.astype(jnp.float32), 0.0)
        part = (w > 0).astype(jnp.float32)
        eff_k = jnp.maximum(jnp.sum(part), 1.0)
        metric_w = part / eff_k
        w_sum = jnp.sum(w)
        w_sq_sum = jnp.sum(jnp.square(w))
        sum_sq = jnp.sum(jnp.square(w * dn))
        norm_of_sum_sq = jnp.square(pg_norm) * jnp.square(w_sum)
        off_diag = jnp.square(w_sum) - w_sq_sum
        pairwise_dot = jnp.where(
            eff_k > 1.5,
            (norm_of_sum_sq - sum_sq) / jnp.maximum(off_diag, 1e-12),
            sum_sq / jnp.maximum(w_sq_sum, 1e-12),
        )
        mean_sq_norm = sum_sq / jnp.maximum(w_sq_sum, 1e-12)
        w_norm = w / jnp.maximum(w_sum, 1e-12)
        weight_entropy = -jnp.sum(
            jnp.where(w_norm > 0, w_norm * jnp.log(jnp.maximum(w_norm, 1e-30)), 0.0)
        )
        effective_clients = jnp.sum(part)
        delta_norm_mean = jnp.sum(dn * metric_w)
    else:
        sum_sq = jnp.sum(jnp.square(dn))
        norm_of_sum_sq = jnp.square(pg_norm) * c * c
        pairwise_dot = (norm_of_sum_sq - sum_sq) / jnp.maximum(1, c * (c - 1))
        mean_sq_norm = sum_sq / c
        weight_entropy = jnp.log(jnp.asarray(c, jnp.float32))
        effective_clients = jnp.sum(finite.astype(jnp.float32))
        delta_norm_mean = jnp.sum(dn) / jnp.maximum(
            jnp.sum(finite.astype(jnp.float32)), 1.0
        )
    consensus = pairwise_dot / (mean_sq_norm + 1e-12)  # ~cosine alignment
    return {
        "pseudo_grad_norm": pg_norm,
        "client_delta_norm_mean": delta_norm_mean,
        "client_consensus": consensus,
        "effective_clients": effective_clients,
        "weight_entropy": weight_entropy,
        "nonfinite_deltas": jnp.sum((~finite).astype(jnp.float32)),
    }


#: round metrics worth attaching to telemetry spans (the divergence
#: leading-indicators, paper Figs 7/8) — a curated subset so span attrs stay
#: small and schema-stable
TRACE_METRIC_KEYS = (
    "train_loss",
    "pseudo_grad_norm",
    "client_consensus",
    "weight_entropy",
    "effective_clients",
    "model_norm",
)


def trace_attrs(metrics: Dict[str, Any], keys=TRACE_METRIC_KEYS) -> Dict[str, float]:
    """Host-side float view of a round's telemetry-worthy metrics.

    The device→host sync happens HERE, once, and only when a caller is
    actually tracing — the jitted round itself never knows telemetry exists,
    which is what keeps traced and untraced runs bitwise identical.
    """
    return {k: float(metrics[k]) for k in keys if k in metrics}


def apply_aggregate(
    fed: FederatedConfig,
    state: Dict[str, Any],  # needs 'params', 'outer', 'round', 'rng'
    deltas,  # pytree with leading client/buffer axis (C, ...) — pseudo-gradients
    client_weights: Optional[jax.Array] = None,  # (C,) aggregation weights
    codec: Optional[Codec] = None,  # uplink codec; decodes encoded deltas first
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Server phase of a federated round (Algorithm 1, L.8–9): ONE weighted
    aggregation of the pseudo-gradients (the round's single cross-client
    collective), optional DP noise on the aggregate, and the outer-optimizer
    update. Pure in ``(state, deltas, weights)`` — jit it.

    With a ``codec``, ``deltas`` arrive as encoded payloads (``run_clients``'s
    wire format) and are decoded to float32 per client *before* the weighted
    mean — the weight vector therefore applies to the decoded deltas, so elastic
    participation and compression compose without either knowing about the other.

    The leading axis of ``deltas`` need not be a synchronous cohort: the async
    aggregator's flush (``core/async_agg.flush_buffer``) calls this exact function
    on its delta *buffer* with staleness-discounted weights, which is what keeps
    the sync and async server updates algebraically (and, at matched inputs,
    bitwise) identical.
    """
    if codec is not None:
        deltas = jax.vmap(codec.decode)(deltas)

    # THE once-per-round collective on the mesh (weighted when elastic)
    if client_weights is not None:
        pseudo_grad = _weighted_mean_clients(
            deltas, client_weights.astype(jnp.float32)
        )
    else:
        pseudo_grad = _mean_clients(deltas)

    delta_norms = jax.vmap(global_norm)(deltas)
    return _finish_aggregate(fed, state, pseudo_grad, delta_norms, client_weights)


def _finish_aggregate(
    fed: FederatedConfig,
    state: Dict[str, Any],  # needs 'params', 'outer', 'round', 'rng'
    pseudo_grad,  # pytree, NO client axis — the aggregated update direction
    delta_norms: jax.Array,  # (C,) per-client decoded delta norms (metrics)
    client_weights: Optional[jax.Array],  # (C,) or None (flat mean)
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Shared tail of every server phase: rng split → optional DP noise →
    outer update → aggregation metrics → new state. Factored out of
    :func:`apply_aggregate` so robust estimators (``core/robust.py``) can swap
    the weighted mean for a trimmed mean / coordinate median and reuse the
    identical noise/update/metrics sequence. Same ops in the same order as the
    pre-refactor tail, so the plain-mean path through here is bitwise unchanged.
    """
    elastic = client_weights is not None
    # the leading axis is the cohort for the sync round but the *buffer* for the
    # async flush — size it from the data, not from fed.clients_per_round
    C = delta_norms.shape[0]

    rng, noise_rng = jax.random.split(state["rng"])
    if fed.dp_noise > 0.0:
        # noise must cover the worst single client's influence on the aggregate:
        # for the weighted mean that is max_k w_k/Σw (= 1/C when uniform), NOT
        # 1/K_eff — with skewed data-size weights one heavy client can dominate
        if elastic:
            w = client_weights.astype(jnp.float32)
            scale = fed.dp_noise * jnp.max(w) / jnp.maximum(jnp.sum(w), 1e-12)
        else:
            scale = fed.dp_noise / C
        leaves, treedef = jax.tree_util.tree_flatten(pseudo_grad)
        keys = jax.random.split(noise_rng, len(leaves))
        leaves = [
            l + scale * jax.random.normal(k, l.shape, l.dtype)
            for l, k in zip(leaves, keys)
        ]
        pseudo_grad = jax.tree_util.tree_unflatten(treedef, leaves)

    new_global, new_outer = outer_update(
        fed.outer, state["params"], pseudo_grad, state["outer"]
    )

    # ---- aggregation metrics (paper Figs 7, 8) — shared formula set ----
    metrics = dict(
        aggregation_metrics(delta_norms, global_norm(pseudo_grad), client_weights),
        global_model_norm=global_norm(new_global),
    )

    new_state = {
        "params": new_global,
        "outer": new_outer,
        "round": state["round"] + 1,
        "rng": rng,
    }
    return new_state, metrics


def federated_round(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics_dict)
    fed: FederatedConfig,
    state: Dict[str, Any],
    batches: Dict[str, jax.Array],  # leaves (τ, C, ...) — per-step per-client batches
    client_weights: Optional[jax.Array] = None,  # (C,) elastic participation weights
    shard_clients: Optional[Callable] = None,  # sharding-constraint hook (mesh runs)
    codec: Optional[Codec] = None,  # uplink codec (encode client-side, decode server-side)
    residuals: Optional[Any] = None,  # (C, ...) cohort error-feedback residuals
    tau_steps: Optional[jax.Array] = None,  # (C,) int32 realized per-client steps τ_i
    apply_fn: Optional[Callable] = None,  # server-phase override (fused Pallas path)
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """One full federated round — :func:`run_clients` composed with
    :func:`apply_aggregate`. Pure function of (state, batches, weights, residuals,
    tau_steps) — jit it.

    ``apply_fn`` swaps the server phase for a drop-in replacement with
    ``apply_aggregate``'s exact signature and state/metrics contract — the
    ``--fused-server`` flag plugs ``kernels/fedcore.fused_apply_aggregate``
    (the flat-buffer Pallas pass) in here. ``None`` keeps this jnp reference
    phase, bitwise-unchanged.

    ``tau_steps`` enables straggler partial progress (see :func:`run_clients`);
    the caller's weight policy (``core/aggregator``) is expected to scale the
    weights by τ_i/τ so a partial delta is credited fractionally. An all-full
    τ-vector is bitwise ``tau_steps=None``.

    ``client_weights`` makes the round *elastic*: a (C,) vector of aggregation
    weights (e.g. FedAvg data sizes from a ``ParticipationPlan``), where a zero
    marks a dropped/straggling/unavailable client whose delta is excluded from the
    aggregate. Because the weights are a traced array argument, any effective
    cohort K_eff ≤ C runs inside the one compiled computation — no recompile when
    participation changes round to round. ``None`` (and equivalently all-ones
    weights, bitwise) reproduces the legacy flat-mean round.

    ``codec`` compresses the uplink between the two phases; the identity codec
    (and ``None``) keep the round bitwise the uncompressed one. For stateful
    codecs the updated cohort residuals come back as
    ``new_state['uplink_residuals']`` (plus in-graph ``uplink_residual_norm``
    telemetry); use :func:`federated_round_with_uplink` when the residuals live
    in a population-keyed store.
    """
    deltas, aux = run_clients(
        loss_fn, fed, state, batches,
        client_weights=client_weights, shard_clients=shard_clients,
        codec=codec, residuals=residuals, tau_steps=tau_steps,
    )
    new_state, agg_metrics = (apply_fn or apply_aggregate)(
        fed, state, deltas, client_weights=client_weights, codec=codec
    )

    step_metrics = aux["step_metrics"]
    metrics = {
        "train_loss": step_metrics["loss"][-1],
        "train_loss_mean": jnp.mean(step_metrics["loss"]),
        "client_grad_norm": step_metrics["grad_norm"][-1],
        "applied_update_norm": step_metrics["applied_update_norm"][-1],
        "lr": step_metrics["lr"][-1],
        "client_model_norm_mean": aux["client_model_norm_mean"],
        "avg_client_model_norm": aux["avg_client_model_norm"],
        **agg_metrics,
    }

    if fed.keep_inner_state:
        new_state["inner"] = aux["inner"]
    if "residuals" in aux:
        new_state["uplink_residuals"] = aux["residuals"]
        metrics["uplink_residual_norm"] = aux["uplink_residual_norm"]
    return new_state, metrics


# ---------------------------------------------------------------------------
# Population-keyed error-feedback residual store
# ---------------------------------------------------------------------------


def init_uplink_residuals(codec: Optional[Codec], params, population: int):
    """The per-client error-feedback store: one zero residual row per POPULATION
    client, leaves (P, ...) float32. This is the ownership story for compression
    residuals — a client's row follows it across rounds, cohorts, and (async)
    dispatches, and the store checkpoints/resumes as ordinary state. ``None`` for
    stateless codecs (no residual to own)."""
    if codec is None or not codec.stateful:
        return None
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((population,) + p.shape, jnp.float32), params
    )


class SparseResidualStore:
    """Population-keyed error-feedback store that materializes rows ONLY for
    clients that have ever sat in a cohort — the flat-memory replacement for the
    dense ``(P, ...)`` array :func:`init_uplink_residuals` builds.

    The store is a host-side ``id → row`` map (each row a params-shaped float32
    pytree, no leading axis). Its observable semantics are bitwise the dense
    store's: a dense store starts all-zero, so gathering a never-materialized id
    returns the same zero row ``jnp.take`` would, and scattering a cohort's rows
    back writes the same values ``r.at[sel].set(n)`` would. Memory, however, is
    ``O(#ever-selected · N)`` instead of ``O(P · N)`` — at P=100k with a small
    ever-selected set the dense store is never allocated at all.

    Checkpointing: :meth:`stacked` emits the rows as one ``(n_ids, ...)`` pytree
    in sorted-id order (the manifest records the id list); :meth:`to_dense`
    reproduces the legacy PR-3 dense layout; :meth:`from_dense` ingests a legacy
    dense checkpoint, leaving all-zero rows unmaterialized (indistinguishable
    through ``gather``).
    """

    def __init__(self, params_like):
        self._template = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(tuple(p.shape), jnp.float32), params_like
        )
        self._rows: Dict[int, Any] = {}

    @classmethod
    def create(cls, codec: Optional[Codec], params) -> Optional["SparseResidualStore"]:
        """``None`` for stateless codecs — mirrors :func:`init_uplink_residuals`."""
        if codec is None or not codec.stateful:
            return None
        return cls(params)

    # ---- row accounting ----

    def ids(self):
        """Sorted population ids that own a materialized row."""
        return sorted(self._rows)

    def __len__(self) -> int:
        return len(self._rows)

    def __contains__(self, cid) -> bool:
        return int(cid) in self._rows

    @property
    def row_nbytes(self) -> int:
        return sum(
            int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
            for s in jax.tree_util.tree_leaves(self._template)
        )

    @property
    def nbytes(self) -> int:
        """Exact bytes held: rows × params size. The dense equivalent is P × params."""
        return len(self._rows) * self.row_nbytes

    def _zero_row(self):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), self._template
        )

    def row(self, cid):
        """One client's row; never-materialized ids read as the zero row."""
        cid = int(cid)
        if cid in self._rows:
            return self._rows[cid]
        return self._zero_row()

    # ---- the gather/scatter contract the round functions use ----

    def gather(self, ids):
        """Stacked ``(C, ...)`` cohort rows for ``plan.selected`` — bitwise what
        ``jnp.take(dense, sel, axis=0)`` returns (unmaterialized ids are zero)."""
        rows = [self.row(i) for i in np.asarray(ids).tolist()]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rows)

    def scatter(self, ids, stacked, mask=None) -> None:
        """Write a cohort's updated rows back, materializing on first touch.

        ``mask[k]`` False marks slot ``k`` as a tile PADDING slot (not a real
        cohort member) and skips it, so padding never materializes a row. Real
        cohort members always materialize — including zero-weight (dropped /
        straggling) ones, whose rows come back bitwise unchanged from
        ``run_clients``; that matches the dense scatter, which also writes their
        unchanged rows back.
        """
        for k, cid in enumerate(np.asarray(ids).tolist()):
            if mask is not None and not bool(mask[k]):
                continue
            self._rows[int(cid)] = jax.tree_util.tree_map(lambda x: x[k], stacked)

    # ---- checkpoint lanes ----

    def stacked(self):
        """All rows as one ``(n_ids, ...)`` pytree in sorted-id order (the canonical
        checkpoint lane; pair with :meth:`ids` in the manifest)."""
        ids = self.ids()
        if not ids:
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros((0,) + tuple(s.shape), s.dtype), self._template
            )
        rows = [self._rows[i] for i in ids]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rows)

    def to_dense(self, population: int):
        """Materialize the legacy dense ``(P, ...)`` layout (PR-3 schema)."""
        dense = jax.tree_util.tree_map(
            lambda s: jnp.zeros((population,) + tuple(s.shape), s.dtype),
            self._template,
        )
        ids = self.ids()
        if not ids:
            return dense
        sel = jnp.asarray(ids, jnp.int32)
        return jax.tree_util.tree_map(
            lambda d, s: d.at[sel].set(s), dense, self.stacked()
        )

    @classmethod
    def from_stacked(cls, params_like, ids, stacked) -> "SparseResidualStore":
        """Rebuild from the canonical checkpoint lane (manifest ids + stacked rows)."""
        store = cls(params_like)
        for k, cid in enumerate(int(i) for i in ids):
            store._rows[cid] = jax.tree_util.tree_map(lambda x: jnp.asarray(x[k]), stacked)
        return store

    @classmethod
    def from_dense(cls, params_like, dense) -> "SparseResidualStore":
        """Ingest a legacy dense ``(P, ...)`` store. All-zero rows stay
        unmaterialized — a zero row and no row are indistinguishable through
        :meth:`gather`, so the conversion is semantics-preserving."""
        store = cls(params_like)
        leaves = [np.asarray(l) for l in jax.tree_util.tree_leaves(dense)]
        population = leaves[0].shape[0]
        owned = np.zeros(population, dtype=bool)
        for leaf in leaves:
            owned |= leaf.reshape(population, -1).any(axis=1)
        for cid in np.nonzero(owned)[0].tolist():
            store._rows[int(cid)] = jax.tree_util.tree_map(
                lambda x: jnp.asarray(x[cid]), dense
            )
        return store


def federated_round_with_uplink(
    loss_fn: Callable,
    fed: FederatedConfig,
    codec: Optional[Codec],
    state: Dict[str, Any],
    batches: Dict[str, jax.Array],
    client_weights: Optional[jax.Array] = None,
    selected: Optional[jax.Array] = None,  # (C,) population ids bound to the client axis
    shard_clients: Optional[Callable] = None,
    tau_steps: Optional[jax.Array] = None,  # (C,) int32 realized per-client steps τ_i
    apply_fn: Optional[Callable] = None,  # server-phase override (fused Pallas path)
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """:func:`federated_round` wired to the population-keyed residual store.

    ``state['uplink_residuals']`` holds one error-feedback row per population
    client; ``selected`` binds this round's client axis to population ids (the
    ``ParticipationPlan.selected`` vector, traced — changing cohorts never
    recompiles). The cohort's rows are gathered, the round runs, and the updated
    rows scatter back — masked clients' rows come back bitwise unchanged (the
    gather/scatter is then a no-op for them), so padding slots can never clobber
    a live client's residual. ``selected`` always holds distinct ids (sampler
    contract), so the scatter is order-independent.

    Stateless codecs (and ``codec=None``) reduce to plain ``federated_round``.
    """
    if codec is None or not codec.stateful:
        return federated_round(
            loss_fn, fed, state, batches, client_weights=client_weights,
            shard_clients=shard_clients, codec=codec, tau_steps=tau_steps,
            apply_fn=apply_fn,
        )
    if selected is None:
        raise ValueError("stateful uplink codec requires the cohort's population ids")
    store = state["uplink_residuals"]
    core = {k: v for k, v in state.items() if k != "uplink_residuals"}
    sel = selected.astype(jnp.int32)
    cohort_res = jax.tree_util.tree_map(lambda r: jnp.take(r, sel, axis=0), store)
    new_core, metrics = federated_round(
        loss_fn, fed, core, batches, client_weights=client_weights,
        shard_clients=shard_clients, codec=codec, residuals=cohort_res,
        tau_steps=tau_steps, apply_fn=apply_fn,
    )
    new_cohort_res = new_core.pop("uplink_residuals")
    new_core["uplink_residuals"] = jax.tree_util.tree_map(
        lambda r, n: r.at[sel].set(n), store, new_cohort_res
    )
    return new_core, metrics


# ---------------------------------------------------------------------------
# Streamed cohorts: tile client phase + partial-sum server phase
# ---------------------------------------------------------------------------
#
# A large cohort C is streamed through the jitted client phase in fixed-size
# tiles of C_tile clients, and the tiles fold into the round via the
# `hierarchical_mean` algebra: each tile forwards Σ_k w_k Δ_k (and its decoded
# per-client delta norms), the server accumulates the tile sums, and divides by
# Σ w ONCE in `apply_aggregate_partial`. The (C, N) delta buffer and the
# (C,)-batched client state are therefore bounded by C_tile regardless of C.
# With one tile (C_tile == C) the op sequence is exactly
# `_weighted_mean_clients` split across two jits — bitwise the flat round.


#: rng stream tag for tiles t > 0 — tile 0 keeps state['rng'] untouched so the
#: single-tile round is bitwise the flat round, rng-consuming codecs included.
TILE_RNG_TAG = 0x7113


def tile_rng(rng: jax.Array, tile_index: int) -> jax.Array:
    """Per-tile rng lane: tile 0 is the round rng itself (the bitwise identity);
    later tiles fold in a tagged tile index so their codec encode keys are
    decorrelated from each other and from the server's DP-noise lane."""
    if tile_index == 0:
        return rng
    return jax.random.fold_in(rng, TILE_RNG_TAG + tile_index)


def run_client_tile(
    loss_fn: Callable,
    fed: FederatedConfig,  # clients_per_round == C_tile
    state: Dict[str, Any],  # needs 'params', 'round', 'rng' (a per-tile rng lane)
    batches: Dict[str, jax.Array],  # leaves (τ, C_tile, ...)
    client_weights: jax.Array,  # (C_tile,) — REQUIRED (pads carry weight 0)
    shard_clients: Optional[Callable] = None,
    codec: Optional[Codec] = None,
    residuals: Optional[Any] = None,  # (C_tile, ...) cohort error-feedback rows
    tau_steps: Optional[jax.Array] = None,  # (C_tile,) int32
    return_deltas: bool = False,  # also return the decoded (C_tile, ...) deltas
) -> Dict[str, Any]:
    """One cohort TILE of a streamed round: :func:`run_clients` on ``C_tile``
    clients, folded to weighted partial sums. Pure — jit it once and replay it
    over every tile of every round.

    ``return_deltas`` adds the decoded per-client deltas to the output —
    required by the robust tiled fold (``core/robust.py``), whose order
    statistics cannot be recovered from the weighted partial sum alone. The
    default path never materializes them past this function.

    Returns a dict of partial results:

    - ``delta_sum``  — Σ_k w_k Δ_k over the tile (decoded), the island-style
      partial numerator of the weighted mean (``hierarchical_mean`` algebra).
    - ``delta_norms`` — (C_tile,) decoded per-client delta norms (for
      :func:`aggregation_metrics`, concatenated across tiles).
    - ``residuals`` / ``uplink_residual_norm`` — updated EF rows (stateful codecs).
    - ``eff_k`` + the :func:`run_clients` telemetry pieces, recombined across
      tiles by :func:`combine_tile_metrics`.

    The partial numerator uses the exact op sequence of
    ``_weighted_mean_clients`` (``jnp.sum(_weigh_clients(x, w), axis=0)``), and
    :func:`apply_aggregate_partial` performs the identical final divide — with a
    single tile the round is bitwise :func:`federated_round`.
    """
    if fed.keep_inner_state:
        raise ValueError(
            "streamed cohorts cannot keep per-client inner state across rounds "
            "(the (C,)-batched inner store is exactly the memory term tiling "
            "removes); use keep_inner_state=False"
        )
    deltas, aux = run_clients(
        loss_fn, fed, state, batches,
        client_weights=client_weights, shard_clients=shard_clients,
        codec=codec, residuals=residuals, tau_steps=tau_steps,
    )
    if codec is not None:
        deltas = jax.vmap(codec.decode)(deltas)
    w = client_weights.astype(jnp.float32)
    out = {
        "delta_sum": jax.tree_util.tree_map(
            lambda x: jnp.sum(_weigh_clients(x, w), axis=0), deltas
        ),
        "delta_norms": jax.vmap(global_norm)(deltas),
        "eff_k": jnp.sum((w > 0).astype(jnp.float32)),
        "step_metrics": aux["step_metrics"],
        "client_model_norm_mean": aux["client_model_norm_mean"],
        "avg_client_model_norm": aux["avg_client_model_norm"],
    }
    if "residuals" in aux:
        out["residuals"] = aux["residuals"]
        out["uplink_residual_norm"] = aux["uplink_residual_norm"]
    if return_deltas:
        out["deltas"] = deltas
    return out


def apply_aggregate_partial(
    fed: FederatedConfig,
    state: Dict[str, Any],  # needs 'params', 'outer', 'round', 'rng'
    delta_sum,  # pytree — Σ over ALL tiles of Σ_k w_k Δ_k (no client axis)
    client_weights: jax.Array,  # (C_total,) full-cohort weights (pads at w=0)
    delta_norms: jax.Array,  # (C_total,) decoded per-client delta norms
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Server phase of a streamed round: the ONE divide of the two-tier
    aggregation, then DP noise and the outer update — :func:`apply_aggregate`
    with the weighted mean's numerator precomputed by the tiles.

    Mirrors ``apply_aggregate`` operation for operation (same rng split, same
    elastic DP-noise scale, same metrics formulas), so a single-tile round is
    bitwise the flat round. Zero-weight padding slots are invisible: they add
    exact zeros to ``delta_sum``, nothing to Σw / max(w), and
    :func:`aggregation_metrics` masks them out via ``w > 0``.
    """
    w = client_weights.astype(jnp.float32)
    w_sum = _safe_weight_sum(w)
    pseudo_grad = jax.tree_util.tree_map(
        lambda s: s / w_sum.astype(s.dtype), delta_sum
    )

    rng, noise_rng = jax.random.split(state["rng"])
    if fed.dp_noise > 0.0:
        scale = fed.dp_noise * jnp.max(w) / jnp.maximum(jnp.sum(w), 1e-12)
        leaves, treedef = jax.tree_util.tree_flatten(pseudo_grad)
        keys = jax.random.split(noise_rng, len(leaves))
        leaves = [
            l + scale * jax.random.normal(k, l.shape, l.dtype)
            for l, k in zip(leaves, keys)
        ]
        pseudo_grad = jax.tree_util.tree_unflatten(treedef, leaves)

    new_global, new_outer = outer_update(
        fed.outer, state["params"], pseudo_grad, state["outer"]
    )
    metrics = dict(
        aggregation_metrics(delta_norms, global_norm(pseudo_grad), client_weights),
        global_model_norm=global_norm(new_global),
    )
    new_state = {
        "params": new_global,
        "outer": new_outer,
        "round": state["round"] + 1,
        "rng": rng,
    }
    return new_state, metrics


def combine_tile_metrics(tile_outs) -> Dict[str, jax.Array]:
    """Fold per-tile client telemetry into :func:`federated_round`'s metric dict
    (everything except the ``apply_aggregate_partial`` server metrics).

    One tile: passed through verbatim (bitwise the flat round's assembly). More
    tiles: each tile's participation-weighted means recombine weighted by its
    effective client count — exact algebra for the per-step scalar series (which
    are already Σ v·part/eff within the tile), a documented approximation for
    ``avg_client_model_norm`` and ``uplink_residual_norm`` (norms of means do
    not decompose across tiles; these are monitoring-only quantities)."""
    if len(tile_outs) == 1:
        t = tile_outs[0]
        sm = t["step_metrics"]
        out = {
            "train_loss": sm["loss"][-1],
            "train_loss_mean": jnp.mean(sm["loss"]),
            "client_grad_norm": sm["grad_norm"][-1],
            "applied_update_norm": sm["applied_update_norm"][-1],
            "lr": sm["lr"][-1],
            "client_model_norm_mean": t["client_model_norm_mean"],
            "avg_client_model_norm": t["avg_client_model_norm"],
        }
        if "uplink_residual_norm" in t:
            out["uplink_residual_norm"] = t["uplink_residual_norm"]
        return out

    eff = jnp.stack([t["eff_k"].astype(jnp.float32) for t in tile_outs])
    tile_w = eff / jnp.maximum(jnp.sum(eff), 1.0)  # all-pad tiles weigh 0

    def fold(vals):
        v = jnp.stack(vals)
        return jnp.sum(v * tile_w.reshape((-1,) + (1,) * (v.ndim - 1)), axis=0)

    sm = {
        k: fold([t["step_metrics"][k] for t in tile_outs])
        for k in tile_outs[0]["step_metrics"]
    }
    out = {
        "train_loss": sm["loss"][-1],
        "train_loss_mean": jnp.mean(sm["loss"]),
        "client_grad_norm": sm["grad_norm"][-1],
        "applied_update_norm": sm["applied_update_norm"][-1],
        "lr": sm["lr"][-1],
        "client_model_norm_mean": fold(
            [t["client_model_norm_mean"] for t in tile_outs]
        ),
        "avg_client_model_norm": fold(
            [t["avg_client_model_norm"] for t in tile_outs]
        ),
    }
    if "uplink_residual_norm" in tile_outs[0]:
        out["uplink_residual_norm"] = fold(
            [t["uplink_residual_norm"] for t in tile_outs]
        )
    return out


# ---------------------------------------------------------------------------
# Centralized baseline (paper's comparison target)
# ---------------------------------------------------------------------------


def init_centralized_state(inner: InnerOptConfig, params) -> Dict[str, Any]:
    return {
        "params": params,
        "inner": init_inner_state(inner, params),
        "step": jnp.zeros((), jnp.int32),
    }


def centralized_step(
    loss_fn: Callable,
    inner: InnerOptConfig,
    state: Dict[str, Any],
    batch: Dict[str, jax.Array],  # leaves (B, ...) — the full global batch
    grad_accum: int = 1,
    pre_split: bool = False,
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Standard synchronous data-parallel step: per-step gradient all-reduce."""
    (loss, metrics), grads = _accum_value_and_grad(
        loss_fn, state["params"], batch, grad_accum, pre_split=pre_split
    )
    new_params, new_inner, opt_metrics = inner_update(
        inner, state["params"], grads, state["inner"], state["step"]
    )
    metrics = dict(metrics, **opt_metrics)
    metrics["global_model_norm"] = global_norm(new_params)
    return (
        {"params": new_params, "inner": new_inner, "step": state["step"] + 1},
        metrics,
    )


# ---------------------------------------------------------------------------
# Hierarchical (two-level) aggregation — Photon's sub-federation (Alg. 1 L.19–24)
# ---------------------------------------------------------------------------


def hierarchical_mean(deltas, n_groups: int, weights: Optional[jax.Array] = None):
    """Two-phase mean: partial aggregation within node groups (Photon LLM Node islands),
    then across groups. With equal group sizes this equals the flat mean (tested); on
    the mesh it pins the reduce-within-pod → reduce-across-pods schedule.

    With ``weights`` (C,) each island forwards Σ_k w_k Δ_k and Σ_k w_k; the server
    divides once — algebraically identical to the weighted flat mean, so elastic
    participation composes with sub-federation for free.

    Uneven islands: when ``C % n_groups != 0`` the weighted form zero-pads the
    client axis up to the next multiple — a pad slot carries weight 0 and a zero
    delta, so the partial sums are untouched (0·0 = 0 is exact in fp) and the
    final divide uses the REAL weight mass only. The unweighted form has no way
    to mark a pad as absent (every slot counts 1/C) and raises ``ValueError``
    instead — a real error, not a bare ``assert`` that vanishes under
    ``python -O``."""

    def _check_divisible(c: int):
        if c % n_groups != 0:
            raise ValueError(
                f"client axis of size {c} does not divide into {n_groups} equal "
                "groups; pass weights= to use the zero-weight padding path"
            )

    if weights is None:

        def two_level(x):
            _check_divisible(x.shape[0])
            grouped = x.reshape(n_groups, x.shape[0] // n_groups, *x.shape[1:])
            partial = jnp.mean(grouped, axis=1)  # within-island partial aggregation
            return jnp.mean(partial, axis=0)  # server aggregation of island results

        return jax.tree_util.tree_map(two_level, deltas)

    w = weights.astype(jnp.float32)
    w_sum = _safe_weight_sum(w)  # real clients only — pads never enter the divide
    c = int(w.shape[0])
    pad = (-c) % n_groups
    w_padded = jnp.concatenate([w, jnp.zeros((pad,), jnp.float32)]) if pad else w

    def two_level_weighted(x):
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
            )
        grouped = _weigh_clients(x, w_padded).reshape(
            n_groups, (c + pad) // n_groups, *x.shape[1:]
        )
        partial = jnp.sum(grouped, axis=1)  # within-island weighted partial sums
        return jnp.sum(partial, axis=0) / w_sum.astype(x.dtype)

    return jax.tree_util.tree_map(two_level_weighted, deltas)
