"""The paper's core contribution: federated generative pre-training rounds (Photon).

One *round* (Algorithm 1) executes, inside a single jitted computation:

  1. broadcast θ_global to a client axis C (sharded over ('pod','data') on the mesh),
  2. τ local AdamW steps per client via ``lax.scan`` — NO cross-client collectives,
  3. pseudo-gradients Δ_k = θ_global − θ_k, per-client DP post-processing,
  4. ONE aggregation (mean over the client axis → a single all-reduce per round),
  5. outer-optimizer update of θ_global (FedAvg / FedMom / FedAdam).

This is the TPU-native mapping of Photon's client/server architecture: the client axis
is a leading parameter dimension, so per-device memory matches replicated DDP while the
round-boundary collective is the only cross-client traffic — the paper's τ×
communication reduction, visible directly in the compiled HLO.

The round is factored into two pure phases so synchronous and asynchronous
aggregation share one client code path:

  - :func:`run_clients`   — steps 1–3 (broadcast → τ local steps → post-processed
    pseudo-gradients). Used verbatim by the sync round and by the FedBuff-style
    async buffer (``core/async_agg``), whose clients train against stale params.
  - :func:`apply_aggregate` — steps 4–5 (ONE weighted aggregation → optional DP
    noise → outer update). The async buffer's flush calls this same function on
    its buffered, staleness-discounted deltas.
  - :func:`federated_round` — the two recomposed; with all-ones (or ``None``)
    weights this is bitwise-identical to the pre-refactor flat-mean round.

The client→server uplink between the two phases is where compression plugs in
(``core/compression.Codec``): with a ``codec``, ``run_clients`` emits *encoded*
payloads (the wire format) plus each client's updated error-feedback residual,
and ``apply_aggregate`` decodes under the participation weight vector before the
one collective. The identity codec keeps the whole pipeline bitwise-transparent
(rng and DP-noise lanes included — tested), so every elastic/async equivalence
guarantee survives compression being threaded through. Error-feedback residuals
are PER-CLIENT state keyed by population client id: :func:`init_uplink_residuals`
builds the (P, ...) store and :func:`federated_round_with_uplink` gathers the
round's cohort rows and scatters them back, masked so a client that did not
upload keeps its residual untouched.

The same functions drive the single-host simulator (tests, benchmarks) and the
multi-pod dry-run (launch/dryrun.py); only the jit shardings differ.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import Codec
from repro.core.inner_opt import (
    InnerOptConfig,
    global_norm,
    init_inner_state,
    inner_update,
)
from repro.core.outer_opt import OuterOptConfig, init_outer_state, outer_update


@dataclass(frozen=True)
class FederatedConfig:
    clients_per_round: int = 8  # K — the client axis size of the jitted round
    local_steps: int = 500  # τ (paper §6.5)
    inner: InnerOptConfig = field(default_factory=InnerOptConfig)
    outer: OuterOptConfig = field(default_factory=OuterOptConfig)
    keep_inner_state: bool = False  # paper Fig 10 'FedAvg-KeepOpt' (not recommended)
    grad_accum: int = 1  # micro-batches per local step (paper §2.1.1 device batch size)
    pre_split_micro: bool = False  # batches carry (τ, C, grad_accum, B_micro, ...)
    fedprox_mu: float = 0.0  # FedProx proximal term strength
    dp_clip: float = 0.0  # per-client pseudo-gradient clip (0 = off)
    dp_noise: float = 0.0  # Gaussian noise std on the aggregate (0 = off)
    pseudo_grad_dtype: str = "float32"  # 'bfloat16' = beyond-paper compressed uplink


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------


def init_federated_state(
    fed: FederatedConfig, params, rng: Optional[jax.Array] = None
) -> Dict[str, Any]:
    state: Dict[str, Any] = {
        "params": params,
        "outer": init_outer_state(fed.outer, params),
        "round": jnp.zeros((), jnp.int32),
        "rng": rng if rng is not None else jax.random.PRNGKey(0),
    }
    if fed.keep_inner_state:
        inner = init_inner_state(fed.inner, params)
        state["inner"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (fed.clients_per_round,) + x.shape),
            inner,
        )
    return state


# ---------------------------------------------------------------------------
# Round step
# ---------------------------------------------------------------------------


def _broadcast_clients(tree, c: int):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (c,) + x.shape), tree
    )


def _mean_clients(tree):
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), tree)


def _weigh_clients(x, weights):
    """Broadcast a (C,) weight vector over a (C, ...) leaf: x_k ← w_k x_k."""
    return x * weights.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)


def _safe_weight_sum(weights):
    return jnp.maximum(jnp.sum(weights), 1e-12)  # all-masked round → zero update


def _weighted_mean_clients(tree, weights):
    """Σ_k w_k x_k / Σ_k w_k over the leading client axis. With all-ones weights this
    is bitwise-identical to ``_mean_clients`` (x·1.0 is exact, Σ1 = C exactly), which
    is what lets the elastic round subsume the legacy flat-mean round."""
    w_sum = _safe_weight_sum(weights)

    def wmean(x):
        return jnp.sum(_weigh_clients(x, weights), axis=0) / w_sum.astype(x.dtype)

    return jax.tree_util.tree_map(wmean, tree)


def _accum_value_and_grad(loss_fn, params, batch, n_micro: int, pre_split: bool = False):
    """value_and_grad with gradient accumulation over ``n_micro`` micro-batches,
    bounding activation memory like DDP micro-batching. With ``pre_split`` the batch
    leaves already carry a leading (n_micro, ...) dim — required on the mesh, where
    reshaping a sharded batch dim would break GSPMD sharding propagation."""
    if n_micro <= 1:
        if pre_split:  # (1, B, ...) -> (B, ...)
            batch = jax.tree_util.tree_map(lambda x: x[0], batch)
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    if pre_split:
        micro = batch
    else:
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]), batch
        )

    def body(carry, mb):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc_grads, acc_loss, acc_metrics = carry
        acc_grads = jax.tree_util.tree_map(lambda a, g: a + g / n_micro, acc_grads, grads)
        acc_metrics = jax.tree_util.tree_map(
            lambda a, m: a + m / n_micro, acc_metrics, metrics
        )
        return (acc_grads, acc_loss + loss / n_micro, acc_metrics), None

    zeros_g = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    mb0 = jax.tree_util.tree_map(lambda x: x[0], micro)
    _, m0 = jax.eval_shape(lambda p, b: loss_fn(p, b), params, mb0)
    zeros_m = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
    (grads, loss, metrics), _ = jax.lax.scan(
        body, (zeros_g, jnp.zeros((), jnp.float32), zeros_m), micro
    )
    return (loss, metrics), grads


def run_clients(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics_dict)
    fed: FederatedConfig,
    state: Dict[str, Any],  # needs 'params', 'round' (+ 'inner' when keep_inner_state)
    batches: Dict[str, jax.Array],  # leaves (τ, C, ...) — per-step per-client batches
    client_weights: Optional[jax.Array] = None,  # (C,) elastic participation weights
    shard_clients: Optional[Callable] = None,  # sharding-constraint hook (mesh runs)
    codec: Optional[Codec] = None,  # uplink codec; encodes the emitted deltas
    residuals: Optional[Any] = None,  # (C, ...) per-client error-feedback residuals
    tau_steps: Optional[jax.Array] = None,  # (C,) int32 realized per-client steps τ_i
) -> Tuple[Any, Dict[str, Any]]:
    """Client phase of a federated round (Algorithm 1, L.4–7): broadcast θ_global
    over the client axis, τ local inner-optimizer steps per client (no cross-client
    collectives), then per-client pseudo-gradients Δ_k = θ_global − θ_k with DP
    clipping and uplink compression applied.

    ``tau_steps`` is the straggler PARTIAL-PROGRESS mask: a traced (C,) vector of
    realized step counts τ_i ≤ τ. The scan still runs all τ iterations, but a
    client whose budget is spent (t ≥ τ_i) holds its params and inner state
    frozen via an in-graph ``where`` — so a slow client's delta reflects exactly
    the τ_i steps it finished, no recompile happens when the τ_i vector changes
    round to round, and an all-full vector (τ_i = τ everywhere) is bitwise
    identical to ``tau_steps=None`` (``where(True, new, old)`` returns ``new``
    exactly — the same discipline as the elastic weight mask).

    Pure in ``(state, batches, weights, residuals)``; shared verbatim by the
    synchronous round and the async buffered path (``core/async_agg``), so the two
    aggregation schedules can never drift apart in client semantics. In the async
    path the caller passes a *stale* ``state`` (the params snapshot the client was
    dispatched with), which is exactly how a buffered delta acquires staleness.

    With a ``codec`` the emitted deltas are ENCODED payloads (the uplink wire
    format; ``apply_aggregate`` decodes them) and, for stateful codecs,
    ``residuals`` must be each cohort member's own error-feedback state —
    ``aux['residuals']`` returns the updated rows, with zero-weight (masked)
    clients keeping their old residual bitwise (they never uploaded). The identity
    codec encodes/decodes as exact no-ops, so ``codec=IdentityCodec()`` is bitwise
    ``codec=None``.

    Returns ``(deltas, aux)``: without a codec, ``deltas`` leaves are (C, ...)
    float32 pseudo-gradients ready for aggregation; ``aux`` carries the per-client
    inner states plus the client-side metric pieces consumed by
    ``federated_round``.
    """
    C = fed.clients_per_round
    elastic = client_weights is not None
    if elastic:
        w = client_weights.astype(jnp.float32)
        part = (w > 0).astype(jnp.float32)  # participation mask (C,)
        eff_k = jnp.maximum(jnp.sum(part), 1.0)
        metric_w = part / eff_k
    global_params = state["params"]
    client_params = _broadcast_clients(global_params, C)
    if shard_clients is not None:
        client_params = shard_clients(client_params)

    if fed.keep_inner_state:
        inner_states = state["inner"]
    else:
        inner_states = jax.vmap(lambda p: init_inner_state(fed.inner, p))(client_params)

    seq_step0 = state["round"].astype(jnp.int32) * fed.local_steps

    def local_step(carry, batch_t):
        params_c, inner_c, t = carry

        def one_client(params, inner, batch):
            (loss, metrics), grads = _accum_value_and_grad(
                loss_fn, params, batch, fed.grad_accum, pre_split=fed.pre_split_micro
            )
            if fed.fedprox_mu > 0.0:
                grads = jax.tree_util.tree_map(
                    lambda g, p, gp: g + fed.fedprox_mu * (p - gp),
                    grads,
                    params,
                    global_params,
                )
            new_params, new_inner, opt_metrics = inner_update(
                fed.inner, params, grads, inner, seq_step0 + t
            )
            metrics = dict(metrics, **opt_metrics)
            return new_params, new_inner, metrics

        new_params_c, new_inner_c, metrics_c = jax.vmap(one_client)(
            params_c, inner_c, batch_t
        )
        if tau_steps is not None:
            # partial progress: clients whose step budget is spent hold their
            # params/inner state (the masked scan lanes still execute, their
            # results are discarded — exactly the elastic-weights discipline)
            active = t < tau_steps.astype(jnp.int32)  # (C,)

            def _hold(new, old):
                return jnp.where(
                    active.reshape((-1,) + (1,) * (new.ndim - 1)), new, old
                )

            new_params_c = jax.tree_util.tree_map(_hold, new_params_c, params_c)
            new_inner_c = jax.tree_util.tree_map(_hold, new_inner_c, inner_c)
            act = active.astype(jnp.float32)
            # metrics weighted over the clients actually stepping at time t
            # (all-active: part·1.0 ≡ part, so this recomputes metric_w exactly)
            raw_w = part * act if elastic else act
            n_active = jnp.sum(raw_w)
            step_w = raw_w / jnp.maximum(n_active, 1.0)
            step_metrics = {k: jnp.sum(v * step_w) for k, v in metrics_c.items()}
            step_metrics["_n_active"] = n_active
        elif elastic:  # don't let masked clients' losses pollute the round metrics
            step_metrics = {k: jnp.sum(v * metric_w) for k, v in metrics_c.items()}
        else:
            step_metrics = {k: jnp.mean(v) for k, v in metrics_c.items()}
        return (new_params_c, new_inner_c, t + 1), step_metrics

    (client_params, inner_states, _), step_metrics = jax.lax.scan(
        local_step, (client_params, inner_states, jnp.zeros((), jnp.int32)), batches
    )
    if tau_steps is not None:
        # DEAD steps — every weighted client past its τ_i — reduced over an
        # empty set above: forward-fill each such step from the last step that
        # had an active client, so step_metrics[-1] is "the last training
        # signal observed" and the per-step series is never zero-diluted. With
        # every client at full τ no step is dead and the gather returns the
        # series untouched (bitwise — the tau_steps=None identity survives).
        n_active = step_metrics.pop("_n_active")  # (τ,)
        t_idx = jnp.arange(n_active.shape[0], dtype=jnp.int32)
        last_live = jax.lax.cummax(jnp.where(n_active > 0, t_idx, -1))
        last_live = jnp.maximum(last_live, 0)  # step 0 is always live (τ_i ≥ 1)
        step_metrics = {k: v[last_live] for k, v in step_metrics.items()}

    if fed.keep_inner_state and elastic:
        # masked clients never actually ran this round: keep their previous inner
        # state instead of the τ steps of stale-data Adam statistics the masked
        # lanes of the scan just produced. (All-ones weights: where(True, new, _)
        # returns `new` exactly, preserving the bitwise flat-round identity.)
        keep = client_weights > 0

        def _restore(new, old):
            return jnp.where(keep.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)

        inner_states = jax.tree_util.tree_map(_restore, inner_states, state["inner"])

    # ---- pseudo-gradients + post-processing (Algorithm 1, L.7 & L.26) ----
    deltas = jax.tree_util.tree_map(
        lambda g, c: g[None].astype(jnp.float32) - c.astype(jnp.float32),
        global_params,
        client_params,
    )

    if fed.dp_clip > 0.0:
        norms = jax.vmap(global_norm)(deltas)  # (C,)
        scale = jnp.minimum(1.0, fed.dp_clip / (norms + 1e-9))
        deltas = jax.tree_util.tree_map(
            lambda d: d * scale.reshape((-1,) + (1,) * (d.ndim - 1)), deltas
        )

    new_residuals = None
    if codec is not None:  # encoded uplink: deltas leave as codec payloads
        enc_keys = None
        if codec.needs_rng:
            # derived, never consumed: fold_in leaves the server rng lane
            # untouched, so stochastic rounding can't perturb the DP-noise draw
            base = state["rng"] if "rng" in state else jax.random.PRNGKey(0)
            per_round = jax.random.fold_in(base, state["round"].astype(jnp.uint32))
            enc_keys = jax.random.split(per_round, C)
        if codec.stateful:
            if residuals is None:  # first-ever upload for this cohort
                residuals = jax.vmap(codec.init_residual)(deltas)
            if codec.needs_rng:
                deltas, new_residuals = jax.vmap(
                    lambda d, e, k: codec.encode(d, e, rng=k)
                )(deltas, residuals, enc_keys)
            else:
                deltas, new_residuals = jax.vmap(
                    lambda d, e: codec.encode(d, e)
                )(deltas, residuals)
            if elastic:
                # a masked client never uploaded: its dropped-mass residual must
                # stay bitwise untouched (all-ones weights: where(True, new, _)
                # is exact, preserving the identity-codec bitwise guarantee)
                keep = client_weights > 0

                def _keep_old(new, old):
                    return jnp.where(
                        keep.reshape((-1,) + (1,) * (new.ndim - 1)), new, old
                    )

                new_residuals = jax.tree_util.tree_map(
                    _keep_old, new_residuals, residuals
                )
        elif codec.needs_rng:
            deltas = jax.vmap(lambda d, k: codec.encode(d, rng=k)[0])(deltas, enc_keys)
        else:
            deltas = jax.vmap(lambda d: codec.encode(d)[0])(deltas)
    elif fed.pseudo_grad_dtype != "float32":  # legacy flat-cast compressed uplink
        dt = jnp.dtype(fed.pseudo_grad_dtype)
        deltas = jax.tree_util.tree_map(
            lambda d: d.astype(dt).astype(jnp.float32), deltas
        )

    # client-side metric pieces (paper Figs 7, 8)
    client_norms = jax.vmap(global_norm)(client_params)  # (C,)
    if elastic:
        client_norm_mean = jnp.sum(client_norms * metric_w)
        avg_client_norm = global_norm(_weighted_mean_clients(client_params, w))
    else:
        client_norm_mean = jnp.mean(client_norms)
        avg_client_norm = global_norm(_mean_clients(client_params))

    aux = {
        "inner": inner_states,
        "step_metrics": step_metrics,
        "client_model_norm_mean": client_norm_mean,
        "avg_client_model_norm": avg_client_norm,
    }
    if new_residuals is not None:
        res_norms = jax.vmap(global_norm)(new_residuals)  # (C,) EF telemetry
        aux["residuals"] = new_residuals
        aux["uplink_residual_norm"] = (
            jnp.sum(res_norms * metric_w) if elastic else jnp.mean(res_norms)
        )
    return deltas, aux


def aggregation_metrics(
    delta_norms: jax.Array,  # (C,) per-client delta norms
    pg_norm: jax.Array,  # () norm of the aggregated (post-noise) pseudo-gradient
    client_weights: Optional[jax.Array],  # (C,) or None (flat mean)
) -> Dict[str, jax.Array]:
    """The scalar aggregation monitors (paper Figs 7, 8), shared by the jnp
    reference server phase and the fused flat-buffer phase
    (``kernels/fedcore.fused_apply_aggregate``) — ONE formula set, fed either
    from per-leaf norm passes (ref) or from in-kernel accumulators (fused), so
    the two paths can never drift apart on a metrics fix.

    Weighted consensus: Σw_k d_k = W·pg, so the cross terms are
    ||pg||²W² − Σ(w_k||d_k||)², normalized over the off-diagonal weight mass.
    The off-diagonal mass vanishes at K_eff=1 — the 0/ε there would amplify fp
    rounding into garbage, and a lone client trivially agrees with itself.
    """
    c = delta_norms.shape[0]
    elastic = client_weights is not None
    if elastic:
        w = client_weights.astype(jnp.float32)
        part = (w > 0).astype(jnp.float32)
        eff_k = jnp.maximum(jnp.sum(part), 1.0)
        metric_w = part / eff_k
        w_sum = jnp.sum(w)
        w_sq_sum = jnp.sum(jnp.square(w))
        sum_sq = jnp.sum(jnp.square(w * delta_norms))
        norm_of_sum_sq = jnp.square(pg_norm) * jnp.square(w_sum)
        off_diag = jnp.square(w_sum) - w_sq_sum
        pairwise_dot = jnp.where(
            eff_k > 1.5,
            (norm_of_sum_sq - sum_sq) / jnp.maximum(off_diag, 1e-12),
            sum_sq / jnp.maximum(w_sq_sum, 1e-12),
        )
        mean_sq_norm = sum_sq / jnp.maximum(w_sq_sum, 1e-12)
        w_norm = w / jnp.maximum(w_sum, 1e-12)
        weight_entropy = -jnp.sum(
            jnp.where(w_norm > 0, w_norm * jnp.log(jnp.maximum(w_norm, 1e-30)), 0.0)
        )
        effective_clients = jnp.sum(part)
        delta_norm_mean = jnp.sum(delta_norms * metric_w)
    else:
        sum_sq = jnp.sum(jnp.square(delta_norms))
        norm_of_sum_sq = jnp.square(pg_norm) * c * c
        pairwise_dot = (norm_of_sum_sq - sum_sq) / jnp.maximum(1, c * (c - 1))
        mean_sq_norm = sum_sq / c
        weight_entropy = jnp.log(jnp.asarray(c, jnp.float32))
        effective_clients = jnp.asarray(c, jnp.float32)
        delta_norm_mean = jnp.mean(delta_norms)
    consensus = pairwise_dot / (mean_sq_norm + 1e-12)  # ~cosine alignment
    return {
        "pseudo_grad_norm": pg_norm,
        "client_delta_norm_mean": delta_norm_mean,
        "client_consensus": consensus,
        "effective_clients": effective_clients,
        "weight_entropy": weight_entropy,
    }


#: round metrics worth attaching to telemetry spans (the divergence
#: leading-indicators, paper Figs 7/8) — a curated subset so span attrs stay
#: small and schema-stable
TRACE_METRIC_KEYS = (
    "train_loss",
    "pseudo_grad_norm",
    "client_consensus",
    "weight_entropy",
    "effective_clients",
    "model_norm",
)


def trace_attrs(metrics: Dict[str, Any], keys=TRACE_METRIC_KEYS) -> Dict[str, float]:
    """Host-side float view of a round's telemetry-worthy metrics.

    The device→host sync happens HERE, once, and only when a caller is
    actually tracing — the jitted round itself never knows telemetry exists,
    which is what keeps traced and untraced runs bitwise identical.
    """
    return {k: float(metrics[k]) for k in keys if k in metrics}


def apply_aggregate(
    fed: FederatedConfig,
    state: Dict[str, Any],  # needs 'params', 'outer', 'round', 'rng'
    deltas,  # pytree with leading client/buffer axis (C, ...) — pseudo-gradients
    client_weights: Optional[jax.Array] = None,  # (C,) aggregation weights
    codec: Optional[Codec] = None,  # uplink codec; decodes encoded deltas first
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Server phase of a federated round (Algorithm 1, L.8–9): ONE weighted
    aggregation of the pseudo-gradients (the round's single cross-client
    collective), optional DP noise on the aggregate, and the outer-optimizer
    update. Pure in ``(state, deltas, weights)`` — jit it.

    With a ``codec``, ``deltas`` arrive as encoded payloads (``run_clients``'s
    wire format) and are decoded to float32 per client *before* the weighted
    mean — the weight vector therefore applies to the decoded deltas, so elastic
    participation and compression compose without either knowing about the other.

    The leading axis of ``deltas`` need not be a synchronous cohort: the async
    aggregator's flush (``core/async_agg.flush_buffer``) calls this exact function
    on its delta *buffer* with staleness-discounted weights, which is what keeps
    the sync and async server updates algebraically (and, at matched inputs,
    bitwise) identical.
    """
    if codec is not None:
        deltas = jax.vmap(codec.decode)(deltas)
    elastic = client_weights is not None
    if elastic:
        w = client_weights.astype(jnp.float32)
    global_params = state["params"]

    # THE once-per-round collective on the mesh (weighted when elastic)
    if elastic:
        pseudo_grad = _weighted_mean_clients(deltas, w)
    else:
        pseudo_grad = _mean_clients(deltas)

    # the leading axis is the cohort for the sync round but the *buffer* for the
    # async flush — size it from the data, not from fed.clients_per_round
    C = jax.tree_util.tree_leaves(deltas)[0].shape[0]

    rng, noise_rng = jax.random.split(state["rng"])
    if fed.dp_noise > 0.0:
        # noise must cover the worst single client's influence on the aggregate:
        # for the weighted mean that is max_k w_k/Σw (= 1/C when uniform), NOT
        # 1/K_eff — with skewed data-size weights one heavy client can dominate
        if elastic:
            scale = fed.dp_noise * jnp.max(w) / jnp.maximum(jnp.sum(w), 1e-12)
        else:
            scale = fed.dp_noise / C
        leaves, treedef = jax.tree_util.tree_flatten(pseudo_grad)
        keys = jax.random.split(noise_rng, len(leaves))
        leaves = [
            l + scale * jax.random.normal(k, l.shape, l.dtype)
            for l, k in zip(leaves, keys)
        ]
        pseudo_grad = jax.tree_util.tree_unflatten(treedef, leaves)

    new_global, new_outer = outer_update(
        fed.outer, global_params, pseudo_grad, state["outer"]
    )

    # ---- aggregation metrics (paper Figs 7, 8) — shared formula set ----
    delta_norms = jax.vmap(global_norm)(deltas)
    metrics = dict(
        aggregation_metrics(delta_norms, global_norm(pseudo_grad), client_weights),
        global_model_norm=global_norm(new_global),
    )

    new_state = {
        "params": new_global,
        "outer": new_outer,
        "round": state["round"] + 1,
        "rng": rng,
    }
    return new_state, metrics


def federated_round(
    loss_fn: Callable,  # (params, batch) -> (loss, metrics_dict)
    fed: FederatedConfig,
    state: Dict[str, Any],
    batches: Dict[str, jax.Array],  # leaves (τ, C, ...) — per-step per-client batches
    client_weights: Optional[jax.Array] = None,  # (C,) elastic participation weights
    shard_clients: Optional[Callable] = None,  # sharding-constraint hook (mesh runs)
    codec: Optional[Codec] = None,  # uplink codec (encode client-side, decode server-side)
    residuals: Optional[Any] = None,  # (C, ...) cohort error-feedback residuals
    tau_steps: Optional[jax.Array] = None,  # (C,) int32 realized per-client steps τ_i
    apply_fn: Optional[Callable] = None,  # server-phase override (fused Pallas path)
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """One full federated round — :func:`run_clients` composed with
    :func:`apply_aggregate`. Pure function of (state, batches, weights, residuals,
    tau_steps) — jit it.

    ``apply_fn`` swaps the server phase for a drop-in replacement with
    ``apply_aggregate``'s exact signature and state/metrics contract — the
    ``--fused-server`` flag plugs ``kernels/fedcore.fused_apply_aggregate``
    (the flat-buffer Pallas pass) in here. ``None`` keeps this jnp reference
    phase, bitwise-unchanged.

    ``tau_steps`` enables straggler partial progress (see :func:`run_clients`);
    the caller's weight policy (``core/aggregator``) is expected to scale the
    weights by τ_i/τ so a partial delta is credited fractionally. An all-full
    τ-vector is bitwise ``tau_steps=None``.

    ``client_weights`` makes the round *elastic*: a (C,) vector of aggregation
    weights (e.g. FedAvg data sizes from a ``ParticipationPlan``), where a zero
    marks a dropped/straggling/unavailable client whose delta is excluded from the
    aggregate. Because the weights are a traced array argument, any effective
    cohort K_eff ≤ C runs inside the one compiled computation — no recompile when
    participation changes round to round. ``None`` (and equivalently all-ones
    weights, bitwise) reproduces the legacy flat-mean round.

    ``codec`` compresses the uplink between the two phases; the identity codec
    (and ``None``) keep the round bitwise the uncompressed one. For stateful
    codecs the updated cohort residuals come back as
    ``new_state['uplink_residuals']`` (plus in-graph ``uplink_residual_norm``
    telemetry); use :func:`federated_round_with_uplink` when the residuals live
    in a population-keyed store.
    """
    deltas, aux = run_clients(
        loss_fn, fed, state, batches,
        client_weights=client_weights, shard_clients=shard_clients,
        codec=codec, residuals=residuals, tau_steps=tau_steps,
    )
    new_state, agg_metrics = (apply_fn or apply_aggregate)(
        fed, state, deltas, client_weights=client_weights, codec=codec
    )

    step_metrics = aux["step_metrics"]
    metrics = {
        "train_loss": step_metrics["loss"][-1],
        "train_loss_mean": jnp.mean(step_metrics["loss"]),
        "client_grad_norm": step_metrics["grad_norm"][-1],
        "applied_update_norm": step_metrics["applied_update_norm"][-1],
        "lr": step_metrics["lr"][-1],
        "client_model_norm_mean": aux["client_model_norm_mean"],
        "avg_client_model_norm": aux["avg_client_model_norm"],
        **agg_metrics,
    }

    if fed.keep_inner_state:
        new_state["inner"] = aux["inner"]
    if "residuals" in aux:
        new_state["uplink_residuals"] = aux["residuals"]
        metrics["uplink_residual_norm"] = aux["uplink_residual_norm"]
    return new_state, metrics


# ---------------------------------------------------------------------------
# Population-keyed error-feedback residual store
# ---------------------------------------------------------------------------


def init_uplink_residuals(codec: Optional[Codec], params, population: int):
    """The per-client error-feedback store: one zero residual row per POPULATION
    client, leaves (P, ...) float32. This is the ownership story for compression
    residuals — a client's row follows it across rounds, cohorts, and (async)
    dispatches, and the store checkpoints/resumes as ordinary state. ``None`` for
    stateless codecs (no residual to own)."""
    if codec is None or not codec.stateful:
        return None
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((population,) + p.shape, jnp.float32), params
    )


def federated_round_with_uplink(
    loss_fn: Callable,
    fed: FederatedConfig,
    codec: Optional[Codec],
    state: Dict[str, Any],
    batches: Dict[str, jax.Array],
    client_weights: Optional[jax.Array] = None,
    selected: Optional[jax.Array] = None,  # (C,) population ids bound to the client axis
    shard_clients: Optional[Callable] = None,
    tau_steps: Optional[jax.Array] = None,  # (C,) int32 realized per-client steps τ_i
    apply_fn: Optional[Callable] = None,  # server-phase override (fused Pallas path)
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """:func:`federated_round` wired to the population-keyed residual store.

    ``state['uplink_residuals']`` holds one error-feedback row per population
    client; ``selected`` binds this round's client axis to population ids (the
    ``ParticipationPlan.selected`` vector, traced — changing cohorts never
    recompiles). The cohort's rows are gathered, the round runs, and the updated
    rows scatter back — masked clients' rows come back bitwise unchanged (the
    gather/scatter is then a no-op for them), so padding slots can never clobber
    a live client's residual. ``selected`` always holds distinct ids (sampler
    contract), so the scatter is order-independent.

    Stateless codecs (and ``codec=None``) reduce to plain ``federated_round``.
    """
    if codec is None or not codec.stateful:
        return federated_round(
            loss_fn, fed, state, batches, client_weights=client_weights,
            shard_clients=shard_clients, codec=codec, tau_steps=tau_steps,
            apply_fn=apply_fn,
        )
    if selected is None:
        raise ValueError("stateful uplink codec requires the cohort's population ids")
    store = state["uplink_residuals"]
    core = {k: v for k, v in state.items() if k != "uplink_residuals"}
    sel = selected.astype(jnp.int32)
    cohort_res = jax.tree_util.tree_map(lambda r: jnp.take(r, sel, axis=0), store)
    new_core, metrics = federated_round(
        loss_fn, fed, core, batches, client_weights=client_weights,
        shard_clients=shard_clients, codec=codec, residuals=cohort_res,
        tau_steps=tau_steps, apply_fn=apply_fn,
    )
    new_cohort_res = new_core.pop("uplink_residuals")
    new_core["uplink_residuals"] = jax.tree_util.tree_map(
        lambda r, n: r.at[sel].set(n), store, new_cohort_res
    )
    return new_core, metrics


# ---------------------------------------------------------------------------
# Centralized baseline (paper's comparison target)
# ---------------------------------------------------------------------------


def init_centralized_state(inner: InnerOptConfig, params) -> Dict[str, Any]:
    return {
        "params": params,
        "inner": init_inner_state(inner, params),
        "step": jnp.zeros((), jnp.int32),
    }


def centralized_step(
    loss_fn: Callable,
    inner: InnerOptConfig,
    state: Dict[str, Any],
    batch: Dict[str, jax.Array],  # leaves (B, ...) — the full global batch
    grad_accum: int = 1,
    pre_split: bool = False,
) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
    """Standard synchronous data-parallel step: per-step gradient all-reduce."""
    (loss, metrics), grads = _accum_value_and_grad(
        loss_fn, state["params"], batch, grad_accum, pre_split=pre_split
    )
    new_params, new_inner, opt_metrics = inner_update(
        inner, state["params"], grads, state["inner"], state["step"]
    )
    metrics = dict(metrics, **opt_metrics)
    metrics["global_model_norm"] = global_norm(new_params)
    return (
        {"params": new_params, "inner": new_inner, "step": state["step"] + 1},
        metrics,
    )


# ---------------------------------------------------------------------------
# Hierarchical (two-level) aggregation — Photon's sub-federation (Alg. 1 L.19–24)
# ---------------------------------------------------------------------------


def hierarchical_mean(deltas, n_groups: int, weights: Optional[jax.Array] = None):
    """Two-phase mean: partial aggregation within node groups (Photon LLM Node islands),
    then across groups. With equal group sizes this equals the flat mean (tested); on
    the mesh it pins the reduce-within-pod → reduce-across-pods schedule.

    With ``weights`` (C,) each island forwards Σ_k w_k Δ_k and Σ_k w_k; the server
    divides once — algebraically identical to the weighted flat mean, so elastic
    participation composes with sub-federation for free."""

    def two_level(x):
        c = x.shape[0]
        assert c % n_groups == 0, (c, n_groups)
        grouped = x.reshape(n_groups, c // n_groups, *x.shape[1:])
        partial = jnp.mean(grouped, axis=1)  # within-island partial aggregation
        return jnp.mean(partial, axis=0)  # server aggregation of island results

    if weights is None:
        return jax.tree_util.tree_map(two_level, deltas)

    w = weights.astype(jnp.float32)
    w_sum = _safe_weight_sum(w)

    def two_level_weighted(x):
        c = x.shape[0]
        assert c % n_groups == 0, (c, n_groups)
        grouped = _weigh_clients(x, w).reshape(n_groups, c // n_groups, *x.shape[1:])
        partial = jnp.sum(grouped, axis=1)  # within-island weighted partial sums
        return jnp.sum(partial, axis=0) / w_sum.astype(x.dtype)

    return jax.tree_util.tree_map(two_level_weighted, deltas)
