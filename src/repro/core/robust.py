"""Byzantine-resilient aggregation — screening, robust rules, rollback.

The stack up to PR 9 trusts every admitted delta: a single NaN, overflowed, or
adversarially scaled payload flows straight through the weighted mean into the
global model. That is untenable for the cross-institution collaboration the
paper envisions (and the FL-LLM security survey, arXiv 2406.09831, names
Byzantine-robust aggregation as the standard defense). This module is the
defense subsystem, plugged into the existing seams without touching the healthy
path:

  =======================  ====================================================
  defense layer            where it plugs in
  =======================  ====================================================
  delta screen             the (C,) weight vector of the masked elastic round —
                           :func:`screen_cohort` zero-weights non-finite and
                           norm-outlier clients (median/MAD z-score) *inside*
                           the jitted round, no recompiles; the async door gets
                           the same test as an admission predicate
                           (``admit_delta(screen=...)``)
  robust aggregation rule  ``apply_aggregate``'s ``apply_fn`` seam —
                           :func:`make_robust_apply_fn` swaps the weighted mean
                           for a trimmed mean / coordinate median / norm-clipped
                           mean and reuses ``_finish_aggregate`` (the identical
                           DP-noise → outer-update → metrics tail)
  tiled composition        per-tile order-statistic moments
                           (:func:`tile_fold_init` / ``update`` / ``finish``) —
                           top-k/bottom-k buffers + running sum fold across
                           cohort tiles so trimming stays *exact* without ever
                           materializing the (C, N) delta matrix
  divergence rollback      :class:`RobustState` — a host-side, checkpointable
                           monitor (update-norm spike guard, quarantine table,
                           admitted-norm history) that rides
                           ``manifest['robust']`` so kill/``--resume`` replays
                           bitwise; the train loop performs the actual rollback
                           through ``CheckpointManager``
  =======================  ====================================================

Everything jitted here is a pure function of ``(state, deltas, weights)``;
everything stateful is host-side JSON in :class:`RobustState`. With
``rule='none'`` and screening off no apply_fn is installed and no manifest key
is written — the round is bitwise the undefended one (asserted in tests).

The cardinal trap, documented once here and respected everywhere: **a zero
weight does not neutralize a non-finite delta** (0·NaN = NaN). Flagged
non-finite lanes must have their *values* rewritten (:func:`sanitize_deltas`)
before any sum touches them; finite outliers only need the zero weight.
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, Iterable, Tuple

import jax
import jax.numpy as jnp

from repro.core.federated import (
    FederatedConfig,
    _finish_aggregate,
    _weigh_clients,
    _weighted_mean_clients,
)
from repro.core.inner_opt import global_norm

#: the ``--robust-agg`` choices — 'none' means "mean, exactly as before"
ROBUST_RULES = ("none", "trimmed", "median", "normclip")


@dataclass(frozen=True)
class RobustAggConfig:
    """Knobs of the defense subsystem (the ``--robust-*`` flag family).

    The defaults are all-off: ``rule='none'`` + ``screen=False`` installs no
    apply_fn and the round stays bitwise the PR-9 round. ``clip_norm == 0``
    selects the *adaptive* clip threshold (median admitted norm × ``clip_mult``,
    recomputed every aggregation); a positive value is an absolute threshold —
    and the only normclip mode that composes with cohort tiling, where the
    in-pass median over all tiles is not available when early tiles fold.
    """

    rule: str = "none"  # none | trimmed | median | normclip
    trim_fraction: float = 0.1  # trimmed: fraction trimmed from EACH tail
    clip_mult: float = 3.0  # normclip adaptive: τ = median(norms) · clip_mult
    clip_norm: float = 0.0  # normclip absolute τ (0 → adaptive)
    screen: bool = False  # median/MAD norm screen + non-finite rejection
    screen_z: float = 6.0  # robust z-score flag threshold
    screen_warmup: int = 8  # async: admitted norms before the bound engages
    rollback: bool = False  # divergence guard + checkpoint rollback
    rollback_window: int = 8  # guard window (accepted pg-norm history)
    rollback_factor: float = 4.0  # trigger: pg_norm > window median × factor
    quarantine_rounds: int = 4  # rounds an offending client id sits out

    def __post_init__(self):
        if self.rule not in ROBUST_RULES:
            raise ValueError(f"rule must be one of {ROBUST_RULES}, got {self.rule!r}")
        if not 0.0 <= self.trim_fraction < 0.5:
            raise ValueError(
                f"trim_fraction must be in [0, 0.5), got {self.trim_fraction}"
            )
        if self.clip_mult <= 0.0:
            raise ValueError(f"clip_mult must be > 0, got {self.clip_mult}")
        if self.clip_norm < 0.0:
            raise ValueError(f"clip_norm must be >= 0, got {self.clip_norm}")
        if self.screen_z <= 0.0:
            raise ValueError(f"screen_z must be > 0, got {self.screen_z}")
        if self.screen_warmup < 1:
            raise ValueError(f"screen_warmup must be >= 1, got {self.screen_warmup}")
        if self.rollback_window < 2:
            raise ValueError(
                f"rollback_window must be >= 2, got {self.rollback_window}"
            )
        if self.rollback_factor <= 1.0:
            raise ValueError(
                f"rollback_factor must be > 1, got {self.rollback_factor}"
            )
        if self.quarantine_rounds < 1:
            raise ValueError(
                f"quarantine_rounds must be >= 1, got {self.quarantine_rounds}"
            )

    @property
    def active(self) -> bool:
        """True when the aggregation math itself changes (apply_fn installed)."""
        return self.rule != "none" or self.screen

    @property
    def stateful(self) -> bool:
        """True when host-side defense state must ride the manifest."""
        return self.active or self.rollback


# ---------------------------------------------------------------------------
# Order statistics under a mask — the jittable building blocks
# ---------------------------------------------------------------------------


def masked_median(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Median of ``x[mask]`` at fixed shape: invalid lanes sort to +inf, the
    two middle ranks of the n valid lanes are averaged (traced gather, so n may
    vary round to round without recompiling). n == 0 → 0."""
    filled = jnp.where(mask, x.astype(jnp.float32), jnp.inf)
    s = jnp.sort(filled)
    n = jnp.sum(mask.astype(jnp.int32))
    lo = jnp.take(s, jnp.maximum((n - 1) // 2, 0))
    hi = jnp.take(s, jnp.maximum(n // 2, 0))
    return jnp.where(n > 0, 0.5 * (lo + hi), 0.0)


def screen_cohort(
    delta_norms: jax.Array,  # (C,) per-client delta norms (may contain NaN/inf)
    weights: jax.Array,  # (C,) aggregation weights (0 = already masked out)
    z: float,  # robust z-score threshold
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The delta screen: non-finite rejection + median/MAD norm-outlier test.

    Returns ``(new_weights, flagged, finite)`` — flagged lanes are zero-weighted
    in ``new_weights``; healthy lanes keep their weight *bitwise*
    (``where(False, 0, w)`` returns ``w`` unchanged), which is what lets the
    screen live inside the already-compiled masked round.

    The outlier test uses the robust z-score |x − med| / (1.4826·MAD) over the
    valid lanes only, and disarms itself below 3 valid clients (median/MAD of a
    pair flags nothing meaningful). Non-finite norms are always flagged —
    callers must also :func:`sanitize_deltas` those lanes (0·NaN = NaN).
    """
    finite = jnp.isfinite(delta_norms)
    valid = finite & (weights > 0)
    med = masked_median(delta_norms, valid)
    dev = jnp.where(valid, jnp.abs(delta_norms - med), 0.0)
    mad = masked_median(dev, valid)
    sigma = jnp.maximum(1.4826 * mad, 1e-12)
    n_valid = jnp.sum(valid.astype(jnp.int32))
    outlier = valid & (dev / sigma > z) & (n_valid >= 3)
    flagged = (~finite) | outlier
    new_w = jnp.where(flagged, 0.0, weights)
    return new_w, flagged, finite


def sanitize_deltas(deltas, finite: jax.Array):
    """Zero every element of each non-finite client lane. A zero weight does
    NOT remove a poisoned lane from any sum (0·NaN = NaN) — the lane's values
    must be rewritten. All-finite cohorts pass through bitwise (``where`` with
    an all-True mask returns the original array)."""

    def fix(x):
        m = finite.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, jnp.zeros_like(x))

    return jax.tree_util.tree_map(fix, deltas)


# ---------------------------------------------------------------------------
# Robust aggregation rules — flat (C, ...) cohort
# ---------------------------------------------------------------------------
#
# Trimmed mean and coordinate median are the standard Byzantine-robust
# estimators (Yin et al. 2018): they operate UNWEIGHTED over the admitted
# lanes — the weight vector acts purely as the admission mask (w > 0), because
# an attacker who can inflate its own aggregation weight defeats any weighted
# order statistic. Norm-clipping keeps the weighted mean but bounds each
# client's influence.


def _trim_count(trim_fraction: float, n: jax.Array) -> jax.Array:
    """k_eff = min(floor(trim·n), (n−1)//2) — never trims past the median."""
    k = (trim_fraction * n.astype(jnp.float32)).astype(jnp.int32)
    return jnp.clip(k, 0, jnp.maximum((n - 1) // 2, 0))


def trimmed_mean_clients(deltas, admit: jax.Array, trim_fraction: float):
    """Coordinate-wise trimmed mean over admitted lanes: per coordinate, drop
    the k_eff smallest and k_eff largest admitted values, average the rest.
    Admitted lanes must be finite (non-finite norms fail ``admit`` upstream),
    so the ±inf sort sentinels for masked lanes are unambiguous."""
    c = admit.shape[0]
    n = jnp.sum(admit.astype(jnp.int32))
    k_eff = _trim_count(trim_fraction, n)

    def tm(x):
        m = admit.reshape((-1,) + (1,) * (x.ndim - 1))
        s = jnp.sort(jnp.where(m, x, jnp.inf), axis=0)  # masked lanes sort last
        rank = jnp.arange(c).reshape((-1,) + (1,) * (x.ndim - 1))
        sel = (rank >= k_eff) & (rank < n - k_eff)
        kept = jnp.sum(jnp.where(sel, s, 0.0), axis=0)
        return kept / jnp.maximum(n - 2 * k_eff, 1).astype(x.dtype)

    return jax.tree_util.tree_map(tm, deltas)


def median_clients(deltas, admit: jax.Array):
    """Coordinate-wise median over admitted lanes (even n averages the two
    middle ranks, matching :func:`masked_median`). Zero everywhere if no lane
    is admitted."""
    n = jnp.sum(admit.astype(jnp.int32))
    lo_rank = jnp.maximum((n - 1) // 2, 0)
    hi_rank = jnp.maximum(n // 2, 0)

    def med(x):
        m = admit.reshape((-1,) + (1,) * (x.ndim - 1))
        s = jnp.sort(jnp.where(m, x, jnp.inf), axis=0)
        lo = jnp.take(s, lo_rank, axis=0)
        hi = jnp.take(s, hi_rank, axis=0)
        return jnp.where(n > 0, 0.5 * (lo + hi), 0.0).astype(x.dtype)

    return jax.tree_util.tree_map(med, deltas)


def normclip_scale(
    delta_norms: jax.Array,  # (C,) — may contain NaN/inf (those lanes scale 0)
    admit: jax.Array,  # (C,) bool
    tau: jax.Array,  # () clip threshold
) -> jax.Array:
    """Per-client norm-clip factor s_k = min(1, τ/‖Δ_k‖); non-admitted lanes
    get exactly 0 (their values are already sanitized upstream)."""
    safe = jnp.maximum(jnp.where(jnp.isfinite(delta_norms), delta_norms, 1.0), 1e-12)
    return jnp.where(admit, jnp.minimum(1.0, tau / safe), 0.0)


def make_robust_apply_fn(fed: FederatedConfig, cfg: RobustAggConfig):
    """Build a drop-in server phase with ``apply_aggregate``'s exact signature
    and state/metrics contract — installs at the same ``apply_fn`` seam as the
    fused Pallas phase (the two are mutually exclusive; the aggregator rejects
    the combination).

    Pipeline: decode → screen (optional) → sanitize non-finite lanes → robust
    estimator (or the plain weighted mean for ``rule='none'`` + screen) →
    ``_finish_aggregate`` (the shared DP-noise/outer-update/metrics tail).
    With screening on, the returned metrics carry a ``screen_mask`` (C,) lane
    so the host can trace/quarantine flagged clients — ``SyncAggregator`` pops
    it before the scalar metrics row is assembled.
    """
    if not cfg.active:
        raise ValueError("make_robust_apply_fn called with an inactive config")

    def robust_apply(fed_, state, deltas, client_weights=None, codec=None):
        if codec is not None:
            deltas = jax.vmap(codec.decode)(deltas)
        c = jax.tree_util.tree_leaves(deltas)[0].shape[0]
        w = (
            client_weights.astype(jnp.float32)
            if client_weights is not None
            else jnp.ones((c,), jnp.float32)
        )
        raw_norms = jax.vmap(global_norm)(deltas)
        finite = jnp.isfinite(raw_norms)
        extra = {}
        if cfg.screen:
            w, flagged, finite = screen_cohort(raw_norms, w, cfg.screen_z)
            extra["screen_mask"] = flagged.astype(jnp.float32)
            extra["screened_clients"] = jnp.sum(flagged.astype(jnp.float32))
        deltas = sanitize_deltas(deltas, finite)
        admit = (w > 0) & finite

        if cfg.rule == "trimmed":
            pseudo_grad = trimmed_mean_clients(deltas, admit, cfg.trim_fraction)
        elif cfg.rule == "median":
            pseudo_grad = median_clients(deltas, admit)
        elif cfg.rule == "normclip":
            if cfg.clip_norm > 0.0:
                tau = jnp.asarray(cfg.clip_norm, jnp.float32)
            else:
                tau = masked_median(raw_norms, admit) * cfg.clip_mult
            scale = normclip_scale(raw_norms, admit, tau)
            pseudo_grad = _weighted_mean_clients(
                jax.tree_util.tree_map(lambda x: _weigh_clients(x, scale), deltas), w
            )
        else:  # 'none' — screen-only: plain weighted mean over screened weights
            pseudo_grad = _weighted_mean_clients(deltas, w)

        # raw (unsanitized) norms feed the metrics: aggregation_metrics is
        # NaN-aware and reports poisoned lanes as nonfinite_deltas
        new_state, metrics = _finish_aggregate(fed, state, pseudo_grad, raw_norms, w)
        return new_state, dict(metrics, **extra)

    return robust_apply


# ---------------------------------------------------------------------------
# Tiled composition — exact trimming/median across streamed cohort tiles
# ---------------------------------------------------------------------------
#
# The streamed round (PR 9) folds each tile to a weighted partial sum and never
# holds the (C, N) delta matrix. Order statistics need more than a sum, but not
# the full matrix: a coordinate's trimmed mean is recoverable from (running
# total, top-k buffer, bottom-k buffer, admitted count) as long as k bounds the
# trim count — total − Σ(top k_eff) − Σ(bottom k_eff), averaged over n − 2k_eff.
# The median is rank (n−1)//2, n//2 of the bottom buffer with k = C//2 + 1.
# Memory is O(k·N) instead of O(C·N); for the median that is ~half the flat
# buffer (documented trade: tiled median halves, not removes, the C-term).


def tile_fold_size(rule: str, trim_fraction: float, c_total: int) -> int:
    """Static per-coordinate buffer depth k for the cross-tile fold."""
    if rule == "trimmed":
        return max(1, int(trim_fraction * c_total))
    if rule == "median":
        return c_total // 2 + 1
    raise ValueError(f"no tiled fold for rule {rule!r}")


def tile_fold_init(params_like, k: int) -> Dict[str, Any]:
    """Empty fold: ∓inf sentinel buffers, zero totals, zero count."""
    return {
        "top": jax.tree_util.tree_map(
            lambda p: jnp.full((k,) + p.shape, -jnp.inf, jnp.float32), params_like
        ),
        "bot": jax.tree_util.tree_map(
            lambda p: jnp.full((k,) + p.shape, jnp.inf, jnp.float32), params_like
        ),
        "total": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_like
        ),
        "count": jnp.zeros((), jnp.int32),
    }


def tile_fold_update(fold: Dict[str, Any], deltas, admit: jax.Array):
    """Fold one tile's decoded deltas in: masked lanes enter as ∓inf (so they
    can never displace a real value), buffers re-sort and truncate to k, totals
    and the admitted count accumulate. Pure — jit once, replay per tile."""
    k = jax.tree_util.tree_leaves(fold["top"])[0].shape[0]

    def upd_top(top, d):
        m = admit.reshape((-1,) + (1,) * (d.ndim - 1))
        cat = jnp.concatenate([top, jnp.where(m, d, -jnp.inf)], axis=0)
        return jnp.sort(cat, axis=0)[-k:]

    def upd_bot(bot, d):
        m = admit.reshape((-1,) + (1,) * (d.ndim - 1))
        cat = jnp.concatenate([bot, jnp.where(m, d, jnp.inf)], axis=0)
        return jnp.sort(cat, axis=0)[:k]

    def upd_total(t, d):
        m = admit.reshape((-1,) + (1,) * (d.ndim - 1))
        return t + jnp.sum(jnp.where(m, d, 0.0), axis=0)

    return {
        "top": jax.tree_util.tree_map(upd_top, fold["top"], deltas),
        "bot": jax.tree_util.tree_map(upd_bot, fold["bot"], deltas),
        "total": jax.tree_util.tree_map(upd_total, fold["total"], deltas),
        "count": fold["count"] + jnp.sum(admit.astype(jnp.int32)),
    }


def tile_fold_finish(fold: Dict[str, Any], rule: str, trim_fraction: float):
    """Recover the robust pseudo-gradient from the folded moments.

    Trimmed: total − Σ(largest k_eff) − Σ(smallest k_eff), over n − 2k_eff.
    k_eff ≤ min(k, (n−1)//2) by construction, so the selected buffer entries
    are always real values, never ∓inf sentinels (n admitted values fill the
    buffer ends nearest the data). Median: ranks (n−1)//2 and n//2 of the
    ascending bottom buffer — in range because n ≤ C and k = C//2 + 1.

    Matches the flat estimators to float tolerance, NOT bitwise: the running
    total sums in tile order, the flat path in lane order.
    """
    n = fold["count"]
    k = jax.tree_util.tree_leaves(fold["top"])[0].shape[0]

    if rule == "trimmed":
        k_eff = jnp.minimum(_trim_count(trim_fraction, n), k)

        def fin(top, bot, total):
            rank = jnp.arange(k).reshape((-1,) + (1,) * total.ndim)
            top_sum = jnp.sum(jnp.where(rank >= k - k_eff, top, 0.0), axis=0)
            bot_sum = jnp.sum(jnp.where(rank < k_eff, bot, 0.0), axis=0)
            kept = total - top_sum - bot_sum
            return kept / jnp.maximum(n - 2 * k_eff, 1).astype(total.dtype)

        return jax.tree_util.tree_map(fin, fold["top"], fold["bot"], fold["total"])

    if rule == "median":
        lo_rank = jnp.maximum((n - 1) // 2, 0)
        hi_rank = jnp.maximum(n // 2, 0)

        def fin(bot):
            lo = jnp.take(bot, lo_rank, axis=0)
            hi = jnp.take(bot, hi_rank, axis=0)
            return jnp.where(n > 0, 0.5 * (lo + hi), 0.0)

        return jax.tree_util.tree_map(fin, fold["bot"])

    raise ValueError(f"no tiled fold for rule {rule!r}")


# ---------------------------------------------------------------------------
# Byzantine client simulator — deterministic payload corruption for benches
# ---------------------------------------------------------------------------

#: payload corruption kinds shared by the chaos monkey and the bench simulator
CORRUPT_KINDS = ("nan", "inf", "scale", "sign_flip", "replay")


def corrupt_tree(tree, kind: str, scale: float = 64.0):
    """Apply one payload corruption to a delta/payload pytree (float leaves
    only — integer codec index planes are left alone so the payload still
    decodes). 'replay' is a transport-level kind (resend an old payload) and
    has no single-tree form — callers handle it."""
    def is_float(x):
        return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)

    if kind == "nan":
        fn = lambda x: jnp.full_like(x, jnp.nan) if is_float(x) else x
    elif kind == "inf":
        fn = lambda x: jnp.full_like(x, jnp.inf) if is_float(x) else x
    elif kind == "scale":
        fn = lambda x: x * jnp.asarray(scale, x.dtype) if is_float(x) else x
    elif kind == "sign_flip":
        fn = lambda x: -x if is_float(x) else x
    else:
        raise ValueError(f"corrupt_tree cannot apply kind {kind!r}")
    return jax.tree_util.tree_map(fn, tree)


def make_byzantine_fn(fraction: float, kind: str, population: int):
    """Deterministic Byzantine cohort for the bench/simulator path: population
    client ids below ``floor(fraction · P)`` are attackers and corrupt every
    delta they push; everyone else is honest. Returns None for fraction 0.

    The returned callable has the ``AsyncFederationDriver.corrupt_fn``
    signature ``(client_id, dispatch_index, delta) -> delta``.
    """
    if fraction <= 0.0:
        return None
    if kind not in CORRUPT_KINDS or kind == "replay":
        raise ValueError(f"byzantine kind must be one of {CORRUPT_KINDS[:-1]}, got {kind!r}")
    bad = int(fraction * population)

    def corrupt(client_id: int, index: int, delta):
        if int(client_id) >= bad:
            return delta
        return corrupt_tree(delta, kind)

    return corrupt


# ---------------------------------------------------------------------------
# Host-side defense state — quarantine, norm history, divergence guard
# ---------------------------------------------------------------------------


class RobustState:
    """The checkpointable host half of the defense: everything the jitted math
    cannot own because it spans rounds and client identities.

    - ``quarantine``: population client id → release round. Quarantined ids are
      zero-weighted (sync) or skipped before their client phase runs (async).
    - ``norm_history``: trailing admitted delta norms — the async door's
      adaptive screen bound (median + z·1.4826·MAD) once ``screen_warmup``
      samples exist.
    - ``guard_window``: trailing accepted pseudo-gradient norms; the divergence
      guard trips when a new pg-norm is non-finite or exceeds the full window's
      median × ``rollback_factor``. Triggering values are NOT appended, so one
      spike cannot drag the baseline up.
    - ``last_good``: newest round whose checkpoint the guard has blessed — the
      rollback target.

    Serializes to plain JSON via :meth:`state_dict` and rides
    ``manifest['robust']``; restoring replays bitwise because every decision
    above is a pure function of this state.
    """

    def __init__(self, cfg: RobustAggConfig):
        self.cfg = cfg
        self.quarantine: Dict[int, int] = {}
        self.norm_history: deque = deque(maxlen=max(4 * cfg.screen_warmup, 32))
        self.guard_window: deque = deque(maxlen=cfg.rollback_window)
        self.last_good: int = -1
        self.counters: Dict[str, int] = {
            "screen_rejects": 0,
            "quarantines": 0,
            "rollbacks": 0,
        }

    # -- quarantine -------------------------------------------------------
    def is_quarantined(self, client_id: int, rnd: int) -> bool:
        """True while ``rnd`` is before the client's release round (expired
        entries are dropped on query, keeping the table small)."""
        release = self.quarantine.get(int(client_id))
        if release is None:
            return False
        if rnd >= release:
            del self.quarantine[int(client_id)]
            return False
        return True

    def add_quarantine(self, client_ids: Iterable[int], rnd: int) -> None:
        for cid in client_ids:
            self.quarantine[int(cid)] = max(
                self.quarantine.get(int(cid), 0), rnd + self.cfg.quarantine_rounds
            )
            self.counters["quarantines"] += 1

    # -- async admission norm screen --------------------------------------
    def observe_norm(self, norm: float) -> None:
        v = float(norm)
        if v == v and abs(v) != float("inf"):  # finite only — NaN != NaN
            self.norm_history.append(v)

    def norm_bound(self) -> float:
        """Adaptive admission bound: median + z·1.4826·MAD of the trailing
        admitted norms; +inf until ``screen_warmup`` samples exist (cold
        starts must not reject the first honest arrivals). The bound is
        floored at 2× the median: with near-identical warmup norms the MAD
        collapses to ~0 and a pure z-score bound would reject every honest
        delta whose norm drifts as the server model moves — and because only
        admitted norms refresh the history, the door could never recover.
        Doubling headroom keeps honest drift admissible while still rejecting
        large-scale amplification attacks."""
        if len(self.norm_history) < self.cfg.screen_warmup:
            return float("inf")
        vals = sorted(self.norm_history)
        med = _median_sorted(vals)
        mad = _median_sorted(sorted(abs(v - med) for v in vals))
        return max(med + self.cfg.screen_z * 1.4826 * mad, 2.0 * med, 1e-9)

    # -- divergence guard -------------------------------------------------
    def observe_update(self, pg_norm: float) -> bool:
        """Feed one accepted aggregation's pseudo-gradient norm; returns True
        when the guard trips (caller rolls back to ``last_good``)."""
        v = float(pg_norm)
        if v != v or abs(v) == float("inf"):
            return True
        if (
            len(self.guard_window) == self.cfg.rollback_window
            and v > _median_sorted(sorted(self.guard_window)) * self.cfg.rollback_factor
        ):
            return True
        self.guard_window.append(v)
        return False

    def mark_good(self, rnd: int) -> None:
        self.last_good = max(self.last_good, int(rnd))

    def note_rollback(self) -> None:
        self.counters["rollbacks"] += 1

    def note_screen_rejects(self, n: int = 1) -> None:
        self.counters["screen_rejects"] += int(n)

    # -- checkpoint round-trip -------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "quarantine": {str(k): int(v) for k, v in self.quarantine.items()},
            "norm_history": [float(v) for v in self.norm_history],
            "guard_window": [float(v) for v in self.guard_window],
            "last_good": int(self.last_good),
            "counters": dict(self.counters),
        }

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.quarantine = {int(k): int(v) for k, v in d.get("quarantine", {}).items()}
        self.norm_history = deque(
            d.get("norm_history", []), maxlen=self.norm_history.maxlen
        )
        self.guard_window = deque(
            d.get("guard_window", []), maxlen=self.guard_window.maxlen
        )
        self.last_good = int(d.get("last_good", -1))
        self.counters.update({k: int(v) for k, v in d.get("counters", {}).items()})

    def snapshot_json(self) -> str:
        """Canonical JSON form (stable key order) — handy for bitwise-resume
        assertions in tests."""
        return json.dumps(self.state_dict(), sort_keys=True)


def _median_sorted(vals) -> float:
    vals = list(vals)
    n = len(vals)
    if n == 0:
        return 0.0
    return 0.5 * (vals[(n - 1) // 2] + vals[n // 2])
