"""The paper's primary contribution: the Photon federated pre-training engine."""
from repro.core.federated import (  # noqa: F401
    FederatedConfig,
    centralized_step,
    federated_round,
    hierarchical_mean,
    init_centralized_state,
    init_federated_state,
)
from repro.core.inner_opt import InnerOptConfig, cosine_lr, global_norm  # noqa: F401
from repro.core.outer_opt import OuterOptConfig  # noqa: F401
from repro.core.sampler import (  # noqa: F401
    STRAGGLER_PROFILES,
    ParticipationConfig,
    ParticipationPlan,
    StragglerProfile,
    client_example_counts,
    client_speeds,
    dirichlet_popularity,
    markov_availability,
    participation_counts,
    plan_round,
    sample_round,
)
