"""The paper's primary contribution: the Photon federated pre-training engine."""
from repro.core.aggregator import (  # noqa: F401
    AGGREGATOR_SCHEMA_VERSION,
    Aggregator,
    AsyncBufferAggregator,
    AsyncFederationDriver,
    SyncAggregator,
    partial_progress_weights,
)
from repro.core.async_agg import (  # noqa: F401
    AsyncAggConfig,
    admit_delta,
    admit_deltas,
    flush_buffer,
    init_async_state,
    staleness_discount,
)
from repro.core.compression import (  # noqa: F401
    Bf16Codec,
    Codec,
    IdentityCodec,
    Int8Codec,
    TopKCodec,
    UPLINK_SCHEMES,
    get_codec,
    uplink_bytes,
)
from repro.core.federated import (  # noqa: F401
    FederatedConfig,
    SparseResidualStore,
    aggregation_metrics,
    apply_aggregate,
    apply_aggregate_partial,
    centralized_step,
    combine_tile_metrics,
    federated_round,
    federated_round_with_uplink,
    hierarchical_mean,
    init_centralized_state,
    init_federated_state,
    init_uplink_residuals,
    run_client_tile,
    run_clients,
    tile_rng,
)
from repro.core.inner_opt import InnerOptConfig, cosine_lr, global_norm  # noqa: F401
from repro.core.outer_opt import OuterOptConfig  # noqa: F401
from repro.core.robust import (  # noqa: F401
    CORRUPT_KINDS,
    ROBUST_RULES,
    RobustAggConfig,
    RobustState,
    corrupt_tree,
    make_byzantine_fn,
    make_robust_apply_fn,
    masked_median,
    median_clients,
    normclip_scale,
    sanitize_deltas,
    screen_cohort,
    trimmed_mean_clients,
)
from repro.core.sampler import (  # noqa: F401
    STRAGGLER_PROFILES,
    AsyncTimeline,
    DispatchEvent,
    ParticipationConfig,
    ParticipationPlan,
    StragglerProfile,
    client_example_counts,
    client_speeds,
    dirichlet_popularity,
    markov_availability,
    participation_counts,
    plan_round,
    sample_round,
)
