"""Local (client-side) optimizers: AdamW and SGD, implemented from scratch, plus the
paper's cosine learning-rate schedule synchronized across *sequential* steps (Table 3).

The inner optimizer runs inside each client's local-step scan; its state is by default
discarded between rounds ("stateless clients", paper §7.8).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class InnerOptConfig:
    name: str = "adamw"  # 'adamw' | 'sgd'
    lr_max: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 1e-5
    grad_clip: float = 1.0
    # cosine schedule (synchronized across sequential steps, paper Table 3)
    warmup_steps: int = 100
    total_steps: int = 10_000
    alpha: float = 0.1  # lr_min = alpha * lr_max


def cosine_lr(cfg: InnerOptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr_max * step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    lr_min = cfg.alpha * cfg.lr_max
    cos = lr_min + 0.5 * (cfg.lr_max - lr_min) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), tree), gn


def init_inner_state(cfg: InnerOptConfig, params) -> Dict[str, Any]:
    zeros = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    if cfg.name == "adamw":
        return {"m": zeros(), "v": zeros(), "count": jnp.zeros((), jnp.int32)}
    if cfg.name == "sgd":
        return {"mom": zeros(), "count": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def inner_update(
    cfg: InnerOptConfig,
    params,
    grads,
    state: Dict[str, Any],
    global_step: jax.Array,  # sequential step index for the cosine schedule
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One local optimizer step. Returns (params, state, metrics)."""
    grads, raw_norm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = cosine_lr(cfg, global_step)
    count = state["count"] + 1

    if cfg.name == "adamw":
        c = count.astype(jnp.float32)
        b1c = 1.0 - cfg.beta1**c
        b2c = 1.0 - cfg.beta2**c
        new_m = jax.tree_util.tree_map(
            lambda m, g: cfg.beta1 * m + (1 - cfg.beta1) * g.astype(m.dtype), state["m"], grads
        )
        new_v = jax.tree_util.tree_map(
            lambda v, g: cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g.astype(v.dtype)),
            state["v"],
            grads,
        )

        def upd(p, m, v):
            mh = m / b1c
            vh = v / b2c
            step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
            return (p - lr * step).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, new_m, new_v)
        new_state = {"m": new_m, "v": new_v, "count": count}
    else:  # sgd with heavy-ball momentum
        new_mom = jax.tree_util.tree_map(
            lambda mom, g: 0.9 * mom + g.astype(mom.dtype), state["mom"], grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p, mom: (p - lr * (mom + cfg.weight_decay * p)).astype(p.dtype),
            params,
            new_mom,
        )
        new_state = {"mom": new_mom, "count": count}

    metrics = {"lr": lr, "grad_norm": raw_norm, "applied_update_norm": lr * raw_norm}
    return new_params, new_state, metrics
