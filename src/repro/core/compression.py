"""Pseudo-gradient compression for the client→server uplink (Algorithm 1 L.26
PostProcess). The paper ships lossless compression only; these are the beyond-paper
lossy options, all with unbiasedness or error-feedback so FedAvg convergence
guarantees carry over:

  - bf16 / f8 stochastic-rounding cast      (2x / 4x uplink reduction)
  - top-k sparsification with error feedback (10-100x, stateful residual per client)
  - per-tensor int8 quantization             (4x, scale+zero-point)

All operate on pseudo-gradient pytrees and compose with DP clipping (clip before
compress). The decompressed tree always has the original dtypes/shapes so the outer
optimizer is agnostic.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# casting (with optional stochastic rounding)
# ---------------------------------------------------------------------------


def cast_compress(tree, dtype=jnp.bfloat16, rng: Optional[jax.Array] = None):
    """Cast to a narrow dtype; with ``rng``, stochastic rounding keeps the cast
    unbiased (E[compress(x)] = x)."""
    if rng is None:
        return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(leaves))

    def sr(x, key):
        down = x.astype(dtype).astype(x.dtype)
        up = jnp.nextafter(
            down.astype(jnp.float32), jnp.full_like(down, jnp.inf, jnp.float32)
        ).astype(dtype).astype(x.dtype)
        span = jnp.where(up != down, up - down, 1.0)
        p_up = jnp.clip((x - down) / span, 0.0, 1.0)
        take_up = jax.random.uniform(key, x.shape) < p_up
        return jnp.where(take_up, up, down).astype(dtype)

    return jax.tree_util.tree_unflatten(treedef, [sr(l, k) for l, k in zip(leaves, keys)])


def cast_decompress(tree, dtype=jnp.float32):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


# ---------------------------------------------------------------------------
# top-k sparsification with error feedback
# ---------------------------------------------------------------------------


def init_error_feedback(tree):
    return jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), tree)


def topk_compress(
    tree, k_fraction: float, error: Optional[Any] = None
) -> Tuple[Any, Any]:
    """Keep the top ``k_fraction`` entries by magnitude per tensor; the dropped mass
    accumulates in the ``error`` residual (error feedback a la Stich et al.) and is
    re-added next round. Returns (sparse_tree, new_error)."""
    if error is None:
        error = init_error_feedback(tree)

    def one(x, e):
        xf = x.astype(jnp.float32) + e
        flat = xf.reshape(-1)
        k = max(1, int(flat.size * k_fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(xf) >= thresh
        kept = jnp.where(mask, xf, 0.0)
        return kept.astype(x.dtype), xf - kept

    out = jax.tree_util.tree_map(one, tree, error)
    sparse = jax.tree_util.tree_map(lambda p: p[0], out, is_leaf=lambda n: isinstance(n, tuple))
    new_err = jax.tree_util.tree_map(lambda p: p[1], out, is_leaf=lambda n: isinstance(n, tuple))
    return sparse, new_err


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------


def int8_compress(tree) -> Any:
    """Per-tensor symmetric int8 quantization. Returns a pytree of (q, scale)."""

    def one(x):
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}

    return jax.tree_util.tree_map(one, tree)


def int8_decompress(ctree, like=None) -> Any:
    def one(c):
        return c["q"].astype(jnp.float32) * c["scale"]

    return jax.tree_util.tree_map(one, ctree, is_leaf=lambda n: isinstance(n, dict) and "q" in n)


# ---------------------------------------------------------------------------
# uplink byte accounting
# ---------------------------------------------------------------------------


def uplink_bytes(tree, scheme: str = "float32", k_fraction: float = 0.01) -> float:
    """Bytes a client transmits per round under each scheme (for the comm tables)."""
    n = sum(x.size for x in jax.tree_util.tree_leaves(tree))
    if scheme == "float32":
        return 4.0 * n
    if scheme == "bfloat16":
        return 2.0 * n
    if scheme == "int8":
        return 1.0 * n + 4.0 * len(jax.tree_util.tree_leaves(tree))
    if scheme == "topk":
        return k_fraction * n * (4.0 + 4.0)  # value + index
    raise ValueError(scheme)
