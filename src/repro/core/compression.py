"""Pseudo-gradient compression for the client→server uplink (Algorithm 1 L.26
PostProcess). The paper ships lossless compression only; these are the beyond-paper
lossy options, all with unbiasedness or error-feedback so FedAvg convergence
guarantees carry over:

  - bf16 stochastic-rounding cast            (2x uplink reduction, unbiased)
  - per-tensor int8 quantization             (~4x, scale per tensor)
  - top-k sparsification with error feedback (10-100x, stateful residual per client)

The low-level primitives (``cast_compress`` / ``int8_compress`` / ``topk_compress``)
operate on single pseudo-gradient pytrees. The :class:`Codec` objects wrap them
into the uplink abstraction the federated round consumes (``core/federated.py``):

  - ``encode(delta, residual)`` runs client-side — the *payload* it returns is what
    crosses the wire, and for error-feedback codecs the returned residual is the
    client's own state, keyed by population client id by the caller (sync rounds
    gather/scatter a population store; the async driver owns one row per client).
  - ``decode(payload)`` runs server-side, restoring a float32 params-shaped tree so
    aggregation and the outer optimizer stay codec-agnostic.
  - ``nbytes(params_like)`` is the analytic per-upload byte count (the comm tables);
    ``payload_nbytes(payload)`` measures an actual encoded payload — the two agree
    (tested), which is what makes the logged ``uplink_bytes`` trustworthy.

All codecs compose with DP clipping (clip before compress). The identity codec is
bitwise transparent: a round run through encode→decode with it reproduces the
uncompressed ``federated_round`` exactly, rng and DP-noise lanes included (tested).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# casting (with optional stochastic rounding)
# ---------------------------------------------------------------------------


def cast_compress(tree, dtype=jnp.bfloat16, rng: Optional[jax.Array] = None):
    """Cast to a narrow dtype; with ``rng``, stochastic rounding keeps the cast
    unbiased (E[compress(x)] = x). Stochastic rounding is implemented at the bit
    level — bf16 is the top 16 bits of f32, so adding uniform noise in
    [0, 2^16) to the f32 pattern and truncating rounds to each bf16 neighbor
    with probability exactly proportional to proximity — and therefore only
    supports ``bfloat16``; other dtypes take the deterministic cast."""
    if rng is None:
        return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)
    if dtype != jnp.bfloat16:
        raise ValueError(f"stochastic rounding is bf16-only, got {dtype}")

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(rng, len(leaves))

    def sr(x, key):
        bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
        noise = jax.random.randint(key, x.shape, 0, 1 << 16).astype(jnp.uint32)
        rounded = (bits + noise) & jnp.uint32(0xFFFF0000)
        return jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(dtype)

    return jax.tree_util.tree_unflatten(treedef, [sr(l, k) for l, k in zip(leaves, keys)])


def cast_decompress(tree, dtype=jnp.float32):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


# ---------------------------------------------------------------------------
# top-k sparsification with error feedback
# ---------------------------------------------------------------------------


def init_error_feedback(tree):
    return jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), tree)


def topk_compress(
    tree, k_fraction: float, error: Optional[Any] = None
) -> Tuple[Any, Any]:
    """Keep the top ``k_fraction`` entries by magnitude per tensor; the dropped mass
    accumulates in the ``error`` residual (error feedback a la Stich et al.) and is
    re-added next round. Returns (sparse_tree, new_error).

    The residual is CLIENT state: pass each client its own ``error`` tree and store
    the returned one under the same client id (see :class:`TopKCodec` /
    ``core/federated.init_uplink_residuals``). Calling without ``error`` silently
    restarts feedback from zero — correct only for a client's first-ever upload.

    Exactly ``k = max(1, int(size * k_fraction))`` entries survive per tensor:
    selection is an index scatter from ``lax.top_k`` (ties broken toward the
    lower flat index, top_k's documented order), NOT a ``|x| >= thresh`` mask —
    a threshold mask keeps every tied entry, overshooting k and breaking the
    exact byte accounting ``uplink_bytes`` / ``payload_nbytes`` promise.
    """
    if error is None:
        error = init_error_feedback(tree)

    def one(x, e):
        xf = x.astype(jnp.float32) + e
        flat = xf.reshape(-1)
        k = max(1, int(flat.size * k_fraction))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        kept = jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(xf.shape)
        return kept.astype(x.dtype), xf - kept

    out = jax.tree_util.tree_map(one, tree, error)
    sparse = jax.tree_util.tree_map(lambda p: p[0], out, is_leaf=lambda n: isinstance(n, tuple))
    new_err = jax.tree_util.tree_map(lambda p: p[1], out, is_leaf=lambda n: isinstance(n, tuple))
    return sparse, new_err


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------


def int8_compress(tree) -> Any:
    """Per-tensor symmetric int8 quantization. Returns a pytree of (q, scale)."""

    def one(x):
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}

    return jax.tree_util.tree_map(one, tree)


def int8_decompress(ctree, like=None) -> Any:
    def one(c):
        return c["q"].astype(jnp.float32) * c["scale"]

    return jax.tree_util.tree_map(one, ctree, is_leaf=lambda n: isinstance(n, dict) and "q" in n)


# ---------------------------------------------------------------------------
# uplink byte accounting
# ---------------------------------------------------------------------------

# CLI spelling → canonical scheme name (the ``--uplink`` flag speaks the short form)
SCHEME_ALIASES = {
    "bf16": "bfloat16",
    "identity": "float32",
    "fp32": "float32",
}


def _canon_scheme(scheme: str) -> str:
    return SCHEME_ALIASES.get(scheme, scheme)


def _topk_index_nbytes(n_total: int) -> float:
    """Bytes per sparse index on the wire. Indices address the ONE flat packed
    buffer (the ``kernels/fedcore`` layout — every leaf concatenated into a
    single 1D view), so their dtype is sized to the flat length, not per leaf:
    uint16 up to 64K parameters, uint32 up to 4G, uint64 beyond."""
    if n_total <= 1 << 16:
        return 2.0
    if n_total <= 1 << 32:
        return 4.0
    return 8.0


def uplink_bytes(tree, scheme: str = "float32", k_fraction: float = 0.01) -> float:
    """Bytes a client transmits per upload under each scheme (for the comm tables).

    Exact accounting, matched against real encoded payloads in the tests: int8
    pays one float32 scale per tensor; top-k pays (float32 value + flat-buffer
    index) per kept entry — the index dtype is sized to the TOTAL flat length
    (``_topk_index_nbytes``), with the same per-tensor
    ``k = max(1, int(size * k_fraction))`` kept-entry count that
    ``topk_compress`` keeps (the flat ``FusedTopKCodec`` overrides ``nbytes``
    with its global-budget k).
    """
    scheme = _canon_scheme(scheme)
    leaves = jax.tree_util.tree_leaves(tree)
    n = sum(x.size for x in leaves)
    if scheme == "float32":
        return 4.0 * n
    if scheme == "bfloat16":
        return 2.0 * n
    if scheme == "int8":
        return 1.0 * n + 4.0 * len(leaves)
    if scheme == "topk":
        kept = sum(max(1, int(x.size * k_fraction)) for x in leaves)
        return float(kept) * (4.0 + _topk_index_nbytes(n))
    raise ValueError(scheme)


# ---------------------------------------------------------------------------
# Codec abstraction — what the federated round actually plugs in
# ---------------------------------------------------------------------------


class Codec:
    """An uplink codec: a pure, jittable encode/decode pair over pseudo-gradient
    pytrees, plus byte accounting.

    ``encode(delta, residual=None, rng=None) -> (payload, new_residual)`` and
    ``decode(payload) -> float32 tree``. Stateless codecs ignore/return the
    residual unchanged (``None``); stateful ones (:class:`TopKCodec`) carry the
    error-feedback residual, which is PER-CLIENT state — the caller keys it by
    population client id and must never share one residual between clients.
    ``vmap`` both over a leading client axis for cohort encodes.
    """

    name: str = "float32"
    stateful: bool = False  # encode carries an error-feedback residual
    needs_rng: bool = False  # encode uses randomness (stochastic rounding)

    def init_residual(self, params):
        """Zero residual state shaped like ``params`` (stateful codecs only)."""
        return None

    def encode(self, delta, residual=None, rng: Optional[jax.Array] = None):
        return delta, residual

    def decode(self, payload):
        return payload

    def nbytes(self, params_like) -> float:
        """Analytic bytes per upload for a ``params_like``-shaped delta."""
        return uplink_bytes(params_like, self.name)

    def payload_nbytes(self, payload) -> float:
        """Actual bytes of one encoded payload (host-side; agrees with nbytes)."""
        import numpy as np

        return float(
            sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(payload))
        )

    def __repr__(self) -> str:  # config echo in logs / manifests
        return f"{type(self).__name__}({self.name})"


class IdentityCodec(Codec):
    """Uncompressed float32 uplink. encode/decode are exact identities, so a round
    run through this codec is bitwise the uncompressed ``federated_round`` —
    the equivalence anchor every other codec is measured against."""

    name = "float32"


class Bf16Codec(Codec):
    """bfloat16 cast with stochastic rounding (unbiased: E[payload] = delta).
    Without an rng key the cast degrades to deterministic round-to-nearest,
    matching the legacy ``pseudo_grad_dtype='bfloat16'`` path bitwise."""

    name = "bfloat16"
    needs_rng = True

    def encode(self, delta, residual=None, rng: Optional[jax.Array] = None):
        return cast_compress(delta, jnp.bfloat16, rng=rng), residual

    def decode(self, payload):
        return cast_decompress(payload, jnp.float32)


class Int8Codec(Codec):
    """Per-tensor symmetric int8: payload leaves are {'q': int8, 'scale': f32}."""

    name = "int8"

    def encode(self, delta, residual=None, rng: Optional[jax.Array] = None):
        return int8_compress(delta), residual

    def decode(self, payload):
        return int8_decompress(payload)


@dataclass(frozen=True)
class TopKCodec(Codec):
    """Top-k magnitude sparsification with per-client error feedback: dropped mass
    lands in the client's residual and is re-injected on its next upload. The
    payload is the dense-with-zeros sparse tree (the wire format would ship
    (index, value) pairs — ``nbytes`` accounts 8 bytes per kept entry)."""

    k_fraction: float = 0.05

    name = "topk"
    stateful = True
    # sparse indices address the flat packed buffer; dtype sized to its length
    _index_nbytes = staticmethod(_topk_index_nbytes)

    def __post_init__(self):
        if not 0.0 < self.k_fraction <= 1.0:
            raise ValueError(f"k_fraction must be in (0, 1], got {self.k_fraction}")

    def init_residual(self, params):
        return init_error_feedback(params)

    def encode(self, delta, residual=None, rng: Optional[jax.Array] = None):
        return topk_compress(delta, self.k_fraction, residual)

    def decode(self, payload):
        return payload  # already dense float32-compatible

    def nbytes(self, params_like) -> float:
        return uplink_bytes(params_like, "topk", self.k_fraction)

    def payload_nbytes(self, payload) -> float:
        # Exactly k entries per leaf cross the wire — count them analytically,
        # not by scanning for nonzeros: a kept entry whose VALUE is 0.0 (zero
        # delta + zero residual) still ships its (index, value) pair, so a
        # nonzero scan under-bills all-zero and tie-heavy payloads.
        leaves = jax.tree_util.tree_leaves(payload)
        idx = self._index_nbytes(sum(x.size for x in leaves))
        kept = sum(max(1, int(x.size * self.k_fraction)) for x in leaves)
        return float(kept) * (4.0 + idx)  # float32 value + flat-buffer index


UPLINK_SCHEMES = ("float32", "bf16", "int8", "topk")


def get_codec(scheme: str, topk_fraction: float = 0.05, fused: bool = False) -> Codec:
    """Factory keyed by the ``--uplink`` CLI spelling (aliases accepted).

    ``fused=True`` (the ``--fused-server`` path) returns the flat-buffer Pallas
    codecs from ``kernels/fedcore`` — drop-in :class:`Codec` subclasses, so
    every call site (``run_clients`` / ``apply_aggregate`` / ``admit_deltas``)
    is untouched. The identity codec has no fused variant: it stays the exact
    no-op that anchors every bitwise-equivalence test."""
    canon = _canon_scheme(scheme)
    if canon == "float32":
        return IdentityCodec()
    if fused:
        # deferred: kernels/fedcore imports this module for the base classes
        from repro.kernels.fedcore import (
            FusedBf16Codec,
            FusedInt8Codec,
            FusedTopKCodec,
        )

        if canon == "bfloat16":
            return FusedBf16Codec()
        if canon == "int8":
            return FusedInt8Codec()
        if canon == "topk":
            return FusedTopKCodec(k_fraction=topk_fraction)
    if canon == "bfloat16":
        return Bf16Codec()
    if canon == "int8":
        return Int8Codec()
    if canon == "topk":
        return TopKCodec(k_fraction=topk_fraction)
    raise ValueError(f"unknown uplink scheme {scheme!r}; choose from {UPLINK_SCHEMES}")
