"""Checkpointing for the Photon Aggregator and LLM Nodes (§4.1).

Server state (global params + outer optimizer + round bookkeeping) and per-client state
(data cursors; inner optimizer when stateful) are stored as .npz pytree blobs + a JSON
manifest, replacing the paper's MinIO/S3 object store with the local filesystem while
keeping the same resume semantics: `latest_round()` + `load_server()` give automatic
federated training resumption from the most recent round (§6.2).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf) if not hasattr(leaf, "dtype") or leaf.dtype != jax.numpy.bfloat16 \
            else np.asarray(leaf.astype(jax.numpy.float32))
        flat[jax.tree_util.keystr(path)] = arr
    return flat


def save_pytree(path: str, tree) -> None:
    flat = _flatten_with_paths(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)


def load_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in leaves_with_path:
        key = jax.tree_util.keystr(p)
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(
            jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype", arr.dtype))
        )
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Round-granular checkpoint store with a JSON manifest per round."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def _round_dir(self, rnd: int) -> str:
        return os.path.join(self.dir, f"round_{rnd:06d}")

    # --- server ---------------------------------------------------------
    def save_server(self, rnd: int, state, extra: Optional[Dict] = None) -> str:
        d = self._round_dir(rnd)
        os.makedirs(d, exist_ok=True)
        save_pytree(os.path.join(d, "server.npz"), state)
        manifest = {"round": rnd, "extra": extra or {}}
        with open(os.path.join(d, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        self._gc()
        return d

    def save_client(self, rnd: int, client_id: int, data_state: Dict) -> None:
        """Client-private state: data cursor etc. (kept outside server control, §4.1)."""
        d = self._round_dir(rnd)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"client_{client_id:04d}.json"), "w") as f:
            json.dump(data_state, f)

    def latest_round(self) -> Optional[int]:
        rounds = [
            int(n.split("_")[1])
            for n in os.listdir(self.dir)
            if n.startswith("round_")
            and os.path.exists(os.path.join(self.dir, n, "manifest.json"))
        ]
        return max(rounds) if rounds else None

    def load_manifest(self, rnd: int) -> Dict:
        """Read a round's JSON manifest WITHOUT touching the state blob.

        Resume paths need this ordering: the manifest carries the aggregator's
        dispatch machine (``extra['aggregator']`` — schema version, cursor,
        in-flight slot table) and the writing run's args, which together
        determine the shape of the ``like`` template that ``load_server``
        validates the arrays against. Host-side floats (completion times, the
        simulated clock) live here rather than in the npz precisely because
        JSON float reprs round-trip float64 exactly while the pytree loader
        casts to the template dtype.
        """
        with open(os.path.join(self._round_dir(rnd), "manifest.json")) as f:
            return json.load(f)

    def load_server(self, rnd: int, like) -> Tuple[Any, Dict]:
        d = self._round_dir(rnd)
        state = load_pytree(os.path.join(d, "server.npz"), like)
        manifest = self.load_manifest(rnd)
        return state, manifest

    def load_client(self, rnd: int, client_id: int) -> Dict:
        with open(os.path.join(self._round_dir(rnd), f"client_{client_id:04d}.json")) as f:
            return json.load(f)

    def _gc(self) -> None:
        rounds = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir) if n.startswith("round_")
        )
        for rnd in rounds[: -self.keep_last]:
            d = self._round_dir(rnd)
            for fn in os.listdir(d):
                os.remove(os.path.join(d, fn))
            os.rmdir(d)
