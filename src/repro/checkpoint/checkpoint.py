"""Checkpointing for the Photon Aggregator and LLM Nodes (§4.1).

Server state (global params + outer optimizer + round bookkeeping) and per-client state
(data cursors; inner optimizer when stateful) are stored as .npz pytree blobs + a JSON
manifest, replacing the paper's MinIO/S3 object store with the local filesystem while
keeping the same resume semantics: `latest_round()` + `load_server()` give automatic
federated training resumption from the most recent round (§6.2).

Atomicity guarantee: every blob (``server.npz``, ``manifest.json``, client JSON)
is written to a same-directory temp file, fsynced, then ``os.replace``d into
place, and the manifest is written strictly AFTER the state blob. A crash at any
instant therefore leaves each round directory in one of two states: *complete*
(parseable manifest + state blob, the manifest rename was the commit point) or
*partial* (no readable manifest). ``latest_round()`` only ever selects complete
rounds, and ``_gc`` retains the last ``keep_last`` COMPLETE rounds before
pruning partial debris — so resume after ``kill -9`` mid-save always lands on
the newest round that finished committing.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf) if not hasattr(leaf, "dtype") or leaf.dtype != jax.numpy.bfloat16 \
            else np.asarray(leaf.astype(jax.numpy.float32))
        flat[jax.tree_util.keystr(path)] = arr
    return flat


def _atomic_write(path: str, writer) -> None:
    """Write via same-directory temp file + fsync + ``os.replace`` so the final
    path either holds the complete new content or is untouched — never a
    truncated half-write (the crash mode the resume tests kill-inject)."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        writer(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _atomic_write_json(path: str, obj, **dump_kw) -> None:
    _atomic_write(path, lambda f: f.write(json.dumps(obj, **dump_kw).encode("utf-8")))


def save_pytree(path: str, tree) -> None:
    flat = _flatten_with_paths(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    if not path.endswith(".npz"):
        path = path + ".npz"  # mirror np.savez's implicit suffix for the rename
    _atomic_write(path, lambda f: np.savez(f, **flat))


def load_pytree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in leaves_with_path:
        key = jax.tree_util.keystr(p)
        arr = data[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        leaves.append(
            jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype", arr.dtype))
        )
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Round-granular checkpoint store with a JSON manifest per round."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def _round_dir(self, rnd: int) -> str:
        return os.path.join(self.dir, f"round_{rnd:06d}")

    def _is_complete(self, rnd: int) -> bool:
        """A round is complete iff its state blob exists AND its manifest parses.

        ``os.replace`` makes a truncated manifest impossible on POSIX, but the
        check also guards pre-fix checkpoints and exotic filesystems — resume
        must never select a round it cannot actually load.
        """
        d = self._round_dir(rnd)
        if not os.path.exists(os.path.join(d, "server.npz")):
            return False
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                json.load(f)
        except (OSError, json.JSONDecodeError):
            return False
        return True

    def _round_numbers(self):
        out = []
        for n in os.listdir(self.dir):
            if not n.startswith("round_"):
                continue
            try:
                out.append(int(n.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    # --- server ---------------------------------------------------------
    def save_server(self, rnd: int, state, extra: Optional[Dict] = None) -> str:
        d = self._round_dir(rnd)
        os.makedirs(d, exist_ok=True)
        # state blob first, manifest last: the manifest rename is the commit
        # point that flips the round from partial to complete (module docstring)
        save_pytree(os.path.join(d, "server.npz"), state)
        manifest = {"round": rnd, "extra": extra or {}}
        _atomic_write_json(os.path.join(d, "manifest.json"), manifest, indent=2)
        self._gc()
        return d

    def save_client(self, rnd: int, client_id: int, data_state: Dict) -> None:
        """Client-private state: data cursor etc. (kept outside server control, §4.1)."""
        d = self._round_dir(rnd)
        os.makedirs(d, exist_ok=True)
        _atomic_write_json(os.path.join(d, f"client_{client_id:04d}.json"), data_state)

    def latest_round(self) -> Optional[int]:
        """Newest COMPLETE round — partial (crash-interrupted) rounds are
        skipped, so resume always gets a loadable checkpoint."""
        rounds = [r for r in self._round_numbers() if self._is_complete(r)]
        return max(rounds) if rounds else None

    def load_manifest(self, rnd: int) -> Dict:
        """Read a round's JSON manifest WITHOUT touching the state blob.

        Resume paths need this ordering: the manifest carries the aggregator's
        dispatch machine (``extra['aggregator']`` — schema version, cursor,
        in-flight slot table) and the writing run's args, which together
        determine the shape of the ``like`` template that ``load_server``
        validates the arrays against. Host-side floats (completion times, the
        simulated clock) live here rather than in the npz precisely because
        JSON float reprs round-trip float64 exactly while the pytree loader
        casts to the template dtype.
        """
        with open(os.path.join(self._round_dir(rnd), "manifest.json")) as f:
            return json.load(f)

    def load_server(self, rnd: int, like) -> Tuple[Any, Dict]:
        d = self._round_dir(rnd)
        state = load_pytree(os.path.join(d, "server.npz"), like)
        manifest = self.load_manifest(rnd)
        return state, manifest

    def load_client(self, rnd: int, client_id: int) -> Dict:
        with open(os.path.join(self._round_dir(rnd), f"client_{client_id:04d}.json")) as f:
            return json.load(f)

    def _gc(self) -> None:
        """Retain the last ``keep_last`` COMPLETE rounds, then prune debris.

        Partial rounds never count toward the retention quota (a crash loop
        that kept leaving half-written dirs used to rotate every complete
        checkpoint out of existence). Partial dirs are removed only when they
        are older than the newest complete round — a partial dir NEWER than
        every complete round may be a save in flight, so it is left alone.
        """
        rounds = self._round_numbers()
        complete = [r for r in rounds if self._is_complete(r)]
        if not complete:
            return  # nothing loadable yet: deleting anything can only lose data
        doomed = set(complete[: -self.keep_last])
        newest_complete = complete[-1]
        doomed.update(
            r for r in rounds if r not in complete and r < newest_complete
        )
        for rnd in doomed:
            d = self._round_dir(rnd)
            for fn in os.listdir(d):
                os.remove(os.path.join(d, fn))
            os.rmdir(d)
