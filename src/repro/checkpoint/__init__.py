from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    load_pytree,
    save_pytree,
)

# The canonical aggregator checkpoint pairs a state pytree (server.npz) with a
# JSON-able dispatch manifest (manifest.json 'extra.aggregator') — see
# repro.core.aggregator.Aggregator.checkpoint / AGGREGATOR_SCHEMA_VERSION.
