"""Mixture-of-Experts FFN: shared + routed experts, top-k routing with capacity,
scatter-based dispatch (memory-safe for fine-grained 64-expert configs), load-balance
auxiliary loss. Experts shard over the 'experts' logical axis (expert parallelism).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDesc, activation_fn


def dense_ffn_desc(cfg, d_ff: int, n_copies: int = 1) -> dict:
    d = cfg.d_model
    dff = d_ff * n_copies
    if cfg.activation == "silu":  # SwiGLU
        return {
            "w_in": ParamDesc((d, dff), (None, "ffn"), "normal"),
            "w_gate": ParamDesc((d, dff), (None, "ffn"), "normal"),
            "w_out": ParamDesc((dff, d), ("ffn", None), "normal", 0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
        }
    return {
        "w_in": ParamDesc((d, dff), (None, "ffn"), "normal"),
        "w_out": ParamDesc((dff, d), ("ffn", None), "normal", 0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


def dense_ffn(cfg, p: dict, x: jax.Array) -> jax.Array:
    act = activation_fn(cfg.activation)
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(x.dtype))


def moe_ffn_desc(cfg) -> dict:
    d = cfg.d_model
    e = cfg.n_experts
    dff = cfg.moe_d_ff or cfg.d_ff
    p = {
        "router": ParamDesc((d, e), (None, None), "normal"),
        "w_in": ParamDesc((e, d, dff), ("experts", None, None), "normal"),
        "w_gate": ParamDesc((e, d, dff), ("experts", None, None), "normal"),
        "w_out": ParamDesc((e, dff, d), ("experts", None, None), "normal", 0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }
    if cfg.n_shared_experts:
        p["shared"] = dense_ffn_desc(cfg, dff, cfg.n_shared_experts)
    return p


def moe_ffn(
    cfg, p: dict, x: jax.Array, capacity_factor: float = None
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss scalar)."""
    from repro.models.common import shard_hint

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    xt = shard_hint(x.reshape(T, D), "model", None)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)  # renorm (deepseek)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    onehot_k = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (T, K, E)
    tokens_per_expert = onehot_k.sum((0, 1)) / (T * K)  # f_e
    router_prob = probs.mean(0)  # P_e
    aux = E * jnp.sum(tokens_per_expert * router_prob)

    # Capacity-based dispatch via cumsum position-in-expert + scatter.
    if capacity_factor is None:
        capacity_factor = getattr(cfg, "moe_capacity_factor", 1.25)
    capacity = max(1, int(T * K * capacity_factor / E))
    flat_idx = expert_idx.reshape(T * K)  # route slots, ordered by token then k
    flat_gate = gate_vals.reshape(T * K)
    oh = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)  # (T*K, E)
    pos_in_expert = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1  # (T*K,)
    keep = pos_in_expert < capacity
    safe_pos = jnp.where(keep, pos_in_expert, 0)

    token_of_slot = jnp.repeat(jnp.arange(T), K)
    contrib = jnp.where(keep, flat_gate, 0.0)

    # Scatter tokens into (E, capacity, D) expert buffers. Slot arrays shard over the
    # within-client TP ('model') axis and expert buffers shard over experts ('model'):
    # the slot->expert scatter and expert->slot gather become the canonical MoE
    # all-to-all instead of replicating token-slot tensors.
    buf = jnp.zeros((E, capacity, D), x.dtype)
    src = shard_hint(
        xt[token_of_slot] * keep[:, None].astype(x.dtype), "model", None
    )
    buf = shard_hint(buf.at[flat_idx, safe_pos].add(src), "model", None, None)

    act = activation_fn(cfg.activation)

    @jax.checkpoint
    def expert_ffn(buf_, w_in, w_gate, w_out):
        # checkpointed: the (E, cap, d_ff) hiddens are recomputed in the backward
        # pass instead of living as residuals — they are the widest buffers of
        # fine-grained MoE layers.
        h_in = jnp.einsum("ecd,edf->ecf", buf_, w_in)
        h_gate = jnp.einsum("ecd,edf->ecf", buf_, w_gate)
        h = shard_hint(act(h_gate) * h_in, "model", None, None)
        return jnp.einsum("ecf,efd->ecd", h, w_out)

    out_buf = shard_hint(
        expert_ffn(
            buf,
            p["w_in"].astype(x.dtype),
            p["w_gate"].astype(x.dtype),
            p["w_out"].astype(x.dtype),
        ),
        "model", None, None,
    )

    # Combine: gather each slot's expert output, weight by gate, sum over K.
    slot_out = shard_hint(
        out_buf[flat_idx, safe_pos] * contrib[:, None].astype(x.dtype), "model", None
    )  # (T*K, D)
    yt = slot_out.reshape(T, K, D).sum(1)

    if cfg.n_shared_experts:
        yt = yt + dense_ffn(cfg, p["shared"], xt)

    return yt.reshape(B, S, D), aux.astype(jnp.float32)
