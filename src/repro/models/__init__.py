from repro.models.model import Model, build_model, cross_entropy  # noqa: F401
