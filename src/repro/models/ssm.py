"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: intra-chunk "attention-like" quadratic term + inter-chunk linear
state recurrence, giving O(S·chunk) work and an O(1)-memory decode step. The chunk scan
is the TPU Pallas kernel target (repro.kernels.ssd_scan); this module holds the pure-jnp
implementation used as oracle and as the lowering path on the CPU host.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDesc, rmsnorm


def ssm_desc(cfg) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    g, ds, nh = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads
    conv_dim = di + 2 * g * ds
    return {
        "in_proj": ParamDesc((d, 2 * di + 2 * g * ds + nh), (None, "ffn"), "normal"),
        "conv_w": ParamDesc((cfg.ssm_conv_width, conv_dim), (None, "ffn"), "normal", 0.2),
        "conv_b": ParamDesc((conv_dim,), ("ffn",), "zeros"),
        "A_log": ParamDesc((nh,), ("ssm_heads",), "ssm_a"),
        "dt_bias": ParamDesc((nh,), ("ssm_heads",), "ssm_dt"),
        "D_skip": ParamDesc((nh,), ("ssm_heads",), "ones"),
        "norm_scale": ParamDesc((di,), ("ffn",), "ones"),
        "out_proj": ParamDesc((di, d), ("ffn", None), "normal", 0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


# ---------------------------------------------------------------------------
# SSD chunk scan (reference / oracle)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,  # (B, S, nh, hd)
    dt: jax.Array,  # (B, S, nh) — post-softplus
    A: jax.Array,  # (nh,) negative
    Bm: jax.Array,  # (B, S, G, ds)
    Cm: jax.Array,  # (B, S, G, ds)
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (B, nh, hd, ds)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,nh,hd), final_state (B,nh,hd,ds))."""
    B, S, nh, hd = x.shape
    G, ds = Bm.shape[2], Bm.shape[3]
    if S % chunk:  # pad with dt=0 (identity dynamics, zero input contribution)
        pad = chunk - S % chunk
        y, final_state = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0))),
            chunk,
            initial_state,
        )
        return y[:, :S], final_state
    nc = S // chunk
    rep = nh // G

    xc = x.reshape(B, nc, chunk, nh, hd)
    dtc = dt.reshape(B, nc, chunk, nh).astype(jnp.float32)
    Bc = jnp.repeat(Bm.reshape(B, nc, chunk, G, ds), rep, axis=3)  # (B,nc,l,nh,ds)
    Cc = jnp.repeat(Cm.reshape(B, nc, chunk, G, ds), rep, axis=3)

    dA = dtc * A.astype(jnp.float32)  # (B,nc,l,nh) negative
    dA_cum = jnp.cumsum(dA, axis=2)  # inclusive cumulative within chunk
    dA_total = dA_cum[:, :, -1]  # (B,nc,nh)

    # ---- intra-chunk (quadratic within chunk, causal, decay-weighted) ----
    # L[i,j] = exp(dA_cum[i] - dA_cum[j]) for j <= i  (decay from j+1..i)
    decay = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]  # (B,nc,i,j,nh)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(decay), 0.0)
    scores = jnp.einsum("bclhn,bcshn->bclsh", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    M = scores * L  # (B,nc,i,j,nh)
    dx = xc.astype(jnp.float32) * dtc[..., None]  # dt-weighted inputs
    y_intra = jnp.einsum("bclsh,bcshd->bclhd", M, dx)

    # ---- chunk states: S_c = sum_j exp(dA_total - dA_cum[j]) B_j (dt_j x_j)^T ----
    state_decay = jnp.exp(dA_total[:, :, None, :] - dA_cum)  # (B,nc,l,nh)
    states = jnp.einsum(
        "bclhn,bclhd,bclh->bchdn", Bc.astype(jnp.float32), dx, state_decay
    )  # (B,nc,nh,hd,ds)

    # ---- inter-chunk recurrence over chunks ----
    chunk_decay = jnp.exp(dA_total)  # (B,nc,nh)
    if initial_state is not None:
        init = initial_state.astype(jnp.float32)
    else:
        # inherit x's (possibly batch-sharded) layout — a bare jnp.zeros would start
        # the scan carry replicated and drag the whole recurrence with it
        init = jnp.zeros_like(
            jnp.broadcast_to(x[:, 0, :, :, None], (B, nh, hd, ds)), dtype=jnp.float32
        )

    def step(carry, inp):
        st, dc = inp  # (B,nh,hd,ds), (B,nh)
        new = carry * dc[:, :, None, None] + st
        return new, carry  # emit state *before* this chunk

    states_t = jnp.moveaxis(states, 1, 0)  # (nc,B,nh,hd,ds)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,B,nh)
    final_state, prev_states = jax.lax.scan(step, init, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,nh,hd,ds)

    # ---- inter-chunk contribution: y_inter[i] = exp(dA_cum[i]) C_i · state_prev ----
    in_decay = jnp.exp(dA_cum)  # (B,nc,l,nh)
    y_inter = jnp.einsum(
        "bclhn,bchdn,bclh->bclhd", Cc.astype(jnp.float32), prev_states, in_decay
    )

    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    return y.astype(x.dtype), final_state


def ssd_recurrent_step(
    x: jax.Array,  # (B, nh, hd)
    dt: jax.Array,  # (B, nh)
    A: jax.Array,  # (nh,)
    Bm: jax.Array,  # (B, G, ds)
    Cm: jax.Array,  # (B, G, ds)
    state: jax.Array,  # (B, nh, hd, ds) fp32
) -> Tuple[jax.Array, jax.Array]:
    """Single-token decode update. Returns (y (B,nh,hd), new_state)."""
    B, nh, hd = x.shape
    G, ds = Bm.shape[1], Bm.shape[2]
    rep = nh // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,nh,ds)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))  # (B,nh)
    dx = x.astype(jnp.float32) * dtf[..., None]  # (B,nh,hd)
    new_state = state * dA[..., None, None] + jnp.einsum("bhd,bhn->bhdn", dx, Bh)
    y = jnp.einsum("bhdn,bhn->bhd", new_state, Ch)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------


def causal_conv1d(
    xbc: jax.Array,  # (B, S, C)
    w: jax.Array,  # (W, C)
    b: jax.Array,  # (C,)
    conv_state: Optional[jax.Array] = None,  # (B, W-1, C) history
) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv; returns (y, new_conv_state = last W-1 inputs)."""
    W = w.shape[0]
    if conv_state is None:
        hist = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        hist = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([hist, xbc], axis=1)  # (B, S+W-1, C)
    y = sum(xp[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype) for i in range(W))
    y = y + b.astype(xbc.dtype)
    new_state = xp[:, -(W - 1) :] if W > 1 else jnp.zeros_like(hist)
    return y, new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------


def ssm_block(
    cfg,
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    cache: Optional[dict] = None,  # {'conv': (B,W-1,conv_dim), 'ssd': (B,nh,hd,ds)}
    decode: bool = False,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    di, g, ds, nh, hd = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z, xBC, dt_raw = jnp.split(zxbcdt, [di, di + di + 2 * g * ds], axis=-1)

    conv_state = cache.get("conv") if cache else None
    xBC, new_conv_state = causal_conv1d(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)

    x_ssm, Bm, Cm = jnp.split(xBC, [di, di + g * ds], axis=-1)
    x_ssm = x_ssm.reshape(B, S, nh, hd)
    Bm = Bm.reshape(B, S, g, ds)
    Cm = Cm.reshape(B, S, g, ds)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if decode:
        assert S == 1
        ssd_state = cache["ssd"] if cache else jnp.zeros((B, nh, hd, ds), jnp.float32)
        y1, new_state = ssd_recurrent_step(
            x_ssm[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], ssd_state
        )
        y = y1[:, None]
    else:
        init = cache.get("ssd") if cache else None
        if use_pallas:
            from repro.kernels.ssd_scan import ops as ssd_ops

            y, new_state = ssd_ops.ssd(x_ssm, dt, A, Bm, Cm, cfg.ssm_chunk, init)
        else:
            y, new_state = ssd_chunked(x_ssm, dt, A, Bm, Cm, cfg.ssm_chunk, init)

    y = y + x_ssm * p["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))

    new_cache = None
    if cache is not None or decode:
        new_cache = {"conv": new_conv_state, "ssd": new_state}
    return out, new_cache


def empty_ssm_cache(cfg, batch: int) -> dict:
    di, g, ds, nh, hd = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * g * ds
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.bfloat16),
        "ssd": jnp.zeros((batch, nh, hd, ds), jnp.float32),
    }
