"""Public model API: a lightweight functional facade over the transformer engine.

    model = Model(cfg)
    params = model.init(rng)
    logits, aux, _ = model.forward(params, batch)
    loss, metrics = model.loss(params, batch)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.common import init_params, param_axes, param_shapes


def chunked_cross_entropy(
    cfg,
    params,
    h: jax.Array,  # (B, S, D) pre-head hidden states
    labels: jax.Array,  # (B, S) int32; -1 = ignore
    z_loss: float = 0.0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """LM-head + softmax-CE fused over sequence chunks: the (B, chunk, V) logits block
    is the only vocab-sized temp ever materialized (~1 GB cap), instead of (B, S, V).
    The backward pass recomputes per-chunk logits (checkpointed scan)."""
    from repro.models.transformer import project_logits

    B, S, D = h.shape
    V = cfg.vocab_size
    # chunk size: largest power-of-two divisor of S with B*chunk*V*4B <= ~1 GB
    budget = max(1, (1 << 30) // max(1, B * V * 4))
    chunk = 1
    while chunk * 2 <= min(budget, 512) and S % (chunk * 2) == 0:
        chunk *= 2
    if S % chunk:
        chunk = 1
    n = S // chunk

    hc = jnp.moveaxis(h.reshape(B, n, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    def body(carry, inp):
        nll_sum, zsq_sum, acc_sum, n_valid = carry
        h_b, lab = inp
        logits = project_logits(cfg, params, h_b).astype(jnp.float32)  # (B, chunk, V)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        valid = lab >= 0
        safe = jnp.where(valid, lab, 0)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, lse - ll, 0.0)
        zsq = jnp.where(valid, jnp.square(lse), 0.0)
        acc = jnp.where(valid, jnp.argmax(logits, -1) == safe, False)
        return (
            nll_sum + nll.sum(),
            zsq_sum + zsq.sum(),
            acc_sum + acc.sum().astype(jnp.float32),
            n_valid + valid.sum(),
        ), None

    body = jax.checkpoint(body, prevent_cse=False)
    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
    (nll_sum, zsq_sum, acc_sum, n_valid), _ = jax.lax.scan(body, init, (hc, lc))

    n_valid_f = jnp.maximum(n_valid, 1).astype(jnp.float32)
    ce = nll_sum / n_valid_f
    metrics = {"ce": ce, "n_tokens": n_valid_f, "accuracy": acc_sum / n_valid_f}
    loss = ce
    if z_loss:
        zl = z_loss * zsq_sum / n_valid_f
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics


def cross_entropy(
    logits: jax.Array,  # (B, S, V)
    labels: jax.Array,  # (B, S) int32; -1 = ignore
    z_loss: float = 0.0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    valid = labels >= 0
    safe_labels = jnp.where(valid, labels, 0)
    label_logits = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logits
    n_valid = jnp.maximum(valid.sum(), 1)
    ce = jnp.where(valid, nll, 0.0).sum() / n_valid
    metrics = {"ce": ce, "n_tokens": n_valid.astype(jnp.float32)}
    loss = ce
    if z_loss:
        zl = z_loss * jnp.where(valid, jnp.square(lse), 0.0).sum() / n_valid
        loss = loss + zl
        metrics["z_loss"] = zl
    acc = jnp.where(valid, jnp.argmax(logits, -1) == safe_labels, False).sum() / n_valid
    metrics["accuracy"] = acc.astype(jnp.float32)
    return loss, metrics


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._desc = transformer.model_desc(cfg)

    # -- parameters -----------------------------------------------------
    def desc(self):
        return self._desc

    def init(self, rng: jax.Array, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return init_params(rng, self._desc, dtype)

    def axes(self):
        return param_axes(self._desc)

    def shapes(self):
        return param_shapes(self._desc)

    def abstract_params(self, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        from repro.models.common import is_desc

        return jax.tree_util.tree_map(
            lambda d: jax.ShapeDtypeStruct(d.shape, dtype), self._desc, is_leaf=is_desc
        )

    # -- forward / loss ---------------------------------------------------
    def forward(
        self,
        params,
        batch: Dict[str, jax.Array],
        *,
        mode: str = "train",
        cache=None,
        cache_index=None,
        remat: bool = False,
        use_pallas: bool = False,
    ):
        return transformer.forward(
            self.cfg,
            params,
            batch["tokens"],
            audio_embed=batch.get("audio_embed"),
            mode=mode,
            cache=cache,
            cache_index=cache_index,
            remat=remat,
            use_pallas=use_pallas,
        )

    def loss(
        self, params, batch: Dict[str, jax.Array], *, remat: bool = False,
        use_pallas: bool = False,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Next-token LM loss. batch['tokens'] (B,S); optional batch['loss_mask']."""
        tokens = batch["tokens"]
        h, aux, _ = transformer.forward(
            self.cfg,
            params,
            tokens,
            audio_embed=batch.get("audio_embed"),
            mode="train",
            remat=remat,
            use_pallas=use_pallas,
            logits_mode="hidden",
        )
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((tokens.shape[0], 1), -1, tokens.dtype)], axis=1
        )
        if "loss_mask" in batch:
            labels = jnp.where(batch["loss_mask"] > 0, labels, -1)
        loss, metrics = chunked_cross_entropy(self.cfg, params, h, labels, self.cfg.z_loss)
        if self.cfg.is_moe:
            loss = loss + self.cfg.router_aux_coef * aux
            metrics["moe_aux"] = aux
        metrics["loss"] = loss
        return loss, metrics

    # -- serving ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return transformer.init_cache(self.cfg, batch, max_len, dtype)

    def prefill(self, params, batch, *, use_pallas: bool = False):
        """Fills the cache; returns next-token logits (last position only — the full
        (B, S, V) logits tensor is never materialized)."""
        logits, _, cache = transformer.forward(
            self.cfg,
            params,
            batch["tokens"],
            audio_embed=batch.get("audio_embed"),
            mode="prefill",
            use_pallas=use_pallas,
            logits_mode="last",
        )
        return logits, cache

    def decode_step(self, params, cache, tokens, cache_index, *, use_pallas: bool = False):
        """tokens: (B, 1) — one new token per sequence; cache_index: scalar position."""
        logits, _, new_cache = self.forward(
            params,
            {"tokens": tokens},
            mode="decode",
            cache=cache,
            cache_index=cache_index,
            use_pallas=use_pallas,
        )
        return logits, new_cache


def build_model(name_or_cfg) -> Model:
    if isinstance(name_or_cfg, str):
        from repro.configs import get_config

        return Model(get_config(name_or_cfg))
    return Model(name_or_cfg)
