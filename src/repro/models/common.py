"""Shared building blocks: parameter description (single source of truth for init AND
sharding), norms, activations, positional encodings.

Every parameter is declared once as a ``ParamDesc(shape, axes, init)``; `init_params`
materializes values and `param_axes` extracts the logical-axis tree, so the two can
never structurally diverge (tested in tests/test_sharding.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter description
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDesc:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names per dim (None = replicated)
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed' | 'ssm_a' | 'ssm_dt'
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _materialize(desc: ParamDesc, key: jax.Array, dtype) -> jax.Array:
    if desc.init == "zeros":
        return jnp.zeros(desc.shape, dtype)
    if desc.init == "ones":
        return jnp.ones(desc.shape, dtype)
    if desc.init in ("normal", "embed"):
        return (desc.scale * jax.random.normal(key, desc.shape)).astype(dtype)
    if desc.init == "ssm_a":  # A_log ~ log(Uniform[1, 16])
        return jnp.log(jax.random.uniform(key, desc.shape, minval=1.0, maxval=16.0)).astype(dtype)
    if desc.init == "ssm_dt":  # dt bias: softplus^-1 of Uniform[1e-3, 1e-1]
        dt = jax.random.uniform(key, desc.shape, minval=1e-3, maxval=1e-1)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    raise ValueError(desc.init)


def is_desc(x) -> bool:
    return isinstance(x, ParamDesc)


def init_params(rng: jax.Array, desc_tree, dtype=jnp.float32):
    """Materialize a ParamDesc tree into parameter arrays (deterministic per path)."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        desc_tree, is_leaf=is_desc
    )[0]
    out = {}
    flat = []
    for path, desc in leaves_with_path:
        path_str = jax.tree_util.keystr(path)
        key = jax.random.fold_in(rng, zlib_hash(path_str))
        flat.append(_materialize(desc, key, dtype))
    treedef = jax.tree_util.tree_structure(desc_tree, is_leaf=is_desc)
    return jax.tree_util.tree_unflatten(treedef, flat)


def param_axes(desc_tree):
    """Extract the logical-axes tree (same structure as params)."""
    return jax.tree_util.tree_map(lambda d: d.axes, desc_tree, is_leaf=is_desc)


def param_shapes(desc_tree):
    return jax.tree_util.tree_map(lambda d: d.shape, desc_tree, is_leaf=is_desc)


def zlib_hash(s: str) -> int:
    import zlib

    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def stack_descs(desc_tree, n: int, stack_axis_name: Optional[str] = None):
    """Prepend a stacking dim of size n to every desc (for lax.scan layer stacks)."""
    return jax.tree_util.tree_map(
        lambda d: dataclasses.replace(
            d, shape=(n,) + d.shape, axes=(stack_axis_name,) + d.axes
        ),
        desc_tree,
        is_leaf=is_desc,
    )


# ---------------------------------------------------------------------------
# Ambient-mesh sharding hints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------


def shard_hint(x: jax.Array, *spec_entries) -> jax.Array:
    """Apply a sharding constraint if running under a mesh context whose axes cover
    the spec; otherwise identity. Lets mesh-agnostic model code pin the sharding of
    internal buffers (e.g. MoE dispatch buffers) without plumbing the mesh through."""
    try:
        from jax._src import mesh as mesh_lib
        from jax.sharding import PartitionSpec

        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty:
            return x
        needed = set()
        for e in spec_entries:
            if e is None:
                continue
            needed.update(e if isinstance(e, tuple) else (e,))
        if not needed.issubset(set(m.axis_names)):
            return x
        # divisibility guard
        for dim, e in zip(x.shape, spec_entries):
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            n = 1
            for a in axes:
                n *= m.shape[a]
            if dim % n:
                return x
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec_entries))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_desc(cfg, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": ParamDesc((d,), (None,), "ones")}
    return {"scale": ParamDesc((d,), (None,), "ones"), "bias": ParamDesc((d,), (None,), "zeros")}


def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def alibi_slopes(n_heads: int) -> jax.Array:
    """ALiBi slopes (Press et al. 2022); handles non-power-of-2 head counts."""

    def pow2_slopes(n):
        start = 2.0 ** (-(2.0 ** -(np.log2(n) - 3)))
        return [start * (start**i) for i in range(n)]

    if np.log2(n_heads).is_integer():
        s = pow2_slopes(n_heads)
    else:
        closest = 2 ** int(np.floor(np.log2(n_heads)))
        s = pow2_slopes(closest)
        extra = pow2_slopes(2 * closest)[0::2][: n_heads - closest]
        s = s + extra
    return jnp.asarray(s, dtype=jnp.float32)
