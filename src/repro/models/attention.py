"""Grouped-query attention with qk-norm, RoPE/ALiBi/learned positions, sliding windows,
cross-attention, and KV-cache decode. The scaled-dot-product core dispatches to the
Pallas flash kernel on TPU (cfg-controlled) and the pure-jnp reference otherwise.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDesc, alibi_slopes, apply_rope, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter descriptions
# ---------------------------------------------------------------------------


def attn_desc(cfg, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    scale = 0.02
    p = {
        "wq": ParamDesc((d, hq, hd), (None, "heads", "head_dim"), "normal", scale),
        "wk": ParamDesc((d, hkv, hd), (None, "kv_heads", "head_dim"), "normal", scale),
        "wv": ParamDesc((d, hkv, hd), (None, "kv_heads", "head_dim"), "normal", scale),
        "wo": ParamDesc((hq, hd, d), ("heads", "head_dim", None), "normal", scale / max(1, 2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = ParamDesc((hd,), (None,), "ones")
        p["k_norm"] = ParamDesc((hd,), (None,), "ones")
    return p


# ---------------------------------------------------------------------------
# Core scaled-dot-product (reference path; Pallas kernels in repro.kernels)
# ---------------------------------------------------------------------------


def sdpa(
    q: jax.Array,  # (B, Sq, Hq, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,  # (B, Sk, Hkv, hd)
    mask: Optional[jax.Array],  # broadcastable to (B, 1, 1, Sq, Sk) or None
    bias: Optional[jax.Array] = None,  # additive, broadcastable to (B, Hq, Sq, Sk)
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    grp = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, grp, hd)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qr, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if bias is not None:  # (b|1, Hq, Sq, Sk) -> (b|1, Hkv, grp, Sq, Sk), broadcast over B
        scores = scores + bias.reshape(bias.shape[0], Hkv, grp, *bias.shape[-2:])
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p, v)
    return out.reshape(B, Sq, Hq, hd)


def make_mask(
    q_pos: jax.Array,  # (Sq,) or (B, Sq)
    k_pos: jax.Array,  # (Sk,) or (B, Sk)
    causal: bool,
    window,  # None, python int, or traced scalar (scanned per-layer window)
    k_len: Optional[jax.Array] = None,  # valid KV length for decode (scalar)
) -> jax.Array:
    """Boolean mask broadcastable to (B, 1, 1, Sq, Sk)."""
    if q_pos.ndim == 1:
        q_pos = q_pos[None]
    if k_pos.ndim == 1:
        k_pos = k_pos[None]
    qp = q_pos[:, None, None, :, None]
    kp = k_pos[:, None, None, None, :]
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (qp - kp < window)
    if k_len is not None:
        mask = mask & (kp < k_len)
    return mask


def _pick_chunk(s: int, preferred: int = 256) -> int:
    for c in (preferred, 128, 512, 64, 250, 375, 32):
        if s % c == 0:
            return c
    return s


import os

# §Perf experiment toggle: keep masked score blocks in bf16 through the softmax
# (halves the dominant HBM traffic of the jnp attention path; the Pallas kernel keeps
# scores in VMEM entirely). Enabled per-run: REPRO_BF16_SCORES=1.
_BF16_SCORES = os.environ.get("REPRO_BF16_SCORES", "0") == "1"


def sdpa_chunked(
    q: jax.Array,  # (B, Sq, Hq, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,
    *,
    q_pos: jax.Array,  # (Sq,)
    k_pos: jax.Array,  # (Sk,)
    causal: bool,
    window,
    k_len: Optional[jax.Array],
    slopes: Optional[jax.Array],  # ALiBi (Hq,) or None
    chunk: int = 256,
) -> jax.Array:
    """Flash-structured attention in pure jnp: lax.scan over query chunks keeps the
    materialized score block at (B, H, chunk, Sk) — this is the graph the dry-run
    lowers, bounding HBM temps the same way the Pallas kernel bounds VMEM."""
    B, Sq, Hq, hd = q.shape
    Hkv, Sk = k.shape[2], k.shape[1]
    grp = Hq // Hkv
    chunk = _pick_chunk(Sq, chunk)
    nq = Sq // chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qc = jnp.moveaxis(q.reshape(B, nq, chunk, Hq, hd), 1, 0)  # (nq, B, cq, Hq, hd)
    qpos_c = q_pos.reshape(nq, chunk)

    def body(_, inp):
        qb, qp = inp  # (B, cq, Hq, hd), (cq,)
        qr = qb.reshape(B, chunk, Hkv, grp, hd)
        s = jnp.einsum("bqhgd,bshd->bhgqs", qr, k).astype(jnp.float32) * scale
        qpc = qp[:, None]
        kpc = k_pos[None, :]
        m = jnp.ones((chunk, Sk), bool)
        if causal:
            m &= kpc <= qpc
        if window is not None:
            m &= (qpc - kpc) < window
        if k_len is not None:
            m &= kpc < k_len
        if slopes is not None:
            dist = jnp.maximum((qpc - kpc).astype(jnp.float32), 0.0)
            s = s - slopes.reshape(1, Hkv, grp, 1, 1) * dist[None, None, None]
        s = jnp.where(m[None, None, None], s, NEG_INF)
        if _BF16_SCORES:
            # bf16 shares f32's exponent range, so NEG_INF masking survives; the
            # max-subtraction inside softmax bounds the mantissa error.
            s = s.astype(jnp.bfloat16)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bhgqs,bshd->bqhgd", p, v).reshape(B, chunk, Hq, hd)
        return None, o

    # checkpoint: backward recomputes the per-chunk score block instead of saving all
    # (B, H, chunk, Sk) softmax residuals — the jnp analogue of flash attention's
    # recompute-in-backward.
    body = jax.checkpoint(body, prevent_cse=False)
    _, out = jax.lax.scan(body, None, (qc, qpos_c))  # (nq, B, cq, Hq, hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, hd)


# ---------------------------------------------------------------------------
# Full attention layer
# ---------------------------------------------------------------------------


def attention(
    cfg,
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    positions: jax.Array,  # (S,) token positions (absolute)
    causal: bool = True,
    window=None,
    cache: Optional[dict] = None,  # {'k': (B, Smax, Hkv, hd), 'v': ..., } decode/prefill
    cache_index: Optional[jax.Array] = None,  # scalar write offset for decode
    kv_source: Optional[jax.Array] = None,  # cross-attention memory (B, Skv, D)
    use_pallas: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    kv_in = kv_source if kv_source is not None else x
    k = jnp.einsum("bsd,dhk->bshk", kv_in, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_in, p["wv"].astype(x.dtype))

    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])

    bias = None
    if kv_source is None:  # self-attention: positional treatment
        if cfg.pos_embedding == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        if kv_source is not None and cache_index is None:
            # cross-attention cache is written once at prefill: entire k/v
            new_cache = {"k": k, "v": v}
        elif cache_index is not None and "k" in cache and cache["k"].shape[1] > S:
            # decode: write S (=1) new entries at cache_index, attend over full cache
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1
            )
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1
            )
            new_cache = {"k": ck, "v": cv}
            k, v = ck.astype(q.dtype), cv.astype(q.dtype)
        else:
            # prefill: cache is exactly the computed k/v
            new_cache = {"k": k, "v": v}

    Sk = k.shape[1]
    k_positions = jnp.arange(Sk)
    slopes = None
    if kv_source is not None:
        eff_causal, eff_window, k_len = False, None, None
    else:
        eff_causal, eff_window = causal, window
        k_len = None
        if cache is not None and cache_index is not None and Sk > S:
            k_len = cache_index + S
        if cfg.pos_embedding == "alibi":
            slopes = alibi_slopes(cfg.n_heads)  # (Hq,)

    if (
        use_pallas
        and slopes is None
        and kv_source is None
        and k_len is None
        and (window is None or isinstance(window, int))
    ):
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    elif S >= 512:
        out = sdpa_chunked(
            q, k, v, q_pos=positions, k_pos=k_positions, causal=eff_causal,
            window=eff_window, k_len=k_len, slopes=slopes,
        )
    else:
        mask = (
            None
            if kv_source is not None
            else make_mask(positions, k_positions, eff_causal, eff_window, k_len)
        )
        bias = None
        if slopes is not None:
            dist = (positions[:, None] - k_positions[None, :]).astype(jnp.float32)
            bias = (-slopes[:, None, None] * jnp.maximum(dist, 0.0))[None]
        out = sdpa(q, k, v, mask, bias)

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


def empty_cache_desc(cfg, batch: int, max_len: int, dtype) -> dict:
    """ShapeDtypeStruct-compatible zero cache for one attention layer."""
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, hkv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
