"""Pattern-aware transformer engine.

Layers are grouped into *segments*: a short unrolled prefix plus a periodic body that is
``lax.scan``-ned over its repeats (params stacked on a leading dim). This keeps the HLO
small for 40-62 layer models while supporting heterogeneous layer patterns:

  granite / qwen3 / coder / chameleon / llama4 : period 1 (uniform)
  gemma3        : period 1 — local/global differ only in *window*, passed as scanned data
  deepseek-moe  : prefix 1 (dense-FFN layer 0) + period 1 (MoE layers)
  jamba         : period 8 (MMMMAMMM with alternating dense/MoE FFN)
  mamba2        : period 1 (pure SSD blocks)
  whisper       : encoder stack (non-causal) + decoder stack with cross-attention

Modes: 'train' (no cache), 'prefill' (returns cache), 'decode' (1 token, updates cache).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerKind, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamDesc,
    apply_norm,
    norm_desc,
    stack_descs,
)

WINDOW_SENTINEL = 1 << 30  # "no window": mask (qpos - kpos < sentinel) is always true


# ---------------------------------------------------------------------------
# Segment planning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SegmentPlan:
    kinds: Tuple[LayerKind, ...]  # one per position within the body
    n_repeat: int  # scan length (1 = executed inline)
    first_layer: int  # absolute index of this segment's first layer

    @property
    def period(self) -> int:
        return len(self.kinds)

    @property
    def n_layers(self) -> int:
        return self.period * self.n_repeat

    def window_array(self, all_kinds: List[LayerKind]):
        """(n_repeat, period) int32 window per layer (sentinel = full attention)."""
        import numpy as np

        w = np.full((self.n_repeat, self.period), WINDOW_SENTINEL, dtype=np.int64)
        for r in range(self.n_repeat):
            for p in range(self.period):
                k = all_kinds[self.first_layer + r * self.period + p]
                if k.window is not None:
                    w[r, p] = k.window
        return jnp.asarray(np.minimum(w, WINDOW_SENTINEL), dtype=jnp.int32)


def plan_segments(kinds: List[LayerKind], max_period: int = 12) -> List[SegmentPlan]:
    n = len(kinds)
    sigs = [k.signature for k in kinds]
    for r in range(0, min(3, n) + 1):
        m = n - r
        if m == 0:
            break
        for p in range(1, max_period + 1):
            if m % p:
                continue
            if all(sigs[r + i] == sigs[r + (i % p)] for i in range(m)):
                segs = [
                    SegmentPlan(kinds=(kinds[i],), n_repeat=1, first_layer=i)
                    for i in range(r)
                ]
                segs.append(
                    SegmentPlan(
                        kinds=tuple(kinds[r : r + p]), n_repeat=m // p, first_layer=r
                    )
                )
                return segs
    # fallback: fully unrolled
    return [SegmentPlan(kinds=(k,), n_repeat=1, first_layer=i) for i, k in enumerate(kinds)]


# ---------------------------------------------------------------------------
# Parameter description
# ---------------------------------------------------------------------------


def _layer_desc(cfg: ModelConfig, kind: LayerKind) -> dict:
    d = {"norm1": norm_desc(cfg)}
    if kind.mixer == "attn":
        d["mixer"] = attn_mod.attn_desc(cfg)
    else:
        d["mixer"] = ssm_mod.ssm_desc(cfg)
    if kind.cross_attn:
        d["norm_cross"] = norm_desc(cfg)
        d["cross_attn"] = attn_mod.attn_desc(cfg, cross=True)
    if kind.ffn == "dense":
        d["norm2"] = norm_desc(cfg)
        d["ffn"] = moe_mod.dense_ffn_desc(cfg, cfg.d_ff)
    elif kind.ffn == "moe":
        d["norm2"] = norm_desc(cfg)
        d["ffn"] = moe_mod.moe_ffn_desc(cfg)
    return d


def _segment_desc(cfg: ModelConfig, seg: SegmentPlan) -> dict:
    body = {f"pos{p}": _layer_desc(cfg, k) for p, k in enumerate(seg.kinds)}
    if seg.n_repeat > 1:
        body = stack_descs(body, seg.n_repeat, stack_axis_name="layers")
    return body


def model_desc(cfg: ModelConfig) -> dict:
    d: Dict[str, Any] = {
        "embed": ParamDesc((cfg.padded_vocab, cfg.d_model), ("vocab", None), "embed"),
    }
    if cfg.pos_embedding == "learned":
        d["pos_embed"] = ParamDesc((cfg.max_seq_len, cfg.d_model), (None, None), "embed")
    segs = plan_segments(cfg.layer_kinds())
    d["segments"] = [_segment_desc(cfg, s) for s in segs]
    d["final_norm"] = norm_desc(cfg)
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamDesc((cfg.d_model, cfg.padded_vocab), (None, "vocab"), "normal")
    if cfg.enc_dec:
        enc_segs = plan_segments(cfg.encoder_layer_kinds())
        d["encoder"] = {
            "audio_pos": ParamDesc((cfg.n_audio_frames, cfg.d_model), (None, None), "embed"),
            "segments": [_segment_desc(cfg, s) for s in enc_segs],
            "final_norm": norm_desc(cfg),
        }
    return d


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _layer_cache(cfg: ModelConfig, kind: LayerKind, batch: int, max_len: int, dtype):
    c: Dict[str, Any] = {}
    if kind.mixer == "attn":
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        c["mixer"] = {
            "k": jnp.zeros((batch, max_len, hkv, hd), dtype),
            "v": jnp.zeros((batch, max_len, hkv, hd), dtype),
        }
    else:
        c["mixer"] = ssm_mod.empty_ssm_cache(cfg, batch)
    if kind.cross_attn:
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        c["cross"] = {
            "k": jnp.zeros((batch, cfg.n_audio_frames, hkv, hd), dtype),
            "v": jnp.zeros((batch, cfg.n_audio_frames, hkv, hd), dtype),
        }
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    segs = plan_segments(cfg.layer_kinds())
    out = []
    for seg in segs:
        body = {
            f"pos{p}": _layer_cache(cfg, k, batch, max_len, dtype)
            for p, k in enumerate(seg.kinds)
        }
        if seg.n_repeat > 1:
            body = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (seg.n_repeat,) + x.shape), body
            )
        out.append(body)
    return out


# ---------------------------------------------------------------------------
# Layer / segment application
# ---------------------------------------------------------------------------


def _apply_layer(
    cfg: ModelConfig,
    kind: LayerKind,
    p: dict,
    h: jax.Array,
    *,
    window,
    positions: jax.Array,
    cache: Optional[dict],
    cache_index: Optional[jax.Array],
    enc_out: Optional[jax.Array],
    decode: bool,
    use_pallas: bool,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    x = apply_norm(cfg, p["norm1"], h)
    if kind.mixer == "attn":
        a, mc = attn_mod.attention(
            cfg,
            p["mixer"],
            x,
            positions=positions,
            causal=True,
            window=window,
            cache=cache.get("mixer") if cache else None,
            cache_index=cache_index,
            use_pallas=use_pallas,
        )
    else:
        a, mc = ssm_mod.ssm_block(
            cfg,
            p["mixer"],
            x,
            cache=cache.get("mixer") if cache else None,
            decode=decode,
            use_pallas=use_pallas,
        )
    if mc is not None:
        new_cache["mixer"] = mc
    h = h + a

    if kind.cross_attn:
        xc = apply_norm(cfg, p["norm_cross"], h)
        if decode:
            # static memory KV, computed at prefill
            cc = cache["cross"]
            ca, _ = _cross_attend_cached(cfg, p["cross_attn"], xc, cc)
            new_cache["cross"] = cc
        else:
            ca, cc = attn_mod.attention(
                cfg, p["cross_attn"], xc, positions=positions, causal=False,
                cache={} if cache is not None else None, kv_source=enc_out,
            )
            if cc is not None:
                new_cache["cross"] = cc
        h = h + ca

    if kind.ffn != "none":
        x2 = apply_norm(cfg, p["norm2"], h)
        if kind.ffn == "dense":
            f = moe_mod.dense_ffn(cfg, p["ffn"], x2)
        else:
            f, aux = moe_mod.moe_ffn(cfg, p["ffn"], x2)
        h = h + f

    return h, (new_cache if (cache is not None or decode) else None), aux


def _cross_attend_cached(cfg, p, x, cross_cache):
    """Decode-time cross attention against prefill-cached encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k, v = cross_cache["k"].astype(x.dtype), cross_cache["v"].astype(x.dtype)
    out = attn_mod.sdpa(q, k, v, mask=None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), cross_cache


def _apply_segment(
    cfg: ModelConfig,
    seg: SegmentPlan,
    seg_params: dict,
    h: jax.Array,
    *,
    all_kinds: List[LayerKind],
    positions: jax.Array,
    seg_cache,
    cache_index,
    enc_out,
    decode: bool,
    use_pallas: bool,
    remat: bool = False,
):
    windows = seg.window_array(all_kinds)  # (n_repeat, period)

    def make_layer_fn(pidx, kind):
        def layer_fn(h, params_l, window_l, cache_l):
            return _apply_layer(
                cfg,
                kind,
                params_l,
                h,
                window=window_l,
                positions=positions,
                cache=cache_l,
                cache_index=cache_index,
                enc_out=enc_out,
                decode=decode,
                use_pallas=use_pallas,
            )

        if remat and not decode:
            # per-LAYER checkpointing: the backward pass holds one layer's internals
            # at a time even when the scan body spans a multi-layer hybrid period
            return jax.checkpoint(layer_fn, prevent_cse=False)
        return layer_fn

    layer_fns = [make_layer_fn(p, k) for p, k in enumerate(seg.kinds)]

    def run_body(h, params_r, windows_r, cache_r):
        aux_total = jnp.zeros((), jnp.float32)
        new_cache_r = {}
        for pidx, kind in enumerate(seg.kinds):
            key = f"pos{pidx}"
            h, nc, aux = layer_fns[pidx](
                h,
                params_r[key],
                windows_r[pidx],
                cache_r.get(key) if cache_r else None,
            )
            if nc is not None:
                new_cache_r[key] = nc
            aux_total = aux_total + aux
        return h, new_cache_r, aux_total

    if seg.n_repeat == 1:
        params_r = seg_params
        cache_r = seg_cache
        h, new_cache_r, aux = run_body(h, params_r, windows[0], cache_r)
        return h, (new_cache_r or None), aux

    body = run_body

    def scan_fn(carry, xs):
        h, aux_acc = carry
        params_r, windows_r, cache_r = xs
        h, new_cache_r, aux = body(h, params_r, windows_r, cache_r)
        return (h, aux_acc + aux), new_cache_r

    xs = (seg_params, windows, seg_cache)
    if seg_cache is None:
        xs = (seg_params, windows, jax.tree_util.tree_map(lambda _: None, jnp.zeros(seg.n_repeat)))
        # scan requires a pytree; use a dummy per-repeat placeholder
        xs = (seg_params, windows, jnp.zeros((seg.n_repeat,), jnp.int32))

        def scan_fn(carry, xs):  # noqa: F811
            h, aux_acc = carry
            params_r, windows_r, _ = xs
            h, new_cache_r, aux = body(h, params_r, windows_r, None)
            return (h, aux_acc + aux), new_cache_r

    (h, aux), new_cache = jax.lax.scan(scan_fn, (h, jnp.zeros((), jnp.float32)), xs)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Encoder (audio, non-causal)
# ---------------------------------------------------------------------------


def _encode(cfg: ModelConfig, enc_params: dict, audio_embed: jax.Array, use_pallas: bool):
    h = audio_embed + enc_params["audio_pos"][None, : audio_embed.shape[1]].astype(audio_embed.dtype)
    kinds = cfg.encoder_layer_kinds()
    segs = plan_segments(kinds)
    positions = jnp.arange(audio_embed.shape[1])

    for seg, seg_params in zip(segs, enc_params["segments"]):
        windows = seg.window_array(kinds)

        def enc_layer(h, params_r):
            x = apply_norm(cfg, params_r["pos0"]["norm1"], h)
            a, _ = attn_mod.attention(
                cfg, params_r["pos0"]["mixer"], x, positions=positions, causal=False,
                use_pallas=use_pallas,
            )
            h = h + a
            x2 = apply_norm(cfg, params_r["pos0"]["norm2"], h)
            return h + moe_mod.dense_ffn(cfg, params_r["pos0"]["ffn"], x2)

        if seg.n_repeat == 1:
            h = enc_layer(h, seg_params)
        else:
            def scan_fn(carry, params_r):
                return enc_layer(carry, params_r), None

            h, _ = jax.lax.scan(scan_fn, h, seg_params)
    return apply_norm(cfg, enc_params["final_norm"], h)


# ---------------------------------------------------------------------------
# Public forward
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # (B, S) int32
    *,
    audio_embed: Optional[jax.Array] = None,  # (B, F, D) for enc-dec (stub frontend)
    mode: str = "train",  # 'train' | 'prefill' | 'decode'
    cache=None,
    cache_index: Optional[jax.Array] = None,
    remat: bool = False,
    use_pallas: bool = False,
    logits_mode: str = "full",  # 'full' | 'last' | 'hidden' (return pre-head h)
):
    """Returns (logits (B,S,V) | hidden (B,S,D), aux_loss scalar, new_cache)."""
    assert mode in ("train", "prefill", "decode")
    decode = mode == "decode"
    B, S = tokens.shape
    compute_dtype = jnp.dtype(cfg.compute_dtype)

    embed = params["embed"]
    h = jnp.take(embed, tokens, axis=0).astype(compute_dtype)

    if decode:
        assert cache_index is not None
        positions = cache_index + jnp.arange(S)
    else:
        positions = jnp.arange(S)

    if cfg.pos_embedding == "learned":
        pe = jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], positions[0] if decode else 0, S, axis=0
        )
        h = h + pe.astype(compute_dtype)

    enc_out = None
    if cfg.enc_dec and not decode:
        assert audio_embed is not None, "enc-dec model requires audio_embed"
        enc_out = _encode(cfg, params["encoder"], audio_embed.astype(compute_dtype), use_pallas)

    all_kinds = cfg.layer_kinds()
    segs = plan_segments(all_kinds)
    if mode == "prefill" and cache is None:
        cache = _prefill_placeholder_cache(cfg, segs)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = [] if (cache is not None or decode) else None
    for seg, seg_params, seg_cache in zip(
        segs, params["segments"], cache if cache is not None else [None] * len(segs)
    ):
        h, seg_new_cache, aux = _apply_segment(
            cfg,
            seg,
            seg_params,
            h,
            all_kinds=all_kinds,
            positions=positions,
            seg_cache=seg_cache,
            cache_index=cache_index,
            enc_out=enc_out,
            decode=decode,
            use_pallas=use_pallas,
            remat=remat,
        )
        aux_total = aux_total + aux
        if new_cache is not None:
            new_cache.append(seg_new_cache)

    h = apply_norm(cfg, params["final_norm"], h)
    if logits_mode == "hidden":
        return h, aux_total, new_cache
    if logits_mode == "last":
        h = h[:, -1:]
    logits = project_logits(cfg, params, h)
    return logits, aux_total, new_cache


def project_logits(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    compute_dtype = h.dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(compute_dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(compute_dtype))
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., : cfg.vocab_size]
    return logits


def _prefill_placeholder_cache(cfg, segs):
    """Prefill computes the cache from scratch; placeholder triggers cache outputs."""
    out = []
    for seg in segs:
        body = {f"pos{p}": {"mixer": {}} for p in range(seg.period)}
        out.append(body)
    return out
