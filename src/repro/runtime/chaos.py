"""Fault injection for the cross-process runtime (the ``--chaos`` flags).

Every outbound message on either end rolls one seeded die and suffers at most
one of: process KILL (``os._exit`` — the hard crash the lease/redispatch and
checkpoint-resume machinery must absorb), message DROP (the frame is never
sent; the peer recovers via its own timeout + retry), or DELAY (the send is
held for ``delay_s`` — exercises lease expiry and the deadline flush without
killing anyone).

Payload corruption (``--chaos-corrupt``) is a separate die rolled per worker
*push*: the delta pytree itself is poisoned before it leaves the worker —
NaN/Inf fill, large-scale amplification, sign flip, or a replay of the
previous push. Unlike drop/kill, a corrupted payload arrives as a perfectly
well-formed frame (the CRC passes — corruption happened before framing), so
the only line of defense is the server's delta screen / robust aggregation.

The generator is seeded per ``(seed, role)`` so a chaos run is reproducible
per process and the server's dice are independent of each worker's.
"""
from __future__ import annotations

import copy
import os
import random
import sys
from dataclasses import dataclass
from typing import Any, Optional, Tuple

from repro.core.robust import CORRUPT_KINDS, corrupt_tree


@dataclass(frozen=True)
class ChaosConfig:
    drop: float = 0.0  # P(outbound message silently dropped)
    delay: float = 0.0  # P(outbound message held for delay_s)
    kill: float = 0.0  # P(process exits hard before sending)
    delay_s: float = 0.2
    corrupt: float = 0.0  # P(worker push payload poisoned before send)
    corrupt_kinds: Tuple[str, ...] = CORRUPT_KINDS
    seed: int = 0

    def __post_init__(self):
        for name in ("drop", "delay", "kill", "corrupt"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"chaos {name} probability {p} outside [0, 1]")
        if not self.corrupt_kinds:
            raise ValueError("chaos corrupt_kinds must not be empty")
        for k in self.corrupt_kinds:
            if k not in CORRUPT_KINDS:
                raise ValueError(
                    f"unknown corrupt kind {k!r} (choose from {CORRUPT_KINDS})"
                )

    @property
    def active(self) -> bool:
        return (self.drop + self.delay + self.kill + self.corrupt) > 0.0


KILL_EXIT_CODE = 137  # what SIGKILL would report — supervisors respawn on it


class ChaosMonkey:
    """One die roll per outbound message; at most one fault fires.

    When a tracer is attached, every injected fault lands in the event log as
    a ``fault`` instant — the audit the report CLI cross-checks against. For a
    *kill* the tracer is flushed to disk BEFORE ``os._exit`` (which skips all
    atexit/buffer teardown), so the fault that explains a half-open span
    always survives the crash it causes.
    """

    def __init__(self, cfg: ChaosConfig, role: str, tracer=None):
        self.cfg = cfg
        self.role = role
        self.tracer = tracer
        self._rng = random.Random(f"{cfg.seed}:{role}")
        self._corrupt_rng = random.Random(f"{cfg.seed}:{role}:corrupt")
        self._last_payload: Optional[Any] = None

    def _fault(self, kind: str, **attrs) -> None:
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.point("fault", kind=kind, role=self.role, **attrs)
            self.tracer.count(f"chaos_{kind}")
            if kind == "kill":
                self.tracer.flush()

    def on_payload(self, tree: Any, index: int) -> Tuple[Any, Optional[str]]:
        """Roll the corruption die for one outbound push payload. Returns the
        (possibly poisoned) tree and the corruption kind, or ``None`` when the
        payload goes out clean. ``replay`` resends the previous clean payload
        (valid-looking but stale — the staleness/duplicate machinery's
        problem, not the screen's); with no prior push it degrades to a sign
        flip so the configured probability always injects *something*. The
        fault instant carries the push ``index`` so the report audit can tie
        each injected corruption to its admission outcome."""
        if self.cfg.corrupt <= 0.0:
            return tree, None
        roll = self._corrupt_rng.random()
        prev, self._last_payload = self._last_payload, copy.deepcopy(tree)
        if roll >= self.cfg.corrupt:
            return tree, None
        kind = self._corrupt_rng.choice(self.cfg.corrupt_kinds)
        if kind == "replay":
            if prev is None:
                kind = "sign_flip"
                tree = corrupt_tree(tree, kind)
            else:
                tree = prev
        else:
            tree = corrupt_tree(tree, kind)
        self._fault(f"corrupt_{kind}", index=int(index))
        return tree, kind

    def on_send(self) -> bool:
        """Roll before a send. Returns True when the message must be DROPPED.
        May not return at all (kill)."""
        if not self.cfg.active:
            return False
        r = self._rng.random()
        if r < self.cfg.kill:
            print(f"[chaos:{self.role}] killed before send", file=sys.stderr, flush=True)
            self._fault("kill")
            os._exit(KILL_EXIT_CODE)
        r -= self.cfg.kill
        if r < self.cfg.drop:
            self._fault("drop")
            return True
        r -= self.cfg.drop
        if r < self.cfg.delay:
            import time

            self._fault("delay")
            time.sleep(self.cfg.delay_s)
        return False
