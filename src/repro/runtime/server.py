"""Server side of the cross-process runtime: a :class:`SocketBackend` that
hands dispatched slots to remote worker processes over the length-prefixed
transport.

Protocol (worker-initiated request/response over a persistent connection):

    pull  {worker}                → work {index, client, version, local_steps,
                                          stream_state} + trees {params,
                                          residual?, rng?}
                                  | wait {}    (no grantable slot right now)
                                  | done {}    (run finished — exit)
    push  {index, client, loss, stream_state} + trees {payload, residual?}
                                  → ack {index}

Fault tolerance:

* **Leases.** A granted slot carries a wall-clock lease. If the worker dies or
  stalls past ``lease_timeout``, the next ``pull`` (from any worker) re-grants
  the slot — the assignment was never consumed, only leased. The same worker
  re-pulling its own unexpired lease is also re-granted (a dropped ``work``
  response must not wedge the slot until expiry).
* **Idempotent redispatch.** Assignments are pure (see ``runtime/driver``), so
  two workers racing the same slot return identical results; the first ``push``
  wins, duplicates are acked and discarded.
* **Data cursors.** The server owns every population client's stream state; it
  rides out in the assignment and the advanced cursor rides back in the push.
  It is committed only when the driver processes the result *in event order*,
  which keeps checkpointed cursors consistent with the dispatch manifest —
  a crash-resume recreates in-flight assignments with exactly the cursor they
  originally shipped.

The backend is intentionally dumb about federation: every decision (admission,
staleness, flushes, checkpoints) stays in :class:`FederationDriver` on top of
the ``AsyncBufferAggregator`` it shares with the in-process path.
"""
from __future__ import annotations

import copy
import socket
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs.tracer import get_tracer
from repro.runtime.chaos import ChaosConfig, ChaosMonkey
from repro.runtime.driver import Assignment, ClientBackend, ClientResult
from repro.runtime.transport import Message, TransportError, recv_msg, send_msg


def _tree_leaves(tree):
    """Yield the np-array leaves of a wire pytree (dict/list/tuple nesting)."""
    if isinstance(tree, dict):
        for v in tree.values():
            yield from _tree_leaves(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _tree_leaves(v)
    else:
        yield tree


class SocketBackend(ClientBackend):
    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        stream_states: Optional[List[Dict[str, Any]]] = None,
        lease_timeout: float = 30.0,
        io_timeout: float = 30.0,
        chaos: Optional[ChaosConfig] = None,
        tracer=None,
    ):
        self.lease_timeout = lease_timeout
        self.io_timeout = io_timeout
        self.stream_states = stream_states  # index = population client id
        self.tracer = get_tracer(tracer)
        self._monkey = (
            ChaosMonkey(chaos, "server", tracer=self.tracer)
            if chaos is not None and chaos.active
            else None
        )
        # wire truth for the byte-accounting parity test: bytes of accepted
        # (non-duplicate) push payload blobs, and per-worker last-seen clocks
        # for the liveness gauge — plain host floats, safe to read from the
        # metrics HTTP thread
        self.payload_bytes_rx = 0.0
        self._worker_seen: Dict[str, float] = {}
        self._knobs: Dict[str, float] = {}  # live control knobs (control_* gauges)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: Dict[int, Assignment] = {}  # index → live assignment
        self._leases: Dict[int, tuple] = {}  # index → (deadline, worker)
        self._results: Dict[int, ClientResult] = {}  # arrived, not yet processed
        self._done = False
        self._stop = threading.Event()
        self._conns: List[socket.socket] = []
        self._srv = socket.create_server((host, port))
        self._srv.settimeout(0.2)
        self.host, self.port = self._srv.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="runtime-accept", daemon=True
        )
        self._accept_thread.start()

    # --- ClientBackend ----------------------------------------------------
    def submit(self, a: Assignment) -> None:
        if self.stream_states is not None:
            a.stream_state = copy.deepcopy(self.stream_states[a.client])
        with self._cv:
            self._pending[a.index] = a
            self._cv.notify_all()

    def result(self, index: int, timeout: Optional[float] = None) -> ClientResult:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while index not in self._results:
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError(f"slot {index} still outstanding")
                    self._cv.wait(min(remaining, 0.5))
                else:
                    self._cv.wait(0.5)
            return self._results[index]

    def commit(self, index: int, result: ClientResult) -> None:
        with self._cv:
            self._pending.pop(index, None)
            self._leases.pop(index, None)
            self._results.pop(index, None)
        if self.stream_states is not None and result.stream_state is not None:
            self.stream_states[result.client] = result.stream_state

    def apply_knob_update(self, update, acfg) -> None:
        """Server-side landing of a control-loop :class:`KnobUpdate`: the
        aggregator already rebuilt its jits/lanes; the backend's job is to make
        the LIVE knob values observable — they feed the metrics endpoint as
        ``control_*`` gauges (plain floats, safe for the HTTP thread). Workers
        need no notification: assignments are self-describing and admission
        semantics live entirely server-side."""
        with self._lock:
            self._knobs["control_staleness_alpha"] = float(acfg.staleness_alpha)
            self._knobs["control_buffer_size"] = float(acfg.buffer_size)
        if self.tracer.enabled:
            self.tracer.count("knob_updates_applied")

    def control_knobs(self) -> Dict[str, float]:
        """Current server-side control knob values (empty when uncontrolled)."""
        with self._lock:
            return dict(self._knobs)

    def metrics_extras(self) -> Dict[str, float]:
        """The combined extras callable for the metrics endpoint: worker
        liveness plus the live control knobs."""
        return {**self.worker_liveness(), **self.control_knobs()}

    def finish(self) -> None:
        """Start answering every pull with ``done`` (run complete)."""
        with self._cv:
            self._done = True
            self._cv.notify_all()

    def close(self, linger: float = 0.0) -> None:
        self.finish()
        if linger > 0:  # give workers a beat to pull the "done" answer
            time.sleep(linger)
        self._stop.set()
        try:
            self._srv.close()
        except OSError:
            pass
        with self._lock:
            conns, self._conns = self._conns, []
        for c in conns:
            try:
                c.close()
            except OSError:
                pass

    # --- socket plumbing --------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            conn.settimeout(self.io_timeout)
            with self._lock:
                self._conns.append(conn)
            threading.Thread(
                target=self._serve, args=(conn,), name="runtime-conn", daemon=True
            ).start()

    def _serve(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                msg = recv_msg(conn, tracer=self.tracer)
                if msg.type == "pull":
                    self._handle_pull(conn, msg)
                elif msg.type == "push":
                    self._handle_push(conn, msg)
                else:
                    send_msg(conn, "error", {"reason": f"unknown type {msg.type}"},
                             tracer=self.tracer)
        except (TransportError, OSError):
            pass  # worker went away; its leases expire and redispatch
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _grant(self, worker: str) -> Optional[Assignment]:
        now = time.monotonic()
        with self._lock:
            for index in sorted(self._pending):
                if index in self._results:
                    continue  # computed, waiting for in-order processing
                lease = self._leases.get(index)
                if lease is not None and lease[0] > now and lease[1] != worker:
                    continue  # actively leased to someone else
                regrant = lease is not None
                expired = regrant and lease[0] <= now
                self._leases[index] = (now + self.lease_timeout, worker)
                if self.tracer.enabled:
                    self.tracer.point(
                        "lease_grant", parent=f"d{index}", index=index,
                        worker=worker, regrant=regrant, expired=expired,
                    )
                    self.tracer.count("lease_grants")
                    if expired:
                        self.tracer.count("lease_expiries")
                        self.tracer.count("redispatches")
                    elif regrant:
                        self.tracer.count("lease_regrants")
                return self._pending[index]
        return None

    def _handle_pull(self, conn: socket.socket, msg: Message) -> None:
        worker = str(msg.meta.get("worker", "?"))
        self._worker_seen[worker] = time.monotonic()
        self.tracer.count("pulls")
        if self._done:
            send_msg(conn, "done", chaos=self._monkey, tracer=self.tracer)
            return
        a = self._grant(worker)
        if a is None:
            self.tracer.count("pull_waits")
            send_msg(conn, "wait", chaos=self._monkey, tracer=self.tracer)
            return
        meta = {
            "index": a.index,
            "client": a.client,
            "version": a.version,
            "local_steps": a.local_steps,
            "stream_state": a.stream_state,
        }
        if self.tracer.enabled:
            # cross-process propagation: the worker parents its assignment
            # span into this dispatch's span via the frame header
            meta["trace"] = {"t": self.tracer.trace_id, "s": f"d{a.index}"}
        trees = {"params": a.params}
        if a.residual is not None:
            trees["residual"] = a.residual
        if a.rng is not None:
            trees["rng"] = a.rng
        send_msg(conn, "work", meta=meta, trees=trees,
                 chaos=self._monkey, tracer=self.tracer)

    def _handle_push(self, conn: socket.socket, msg: Message) -> None:
        index = int(msg.meta["index"])
        worker = str(msg.meta.get("worker", "?"))
        self._worker_seen[worker] = time.monotonic()
        result = ClientResult(
            index=index,
            client=int(msg.meta["client"]),
            payload=msg.trees.get("payload"),
            residual=msg.trees.get("residual"),
            loss=float(msg.meta["loss"]),
            stream_state=msg.meta.get("stream_state"),
        )
        with self._cv:
            # first result wins; duplicates (lease races, re-pushed after a
            # dropped ack) are acked and discarded — results are identical
            # anyway because assignments are pure
            accepted = index in self._pending and index not in self._results
            if accepted:
                self._results[index] = result
                self._cv.notify_all()
        if self.tracer.enabled:
            self.tracer.point("push_recv", parent=f"d{index}", index=index,
                              worker=worker, dup=not accepted)
            self.tracer.count("pushes")
            if accepted:
                if result.payload is not None:
                    nbytes = float(sum(
                        np.asarray(leaf).nbytes
                        for leaf in _tree_leaves(result.payload)
                    ))
                    self.payload_bytes_rx += nbytes
                    self.tracer.count("payload_bytes_rx", nbytes)
            else:
                self.tracer.count("dedup_drops")
        send_msg(conn, "ack", {"index": index}, chaos=self._monkey,
                 tracer=self.tracer)

    # --- liveness ---------------------------------------------------------
    def worker_liveness(self, window: float = 15.0) -> Dict[str, float]:
        """Metrics-endpoint extras: workers seen within ``window`` seconds +
        total distinct workers ever seen. Plain floats only (HTTP thread)."""
        now = time.monotonic()
        seen = dict(self._worker_seen)
        return {
            "workers_alive": float(
                sum(1 for t in seen.values() if now - t <= window)
            ),
            "workers_seen": float(len(seen)),
        }

    # --- checkpoint support ----------------------------------------------
    def snapshot_stream_states(self) -> Optional[List[Dict[str, Any]]]:
        """Data cursors as of every PROCESSED event (commit order) — consistent
        with the aggregator's dispatch manifest by construction."""
        if self.stream_states is None:
            return None
        return copy.deepcopy(self.stream_states)
