"""Client worker process: pull → local training → push, forever.

A worker is PURE COMPUTE. It owns no federation state: the assignment carries
the params snapshot, version tag, error-feedback residual row, per-dispatch
uplink rng and the client's data cursor; the worker loads the cursor into its
(identically constructed) stream object, draws the τ local batches, runs the
shared jitted client phase (``runtime.driver.build_client_phase`` — the same
XLA program the in-process simulator compiles) and pushes back the encoded
codec payload, updated residual row, advanced cursor and final train loss.

Because assignments are self-describing and the data draw is deterministic in
the cursor, any worker can serve any population client and re-executing an
assignment is idempotent — which is exactly what the server's lease/redispatch
recovery relies on.

Failure discipline: every pull/push is a request/response with an I/O timeout;
any transport failure (refused, reset, EOF, timeout, chaos-dropped frames)
tears down the connection and retries under bounded exponential backoff
(:class:`repro.runtime.transport.Backoff`); the worker exits cleanly when the
server answers ``done`` or has been unreachable for the backoff's give-up
budget.
"""
from __future__ import annotations

import socket
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.compression import Codec
from repro.core.federated import FederatedConfig
from repro.core.sampler import ParticipationConfig
from repro.obs.tracer import get_tracer
from repro.runtime.chaos import ChaosConfig, ChaosMonkey
from repro.runtime.driver import build_client_phase
from repro.runtime.transport import (
    Backoff,
    Message,
    TransportError,
    connect,
    recv_msg,
    send_msg,
)


class _Dropped(TransportError):
    """Our own outbound frame was chaos-dropped — retry like any other loss."""


class ClientWorker:
    def __init__(
        self,
        loss_fn: Callable,
        fed: FederatedConfig,
        pcfg: ParticipationConfig,
        *,
        streams: Optional[List[Any]] = None,  # one TokenStream per population client
        batch_size: int = 1,
        make_batches: Optional[Callable[[int], Any]] = None,  # pure-in-cid override
        host: str = "127.0.0.1",
        port: int = 0,
        codec: Optional[Codec] = None,
        name: str = "worker",
        io_timeout: float = 30.0,
        poll_interval: float = 0.05,
        backoff: Optional[Backoff] = None,
        chaos: Optional[ChaosConfig] = None,
        tracer=None,
    ):
        if (streams is None) == (make_batches is None):
            raise ValueError("pass exactly one of streams= or make_batches=")
        self.fed = fed
        self.streams = streams
        self.make_batches = make_batches
        self.batch_size = batch_size
        self.host, self.port = host, port
        self.name = name
        self.io_timeout = io_timeout
        self.poll_interval = poll_interval
        self.backoff = backoff or Backoff()
        self._stateful = codec is not None and codec.stateful
        self._codec = codec
        self._partial = pcfg.partial_progress
        self._client_fn = build_client_phase(loss_fn, fed, codec, pcfg.partial_progress)
        self.tracer = get_tracer(tracer)
        self._monkey = (
            ChaosMonkey(chaos, name, tracer=self.tracer)
            if chaos is not None and chaos.active
            else None
        )
        self._sock: Optional[socket.socket] = None

    # --- transport with retry --------------------------------------------
    def _close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _rpc(self, mtype: str, meta: Dict[str, Any], trees=None) -> Optional[Message]:
        """One request/response with reconnect + bounded exponential backoff.
        Returns None when the server stayed unreachable past the give-up
        budget (supervisors decide whether to respawn us)."""
        while True:
            try:
                if self._sock is None:
                    self._sock = connect(self.host, self.port, self.io_timeout)
                if not send_msg(self._sock, mtype, meta, trees,
                                chaos=self._monkey, tracer=self.tracer):
                    raise _Dropped("chaos dropped our frame")
                reply = recv_msg(self._sock, tracer=self.tracer)
                self.backoff.reset()
                return reply
            except (TransportError, OSError) as e:
                self._close()
                if not self.backoff.sleep():
                    print(f"[{self.name}] giving up: {e}", flush=True)
                    return None

    # --- the work loop ----------------------------------------------------
    def run(self, max_assignments: Optional[int] = None) -> int:
        """Serve until the server says done (or goes away). Returns the number
        of assignments completed."""
        done = 0
        while max_assignments is None or done < max_assignments:
            reply = self._rpc("pull", {"worker": self.name})
            if reply is None or reply.type == "done":
                break
            if reply.type == "wait":
                time.sleep(self.poll_interval)
                continue
            if reply.type != "work":
                continue
            index = int(reply.meta["index"])
            # parent into the server's dispatch span via the wire-propagated
            # trace context (fall back to the deterministic id when the
            # server runs untraced — span ids need no handshake)
            wire_trace = reply.meta.get("trace") or {}
            parent = wire_trace.get("s", f"d{index}")
            sid = f"d{index}@{self.name}"
            self.tracer.begin(
                "assignment", span_id=sid, parent=parent, index=index,
                client=int(reply.meta["client"]),
                version=int(reply.meta["version"]),
            )
            with self.tracer.span("train", span_id=f"{sid}/t", parent=sid):
                meta, trees = self._execute(reply)
            if self._monkey is not None:
                # payload corruption happens here — after training, before
                # framing — so the frame CRC passes and only the server's
                # delta screen / robust rule stands between the poison and
                # the model
                trees["payload"], _ = self._monkey.on_payload(
                    trees["payload"], index
                )
            self.tracer.begin("push", span_id=f"{sid}/p", parent=sid)
            ack = self._rpc("push", meta, trees)
            self.tracer.end(f"{sid}/p", ok=ack is not None)
            self.tracer.end(sid, outcome="pushed" if ack is not None else "gave_up")
            self.tracer.count("assignments")
            if ack is None:
                break
            done += 1
        self._close()
        self.tracer.flush()
        return done

    def _draw(self, cid: int, stream_state):
        """τ local batches for ``cid``: from the shipped data cursor (real
        streams) or a pure-in-cid batch function (tests/toy models — the draw
        then needs no cursor to be idempotent). Returns (batches, new_cursor)."""
        if self.streams is None:
            return self.make_batches(cid), None
        from repro.data import round_batches

        stream = self.streams[cid]
        if stream_state is not None:
            stream.load_state_dict(stream_state)
        batches = {
            k: jnp.asarray(v)
            for k, v in round_batches(
                [stream], self.fed.local_steps, self.batch_size
            ).items()
        }
        return batches, stream.state_dict()

    def _execute(self, msg: Message):
        meta = msg.meta
        cid = int(meta["client"])
        batches, new_cursor = self._draw(cid, meta.get("stream_state"))
        params = jax.tree_util.tree_map(jnp.asarray, msg.trees["params"])
        extra: Dict[str, Any] = {}
        if self._codec is not None:
            extra["rng"] = jnp.asarray(msg.trees["rng"])
        if self._partial:
            extra["tau"] = jnp.asarray(
                [int(meta["local_steps"]) or self.fed.local_steps], jnp.int32
            )
        if self._stateful:
            extra["res"] = jax.tree_util.tree_map(jnp.asarray, msg.trees["residual"])
        deltas, aux = self._client_fn(
            params, jnp.asarray(int(meta["version"]), jnp.int32), batches, extra
        )
        payload = jax.tree_util.tree_map(lambda d: d[0], deltas)
        out_meta = {
            "index": int(meta["index"]),
            "client": cid,
            "loss": float(aux["step_metrics"]["loss"][-1]),
            "stream_state": new_cursor,
            "worker": self.name,
        }
        out_trees: Dict[str, Any] = {"payload": payload}
        if self._stateful:
            out_trees["residual"] = aux["residuals"]
        return out_meta, out_trees
