"""Backend-pluggable federation driver — the shared seam between the simulated
in-process timeline and the real cross-process socket runtime.

The event order, admission policy, residual custody and checkpoint schema all
live in :class:`repro.core.AsyncBufferAggregator`; what varies between "one
process simulating everything" and "N worker processes on a network" is only
*who executes a dispatched slot's local training*. That seam is
:class:`ClientBackend`:

    submit(assignment)        — a slot was dispatched; here is everything needed
                                to compute it (fired from the aggregator's
                                ``_on_dispatch`` hook, including replayed slots
                                on crash-resume)
    result(index, timeout)    — block until the slot's upload is available
    commit(index, result)     — the upload was processed in event order; retire
                                the assignment (and persist data cursors)

Assignments are **fully self-describing and pure**: params snapshot and version
tag fixed at dispatch, the client's error-feedback residual row, the
per-dispatch uplink rng (``fold_in(uplink_rng, index)``), the realized τ_i and
the client's data cursor. Because the aggregator holds each client in at most
one slot at a time (``_busy``), the row/cursor a slot carries cannot change
between dispatch and completion — so executing an assignment is idempotent:
a redispatched or duplicated execution returns the identical result, which is
what makes lease-expiry redispatch and first-result-wins dedup safe, and why
the server process alone checkpoints everything.

:class:`FederationDriver` + :class:`LocalClientBackend` reproduces the legacy
:class:`repro.core.AsyncFederationDriver` BITWISE (tested) — the simulated
timeline is now just one pluggable backend; the socket backend in
``runtime/server.py`` is another.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregator import AsyncBufferAggregator
from repro.core.compression import Codec
from repro.core.federated import FederatedConfig, run_clients
from repro.core.async_agg import AsyncAggConfig
from repro.core.sampler import ParticipationConfig


@dataclass
class Assignment:
    """One dispatched slot's work order — everything a worker needs, nothing
    the worker must remember."""

    index: int  # dispatch index: the idempotency key
    client: int  # population client id (data + residual ownership)
    version: int  # model version the snapshot was taken at
    local_steps: int  # realized τ_i under partial progress (0 → full τ)
    params: Any  # params snapshot (by reference — jax arrays are immutable)
    residual: Any = None  # (1, ...) error-feedback row, stateful codecs only
    rng: Any = None  # per-dispatch uplink key, codec runs only
    stream_state: Any = None  # JSON data cursor (socket runtime ships it)


@dataclass
class ClientResult:
    """One slot's upload: exactly what crosses the uplink, plus bookkeeping."""

    index: int
    client: int
    payload: Any  # encoded codec payload (client axis stripped)
    residual: Any  # updated (1, ...) EF row, stateful codecs only
    loss: float  # last local-step train loss
    stream_state: Any = None  # advanced data cursor (socket runtime)


class ClientBackend:
    """Executes assignments; owns nothing resumable except data cursors."""

    def submit(self, assignment: Assignment) -> None:
        raise NotImplementedError

    def result(self, index: int, timeout: Optional[float] = None) -> ClientResult:
        """Block until slot ``index`` completed. Raises ``TimeoutError`` after
        ``timeout`` seconds so the driver can interleave deadline flushes."""
        raise NotImplementedError

    def commit(self, index: int, result: ClientResult) -> None:
        """Called in event order after the driver processed ``result``."""

    def apply_knob_update(self, update, acfg) -> None:
        """The control loop applied a :class:`repro.control.KnobUpdate`
        server-side; ``acfg`` is the post-update async config. Backends that
        expose live state (the socket server's metrics extras) record the new
        knob values here — assignments themselves need nothing: they are
        self-describing, and admission/flush semantics live entirely in the
        aggregator that already changed."""

    def close(self) -> None:
        pass


def build_client_phase(
    loss_fn: Callable,
    fed: FederatedConfig,
    codec: Optional[Codec],
    partial_progress: bool,
):
    """The jitted C=1 local-training phase every backend runs — one shared
    definition so the in-process simulator and the worker processes compile the
    *same* XLA program (the bitwise-parity anchor)."""
    fed1 = replace(fed, clients_per_round=1, keep_inner_state=False)
    stateful = codec is not None and codec.stateful

    def _client(p, r, b, extra):
        st = {"params": p, "round": r}
        kw: Dict[str, Any] = {}
        if codec is not None:
            st["rng"] = extra["rng"]
        if stateful:
            kw["residuals"] = extra["res"]
        if partial_progress:
            kw["tau_steps"] = extra["tau"]
        return run_clients(loss_fn, fed1, st, b, codec=codec, **kw)

    return jax.jit(_client)


class LocalClientBackend(ClientBackend):
    """In-process simulated execution: assignments run lazily when the driver
    pops their completion event, in event order — the same instant the legacy
    ``AsyncFederationDriver`` runs ``make_batches`` + the client phase, so the
    per-client data-draw order and every float are identical."""

    def __init__(
        self,
        loss_fn: Callable,
        fed: FederatedConfig,
        pcfg: ParticipationConfig,
        make_batches: Callable[[int], Dict[str, jax.Array]],
        codec: Optional[Codec] = None,
    ):
        self.fed = fed
        self.make_batches = make_batches
        self._stateful = codec is not None and codec.stateful
        self._partial = pcfg.partial_progress
        self._client_fn = build_client_phase(loss_fn, fed, codec, pcfg.partial_progress)
        self._pending: Dict[int, Assignment] = {}

    def submit(self, a: Assignment) -> None:
        self._pending[a.index] = a

    def result(self, index: int, timeout: Optional[float] = None) -> ClientResult:
        a = self._pending.pop(index)
        batches = self.make_batches(a.client)
        extra: Dict[str, Any] = {}
        if a.rng is not None:
            extra["rng"] = a.rng
        if self._partial:
            extra["tau"] = jnp.asarray(
                [a.local_steps or self.fed.local_steps], jnp.int32
            )
        if self._stateful:
            extra["res"] = a.residual
        deltas, aux = self._client_fn(
            a.params, jnp.asarray(a.version, jnp.int32), batches, extra
        )
        payload = jax.tree_util.tree_map(lambda d: d[0], deltas)
        return ClientResult(
            index=index,
            client=a.client,
            payload=payload,
            residual=aux["residuals"] if self._stateful else None,
            loss=float(aux["step_metrics"]["loss"][-1]),
        )


class FederationDriver(AsyncBufferAggregator):
    """Event-driven federation over a pluggable :class:`ClientBackend`.

    Results are admitted strictly in simulated-event order (the heap's pop
    order), whatever order they physically arrive in — a reorder buffer keyed
    by dispatch index. Combined with self-describing idempotent assignments
    this makes the socket runtime's final state bitwise-equal to the
    in-process simulator's for the same seeds (acceptance test).

    ``flush_deadline`` (seconds, wall clock) arms the partial-participation
    escape hatch: when the next in-order result stalls longer than the
    deadline, the server flushes whatever the buffer holds so rounds keep
    progressing; an empty-buffer deadline flush is a state no-op
    (``async_agg.flush_buffer``'s ``buf_count == 0`` guard). Leave it ``None``
    to preserve exact parity with the simulator.
    """

    def __init__(
        self,
        backend: ClientBackend,
        fed: FederatedConfig,
        acfg: AsyncAggConfig,
        pcfg: ParticipationConfig,
        *,
        flush_deadline: Optional[float] = None,
        **kw,
    ):
        # the backend must exist before super().__init__: construction fires
        # _on_dispatch for the initial K slots (or the restored manifest's)
        self.backend = backend
        self.flush_deadline = flush_deadline
        super().__init__(fed, acfg, pcfg, **kw)

    # --- dispatch → assignment -------------------------------------------
    def _on_dispatch(self, ev, snapshot, version: int) -> None:
        if not ev.completes:
            return  # unavailable/dropped clients never produce an upload
        rng = residual = None
        if self.codec is not None:
            rng = jax.random.fold_in(self._uplink_rng, ev.index)
        if self.residuals is not None:
            residual = self._res_gather(
                self.residuals, jnp.asarray(ev.client, jnp.int32)
            )
        self.backend.submit(
            Assignment(
                index=ev.index,
                client=ev.client,
                version=version,
                local_steps=(ev.local_steps if self.pcfg.partial_progress else 0),
                params=snapshot,
                residual=residual,
                rng=rng,
            )
        )

    def _notify_knobs(self, update) -> None:
        # forward applied knob updates to the backend so the server process
        # can surface the live values (Prometheus control_* gauges)
        self.backend.apply_knob_update(update, self.acfg)

    # --- event loop -------------------------------------------------------
    def _await_result(self, index: int, rows: List[Dict[str, float]]) -> ClientResult:
        while True:
            try:
                return self.backend.result(index, timeout=self.flush_deadline)
            except TimeoutError:
                # deadline-triggered partial flush: keep rounds progressing
                # while a leased-out/straggling slot stalls the event order.
                # With an empty buffer the flush is a core-state no-op, so a
                # quiet network cannot spuriously decay the outer optimizer.
                if int(self.state["buf_count"]) > 0:
                    rows.append(self._flush_row(self.flush(), deadline=True))
                else:
                    self.flush()
                    if self.tracer.enabled:
                        self.tracer.point(
                            "deadline_flush_empty", parent=self._round_span,
                            stalled_index=index,
                        )
                        self.tracer.count("deadline_flushes_empty")

    def step(self) -> List[Dict[str, float]]:
        """Advance by one completion event; returns this step's flush rows
        (possibly several: deadline flushes + the buffer-full flush)."""
        rows: List[Dict[str, float]] = []
        ev, snapshot, version = self._pop_completion()
        if ev.completes:
            staleness = int(self.state["round"]) - version
            rejected = 0 < self.acfg.max_staleness < staleness
            # unlike the in-process simulator we cannot skip a known-stale
            # slot's compute — the worker may already be training — but the
            # result is still fetched so the data cursor advances identically
            res = self._await_result(ev.index, rows)
            if rejected and self.residuals is None:
                self.work_wasted += ev.duration
                self._trace_complete(ev, "rejected_stale", staleness=staleness)
            elif (
                self.robust_state is not None
                and self.robust_state.is_quarantined(
                    int(ev.client), int(self.state["round"])
                )
            ):
                # quarantined sender: the upload was already computed and
                # fetched (the reorder buffer needs the slot retired and the
                # data cursor advanced), but it never reaches the buffer
                self.work_wasted += ev.duration
                self._trace_complete(ev, "quarantined")
            else:
                if self.residuals is not None:
                    cid = jnp.asarray(ev.client, jnp.int32)
                    row = jax.tree_util.tree_map(jnp.asarray, res.residual)
                    # the residual belongs to the client regardless of what
                    # the server decides about this upload
                    self.residuals = self._res_scatter(self.residuals, cid, row)
                    self._res_norms.append(float(self._res_norm_fn(row)))
                payload = jax.tree_util.tree_map(jnp.asarray, res.payload)
                self.uplink_bytes_total += self._bytes_per_upload
                m = self.admit(payload, version, self.event_weight(ev))
                self._note_admission(ev, m)
                rec = self._trace_admit(ev, m)
                if float(m["accepted"]) > 0:
                    self.work_completed += ev.duration
                    self._staleness.append(float(m["staleness"]))
                    self._losses.append(res.loss)
                    self._trace_complete(ev, "admitted",
                                         staleness=rec.get("staleness"))
                else:  # rejected at admission: must not skew the flush row
                    self.work_wasted += ev.duration
                    self._trace_complete(ev, "rejected",
                                         staleness=rec.get("staleness"))
            self.backend.commit(ev.index, res)
            if self.should_flush():
                rows.append(self._flush_row(self.flush()))
        else:
            self.work_wasted += ev.duration
            self._trace_complete(ev, "no_show")
        self._dispatch()
        return rows

    def run_updates(
        self,
        n_updates: int,
        on_update: Optional[Callable[[int, Dict[str, float]], None]] = None,
        max_events: Optional[int] = None,
    ) -> List[Dict[str, float]]:
        """Run until ``n_updates`` outer updates (deadline flushes count — they
        step the outer optimizer like any flush)."""
        history: List[Dict[str, float]] = []
        budget = max_events if max_events is not None else 1000 * max(1, n_updates)
        while len(history) < n_updates and budget > 0:
            budget -= 1
            for row in self.step():
                if len(history) >= n_updates:
                    break
                row["update"] = len(history)
                history.append(row)
                if on_update is not None:
                    on_update(len(history) - 1, row)
        if len(history) < n_updates:
            raise RuntimeError(
                f"event budget exhausted after {len(history)}/{n_updates} outer "
                f"updates — mostly-offline population, zero weights, or "
                f"max_staleness rejecting everything; raise max_events or "
                f"loosen the configuration"
            )
        return history
