"""Cross-process federation runtime (Photon's deployment shape, §4).

``runtime.driver`` holds the backend-pluggable :class:`FederationDriver` (the
simulated in-process timeline is one backend, the socket runtime another),
``runtime.server``/``runtime.worker`` the server and client processes,
``runtime.transport`` the length-prefixed wire format and retry/backoff
primitives, ``runtime.chaos`` the fault-injection hooks. See docs/runtime.md.
"""
from repro.runtime.chaos import ChaosConfig, ChaosMonkey, KILL_EXIT_CODE
from repro.runtime.driver import (
    Assignment,
    ClientBackend,
    ClientResult,
    FederationDriver,
    LocalClientBackend,
    build_client_phase,
)
from repro.runtime.server import SocketBackend
from repro.runtime.transport import (
    Backoff,
    Message,
    TransportError,
    connect,
    decode_msg,
    encode_msg,
    recv_msg,
    send_msg,
)
from repro.runtime.worker import ClientWorker

__all__ = [
    "Assignment",
    "Backoff",
    "ChaosConfig",
    "ChaosMonkey",
    "ClientBackend",
    "ClientResult",
    "ClientWorker",
    "FederationDriver",
    "KILL_EXIT_CODE",
    "LocalClientBackend",
    "Message",
    "SocketBackend",
    "TransportError",
    "build_client_phase",
    "connect",
    "decode_msg",
    "encode_msg",
    "recv_msg",
    "send_msg",
]
