"""Length-prefixed socket transport for the cross-process federation runtime.

Wire format (one message per frame):

    [8-byte big-endian payload length]
    [4-byte big-endian header length][header JSON (utf-8)][array blobs ...]

The header is ``{"type": ..., "meta": {...}, "arrays": [...]}`` where ``meta``
is plain JSON (ints, floats, strings, stream-cursor dicts — JSON float reprs
round-trip float64 exactly, the same discipline as the checkpoint manifests)
and ``arrays`` lists ``{"key", "dtype", "shape", "nbytes"}`` entries describing
the raw little-endian array blobs concatenated after the header, in order.

Pytrees cross the wire as *nested containers of arrays* — string-keyed dicts
plus lists/tuples (the transformer params keep per-layer ``segments`` as a
list). Each tree field flattens to ``field + SEP + k1 + SEP + k2 + ...`` keys
(``SEP`` is the ASCII unit separator, which cannot appear in parameter names);
a list/tuple element's segment is its index prefixed with ``LIST_MARK`` /
``TUPLE_MARK`` (record/group separators), so the receiver rebuilds the exact
container types with no out-of-band template. Empty containers don't survive
the wire (they carry no arrays) — no tree in this codebase has any. bfloat16
arrays are supported via ml_dtypes (the numpy view jax already depends on).

Everything here is synchronous and explicit: ``send_msg`` / ``recv_msg`` over a
connected socket, ``recv_exact`` loops until the frame is complete, and EOF or
a bad magic raises ``TransportError`` so callers can fold it into their
retry/backoff path.
"""
from __future__ import annotations

import json
import socket
import struct
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

SEP = "\x1f"  # unit separator: joins tree-path segments in array keys
LIST_MARK = "\x1e"  # path segment prefix: this node is a list element
TUPLE_MARK = "\x1d"  # path segment prefix: this node is a tuple element
_RESERVED = (SEP, LIST_MARK, TUPLE_MARK)
_LEN = struct.Struct("!Q")
_CRC = struct.Struct("!I")  # CRC-32 of the payload, between length and body
_HDR = struct.Struct("!I")
MAX_FRAME = 1 << 33  # 8 GiB sanity bound — a corrupt length must not OOM us
FRAME_OVERHEAD = _LEN.size + _CRC.size  # per-frame bytes beyond the payload


class TransportError(ConnectionError):
    """Framing/EOF/decoding failure — retryable by reconnecting."""


class FrameCorruptError(TransportError):
    """Payload CRC mismatch — the bytes on the wire are not the bytes sent.

    A subclass of :class:`TransportError` so every existing retry/backoff
    path (worker ``_rpc``, server accept loop) absorbs it by reconnecting;
    the typed class exists so tests and audits can tell corruption apart
    from a plain EOF."""


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # bfloat16 et al. — already a jax dependency

        return np.dtype(getattr(ml_dtypes, name))


def flatten_tree(tree: Any, prefix: str) -> List[Tuple[str, np.ndarray]]:
    """Nested dict/list/tuple containers of arrays → sorted ``(path, array)``
    list. Container types are encoded in the path segments themselves."""
    out: List[Tuple[str, np.ndarray]] = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            if any(c in str(k) for c in _RESERVED):
                raise ValueError(f"tree key {k!r} contains a reserved wire byte")
            out.extend(flatten_tree(tree[k], prefix + SEP + str(k)))
        return out
    if isinstance(tree, (list, tuple)):
        mark = LIST_MARK if isinstance(tree, list) else TUPLE_MARK
        for i, v in enumerate(tree):
            out.extend(flatten_tree(v, prefix + SEP + mark + str(i)))
        return out
    return [(prefix, np.asarray(tree))]


def _materialize(node: Any) -> Any:
    """Convert marker-keyed dict nodes back into the list/tuple they encode."""
    if not isinstance(node, dict):
        return node
    keys = list(node)
    for mark, ctor in ((LIST_MARK, list), (TUPLE_MARK, tuple)):
        if keys and all(k[:1] == mark for k in keys):
            order = sorted(keys, key=lambda s: int(s[1:]))
            return ctor(_materialize(node[k]) for k in order)
    return {k: _materialize(v) for k, v in node.items()}


def unflatten_tree(items: Dict[str, np.ndarray]) -> Any:
    """Inverse of :func:`flatten_tree` for one field's ``path → array`` map.

    Paths are relative to the field (empty path == the field IS one array)."""
    if list(items) == [""]:
        return items[""]
    root: Dict[str, Any] = {}
    for path, arr in items.items():
        parts = path.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return _materialize(root)


@dataclass
class Message:
    type: str
    meta: Dict[str, Any] = field(default_factory=dict)
    trees: Dict[str, Any] = field(default_factory=dict)  # field → np pytree


def encode_msg(
    mtype: str,
    meta: Optional[Dict[str, Any]] = None,
    trees: Optional[Dict[str, Any]] = None,
) -> bytes:
    arrays: List[Dict[str, Any]] = []
    blobs: List[bytes] = []
    for fname, tree in (trees or {}).items():
        if tree is None:
            continue
        if SEP in fname:
            raise ValueError(f"tree field {fname!r} contains the wire separator")
        for path, arr in flatten_tree(tree, fname):
            arr = np.ascontiguousarray(arr)
            arrays.append(
                {
                    "key": path,
                    "dtype": arr.dtype.name,
                    "shape": list(arr.shape),
                    "nbytes": int(arr.nbytes),
                }
            )
            blobs.append(arr.tobytes())
    header = json.dumps(
        {"type": mtype, "meta": meta or {}, "arrays": arrays}
    ).encode("utf-8")
    return b"".join([_HDR.pack(len(header)), header] + blobs)


def decode_msg(payload: bytes) -> Message:
    if len(payload) < _HDR.size:
        raise TransportError("frame shorter than its header-length field")
    (hlen,) = _HDR.unpack_from(payload, 0)
    try:
        header = json.loads(payload[_HDR.size : _HDR.size + hlen].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise TransportError(f"bad message header: {e}") from e
    offset = _HDR.size + hlen
    fields: Dict[str, Dict[str, np.ndarray]] = {}
    for entry in header.get("arrays", ()):
        n = int(entry["nbytes"])
        raw = payload[offset : offset + n]
        if len(raw) != n:
            raise TransportError("frame truncated inside an array blob")
        offset += n
        arr = np.frombuffer(raw, dtype=_np_dtype(entry["dtype"])).reshape(
            entry["shape"]
        )
        fname, _, rel = entry["key"].partition(SEP)
        fields.setdefault(fname, {})[rel] = arr
    trees = {fname: unflatten_tree(items) for fname, items in fields.items()}
    return Message(header["type"], header.get("meta", {}), trees)


# ---------------------------------------------------------------------------
# Socket framing
# ---------------------------------------------------------------------------


def recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(1 << 20, n - len(buf)))
        if not chunk:
            raise TransportError("connection closed mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(_LEN.pack(len(payload)) + _CRC.pack(zlib.crc32(payload)) + payload)


def recv_frame(sock: socket.socket) -> bytes:
    (n,) = _LEN.unpack(recv_exact(sock, _LEN.size))
    if n > MAX_FRAME:
        raise TransportError(f"frame length {n} exceeds MAX_FRAME")
    (crc,) = _CRC.unpack(recv_exact(sock, _CRC.size))
    payload = recv_exact(sock, n)
    if zlib.crc32(payload) != crc:
        raise FrameCorruptError(
            f"frame CRC mismatch ({len(payload)} bytes): payload corrupted in flight"
        )
    return payload


def send_msg(
    sock: socket.socket,
    mtype: str,
    meta: Optional[Dict[str, Any]] = None,
    trees: Optional[Dict[str, Any]] = None,
    chaos=None,
    tracer=None,
) -> bool:
    """Send one message; returns False when chaos injection dropped it (the
    peer sees nothing and must recover via its own timeout). A chaos *kill*
    never returns at all.

    ``tracer`` counts wire truth — actual frame bytes handed to the socket
    (payload + the length prefix + the CRC), counted only for messages that
    really go out: the chaos roll happens first, so dropped/killed sends never
    inflate ``bytes_tx``.
    """
    if chaos is not None and chaos.on_send():
        return False
    payload = encode_msg(mtype, meta, trees)
    send_frame(sock, payload)
    if tracer is not None and tracer.enabled:
        tracer.count("bytes_tx", len(payload) + FRAME_OVERHEAD)
        tracer.count("msgs_tx")
    return True


def recv_msg(sock: socket.socket, tracer=None) -> Message:
    payload = recv_frame(sock)
    if tracer is not None and tracer.enabled:
        tracer.count("bytes_rx", len(payload) + FRAME_OVERHEAD)
        tracer.count("msgs_rx")
    return decode_msg(payload)


# ---------------------------------------------------------------------------
# Bounded exponential backoff (client pull/push retry discipline)
# ---------------------------------------------------------------------------


@dataclass
class Backoff:
    """Deterministic bounded exponential backoff: base · 2^attempt, capped.

    ``give_up_after`` bounds the TOTAL time since the last success — a worker
    that cannot reach the server for that long exits instead of spinning
    forever (the supervisor decides whether to respawn it)."""

    base: float = 0.05
    cap: float = 2.0
    give_up_after: float = 60.0

    def __post_init__(self):
        self._attempt = 0
        self._since = time.monotonic()

    def reset(self) -> None:
        self._attempt = 0
        self._since = time.monotonic()

    def sleep(self) -> bool:
        """Back off once; returns False when the give-up budget is exhausted."""
        if time.monotonic() - self._since > self.give_up_after:
            return False
        time.sleep(min(self.cap, self.base * (2.0 ** self._attempt)))
        self._attempt += 1
        return True


def connect(host: str, port: int, timeout: float) -> socket.socket:
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.settimeout(timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return sock
