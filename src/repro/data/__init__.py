from repro.data.partition import (  # noqa: F401
    PILE_CATEGORIES,
    build_client_streams,
    make_heterogeneous_partition,
    validate_disjoint,
    validation_stream,
)
from repro.data.streams import (  # noqa: F401
    FileShardStream,
    MixedStream,
    SyntheticCategoryStream,
    TokenStream,
    round_batches,
)
