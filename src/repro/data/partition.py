"""The paper's heterogeneous data partitioner (§6.2.1).

Each heterogeneous dataset is first partitioned by *category* (Pile source / mC4
language), then each category is split into J × |C| disjoint *buckets*, where |C| is the
number of clients and J the maximum number of categories a client may draw upon. Each
bucket maps to AT MOST ONE client, so two clients drawing from the same category always
sample disjoint data. This implements that exact bookkeeping plus the IID fallback.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.streams import MixedStream, SyntheticCategoryStream, TokenStream

# The Pile categories used in the paper's heterogeneous experiments (§6.3).
PILE_CATEGORIES = [
    "Wikipedia(en)",
    "ArXiv",
    "PG-19",
    "HackerNews",
    "PubMedCentral",
    "FreeLaw",
    "PhilPapers",
    "StackExchange",
]


@dataclass(frozen=True)
class BucketAssignment:
    category: int
    bucket: int  # bucket index within the category (globally unique per category)


def make_heterogeneous_partition(
    n_clients: int,
    n_categories: int,
    j_max: int,
    seed: int = 0,
) -> List[List[BucketAssignment]]:
    """Assign each client up to ``j_max`` category-buckets. Buckets are never shared:
    category c has J×|C| buckets; a bucket is consumed by at most one client."""
    rng = np.random.default_rng(seed)
    next_free = np.zeros(n_categories, np.int64)  # next unassigned bucket per category
    n_buckets = j_max * n_clients
    assignments: List[List[BucketAssignment]] = []
    for _ in range(n_clients):
        cats = rng.choice(n_categories, size=min(j_max, n_categories), replace=False)
        client: List[BucketAssignment] = []
        for c in cats:
            b = int(next_free[c])
            if b >= n_buckets:
                continue  # category exhausted (cannot happen for j_max*|C| buckets)
            next_free[c] += 1
            client.append(BucketAssignment(category=int(c), bucket=b))
        assignments.append(client)
    return assignments


def validate_disjoint(assignments: Sequence[Sequence[BucketAssignment]]) -> bool:
    seen = set()
    for client in assignments:
        for a in client:
            key = (a.category, a.bucket)
            if key in seen:
                return False
            seen.add(key)
    return True


def build_client_streams(
    n_clients: int,
    seq_len: int,
    vocab_size: int,
    *,
    heterogeneous: bool,
    n_categories: int = len(PILE_CATEGORIES),
    j_max: int = 1,
    seed: int = 0,
) -> List[TokenStream]:
    """Materialize one stream per client.

    IID mode (paper's C4 experiments): every client draws from the same distribution
    but from disjoint buckets. Heterogeneous (Pile) mode: clients draw from distinct
    category buckets via the J×|C| partitioner.
    """
    if not heterogeneous:
        return [
            SyntheticCategoryStream(
                seq_len, vocab_size, category=0, bucket=i, n_categories=1
            )
            for i in range(n_clients)
        ]
    assignments = make_heterogeneous_partition(n_clients, n_categories, j_max, seed)
    assert validate_disjoint(assignments)
    streams: List[TokenStream] = []
    for ci, client in enumerate(assignments):
        subs = [
            SyntheticCategoryStream(
                seq_len, vocab_size, category=a.category, bucket=a.bucket,
                n_categories=n_categories,
            )
            for a in client
        ]
        streams.append(subs[0] if len(subs) == 1 else MixedStream(subs, seed=seed + ci))
    return streams


def validation_stream(seq_len: int, vocab_size: int, heterogeneous: bool,
                      n_categories: int = len(PILE_CATEGORIES)) -> TokenStream:
    """Held-out split: the validation bucket is a reserved bucket id (2**20) no client
    can be assigned, preserving the paper's held-out guarantee (§4.2)."""
    if not heterogeneous:
        return SyntheticCategoryStream(seq_len, vocab_size, category=0,
                                       bucket=1 << 20, n_categories=1)
    subs = [
        SyntheticCategoryStream(seq_len, vocab_size, category=c, bucket=1 << 20,
                                n_categories=n_categories)
        for c in range(n_categories)
    ]
    return MixedStream(subs, seed=12345)
