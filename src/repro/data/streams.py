"""Streaming data sources — the Photon Data Source abstraction.

A ``TokenStream`` continuously yields fixed-length token sequences and carries a
resumable cursor (the paper's client checkpoints track the data-loading index state,
§4.1). Streams compose: a client binds one or more streams (``MixedStream``), matching
Photon's "clients draw upon arbitrary data streams with full control over sampling"
(§5.2). Synthetic category-structured generators stand in for the
C4 / Pile shard files so that every pipeline stage is runnable offline; a file-backed
stream reads pre-tokenized .npy shards with identical semantics.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass
class StreamState:
    cursor: int = 0
    epoch: int = 0


class TokenStream:
    """Base: infinite stream of (seq_len,) int32 token sequences with resumable state."""

    def __init__(self, seq_len: int):
        self.seq_len = seq_len
        self.state = StreamState()

    def next_batch(self, batch_size: int) -> np.ndarray:
        out = np.stack([self._next_seq() for _ in range(batch_size)])
        return out.astype(np.int32)

    def _next_seq(self) -> np.ndarray:
        raise NotImplementedError

    # --- checkpointing -------------------------------------------------
    def state_dict(self) -> dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: dict) -> None:
        self.state = StreamState(**d)


class SyntheticCategoryStream(TokenStream):
    """Category-conditioned synthetic language: each category has its own Zipfian
    unigram distribution over a vocabulary slice plus a small Markov structure, giving
    learnable, *statistically heterogeneous* data (different categories model the
    paper's Pile subsets: Wikipedia / ArXiv / PG-19 / ...).

    Deterministic in (category, bucket, cursor) — replaying from a checkpointed cursor
    reproduces the exact byte stream, like a seekable MosaicML StreamingDataset shard.
    """

    def __init__(
        self,
        seq_len: int,
        vocab_size: int,
        category: int,
        bucket: int = 0,
        n_categories: int = 8,
        zipf_a: float = 1.2,
    ):
        super().__init__(seq_len)
        self.vocab_size = vocab_size
        self.category = category
        self.bucket = bucket
        self.n_categories = n_categories
        # category-specific vocabulary emphasis blended with a shared core — natural
        # text domains overlap heavily (function words) while differing in topical
        # vocabulary; fully disjoint vocabularies would overstate heterogeneity.
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        base = ranks ** (-zipf_a)
        base /= base.sum()
        shift = (category * vocab_size) // max(1, n_categories)
        specific = np.roll(base, shift)
        self._probs = 0.55 * base + 0.45 * specific
        self._probs /= self._probs.sum()

    def _next_seq(self) -> np.ndarray:
        seed = np.random.SeedSequence(
            [self.category, self.bucket, self.state.epoch, self.state.cursor]
        )
        rng = np.random.default_rng(seed)
        self.state.cursor += 1
        toks = rng.choice(self.vocab_size, size=self.seq_len, p=self._probs)
        # light Markov structure: every other token correlates with its predecessor
        toks[1::2] = (toks[0::2][: len(toks[1::2])] + self.category + 1) % self.vocab_size
        return toks


class FileShardStream(TokenStream):
    """Reads pre-tokenized shards (one flat .npy of int32 tokens per shard file)."""

    def __init__(self, seq_len: int, shard_paths: Sequence[str]):
        super().__init__(seq_len)
        if not shard_paths:
            raise ValueError("FileShardStream needs at least one shard")
        self.shard_paths = list(shard_paths)
        self._shards = [np.load(p, mmap_mode="r") for p in self.shard_paths]
        self._sizes = [len(s) // seq_len for s in self._shards]
        self._total = sum(self._sizes)

    def _next_seq(self) -> np.ndarray:
        i = self.state.cursor % self._total
        self.state.cursor += 1
        if self.state.cursor % self._total == 0:
            self.state.epoch += 1
        for shard, n in zip(self._shards, self._sizes):
            if i < n:
                return np.asarray(shard[i * self.seq_len : (i + 1) * self.seq_len])
            i -= n
        raise AssertionError


class MixedStream(TokenStream):
    """A client's merged data stream (Algorithm 1, L.13 BindStream): samples among the
    bound sub-streams with given weights; deterministic in the cursor."""

    def __init__(self, streams: List[TokenStream], weights: Optional[Sequence[float]] = None, seed: int = 0):
        assert streams
        super().__init__(streams[0].seq_len)
        self.streams = streams
        w = np.asarray(weights if weights is not None else [1.0] * len(streams), np.float64)
        self.weights = w / w.sum()
        self.seed = seed

    def _next_seq(self) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, self.state.cursor]))
        self.state.cursor += 1
        idx = rng.choice(len(self.streams), p=self.weights)
        return self.streams[idx]._next_seq()

    def state_dict(self) -> dict:
        return {
            "cursor": self.state.cursor,
            "epoch": self.state.epoch,
            "sub": [s.state_dict() for s in self.streams],
        }

    def load_state_dict(self, d: dict) -> None:
        self.state = StreamState(cursor=d["cursor"], epoch=d["epoch"])
        for s, sd in zip(self.streams, d["sub"]):
            s.load_state_dict(sd)


def round_batches(
    streams: List[TokenStream], tau: int, per_client_batch: int
) -> Dict[str, np.ndarray]:
    """Materialize one federated round's batches: tokens (τ, C, B, S)."""
    c = len(streams)
    seq = streams[0].seq_len
    out = np.empty((tau, c, per_client_batch, seq), np.int32)
    for ci, s in enumerate(streams):
        for t in range(tau):
            out[t, ci] = s.next_batch(per_client_batch)
    return {"tokens": out}
