"""The :class:`FederationController`: windowed metric intake for a policy.

The controller is the piece the aggregators actually hold: it filters each
round/flush metrics row down to the control-relevant keys, maintains a bounded
window of recent rows, invokes its :class:`~repro.control.policy.ControlPolicy`
every ``interval`` observations, and records every applied update in a history
(the audit trail the adaptive-control benchmark serializes). Its full state —
window, counters, history, the policy's knob state — is one JSON-able dict
(``state_dict``), persisted under the ``"control"`` key of the aggregator's
checkpoint manifest so a killed governed run resumes bitwise: the restored
controller has seen exactly the rows the original saw, so every future knob
decision replays identically.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.control.policy import (
    CONTROL_POLICIES,
    ControlPolicy,
    KnobUpdate,
    StaticPolicy,
)

#: the metric keys a policy may consume — rows are filtered to these so the
#: checkpointed window stays small and JSON-clean (floats and float lists only)
CONTROL_KEYS = (
    "admitted_staleness",
    "buffer_fill",
    "buffer_occupancy",
    "staleness_mean",
    "staleness_max",
    "sim_time",
    "effective_k",
    "round_time_sim",
    "partial_tau_mean",
    "partial_rescued_work",
    "partial_wasted_work",
    "train_loss",
    "train_loss_mean",
)


def _filter_row(row: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k in CONTROL_KEYS:
        v = row.get(k)
        if v is None:
            continue
        if isinstance(v, (list, tuple)):
            out[k] = [float(x) for x in v]
        else:
            out[k] = float(v)
    return out


class FederationController:
    """Window + cadence + audit trail around one :class:`ControlPolicy`."""

    def __init__(self, policy: ControlPolicy, *, window: int = 4, interval: int = 1):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if interval < 1:
            raise ValueError(f"interval must be >= 1, got {interval}")
        self.policy = policy
        self.window = int(window)
        self.interval = int(interval)
        self.rows: List[Dict[str, Any]] = []
        self.seen = 0  # observations ever fed in (drives the cadence)
        self.n_updates = 0  # KnobUpdates actually applied
        self.history: List[Dict[str, Any]] = []  # audit trail of every update

    @property
    def enabled(self) -> bool:
        """A static controller is indistinguishable from no controller: the
        aggregators skip ``observe`` entirely, preserving bitwise identity."""
        return self.policy.name != StaticPolicy.name

    def knobs(self) -> Dict[str, float]:
        return self.policy.knobs()

    def observe(self, row: Dict[str, Any]) -> Optional[KnobUpdate]:
        """Feed one metrics row; returns the policy's update when the cadence
        fires and the policy moves a knob."""
        if not self.enabled:
            return None
        self.rows.append(_filter_row(row))
        del self.rows[: -self.window]
        self.seen += 1
        if self.seen % self.interval != 0:
            return None
        update = self.policy.observe(list(self.rows))
        if update is None:
            return None
        self.n_updates += 1
        self.history.append(
            {
                "observation": self.seen,
                "knobs": update.knob_dict(),
                "evidence": dict(update.evidence),
            }
        )
        return update

    # --- resume round-trip (rides the checkpoint manifest) -----------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "policy": self.policy.name,
            "window": self.window,
            "interval": self.interval,
            "rows": [dict(r) for r in self.rows],
            "seen": self.seen,
            "n_updates": self.n_updates,
            "history": [dict(h) for h in self.history],
            "state": self.policy.state_dict(),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if state.get("policy") != self.policy.name:
            raise ValueError(
                f"checkpointed controller ran --control {state.get('policy')!r} "
                f"but this run asked for --control {self.policy.name!r} — the "
                f"knob trajectory would diverge from the original run"
            )
        self.window = int(state["window"])
        self.interval = int(state["interval"])
        self.rows = [dict(r) for r in state["rows"]]
        self.seen = int(state["seen"])
        self.n_updates = int(state["n_updates"])
        self.history = [dict(h) for h in state["history"]]
        self.policy.load_state_dict(state["state"])


def build_controller(
    policy: str, *, window: int = 4, interval: int = 1, **policy_kwargs
) -> Optional[FederationController]:
    """``--control`` factory. Returns ``None`` for ``static``: no controller
    object at all, so the default path carries zero new state (checkpoints stay
    byte-identical to the uncontrolled schema)."""
    if policy not in CONTROL_POLICIES:
        raise ValueError(
            f"unknown control policy {policy!r}; choose from "
            f"{sorted(CONTROL_POLICIES)}"
        )
    if policy == StaticPolicy.name:
        return None
    return FederationController(
        CONTROL_POLICIES[policy](**policy_kwargs), window=window, interval=interval
    )
