"""Closed-loop aggregation control policies (ROADMAP "Adaptive aggregation
control"; Photon's deployment-side control plane, arXiv 2411.02908 §5).

The paper's resilience claims hold *when the server-side knobs match the
observed system*: a buffer sized for a calm population thrashes under heavy
stragglers, a deadline tuned for homogeneous hardware wastes every slow
client's round. This module turns the telemetry the obs layer already exports
(staleness histograms, effective-K, rescued/wasted partial work) back into
knob settings, behind one seam:

    ControlPolicy.observe(metrics_window) -> Optional[KnobUpdate]

A policy is a small pure-host state machine: it sees a bounded window of the
aggregator's per-update metric rows and either returns a :class:`KnobUpdate`
(the new knob values plus the evidence that triggered them) or ``None``.
Everything is stdlib+numpy, JSON-serializable (``state_dict`` /
``load_state_dict`` round-trip exactly — controller state rides the existing
checkpoint manifest), and deterministic: the same metric history always
produces the same knob trajectory, which is what makes a governed run
kill/``--resume`` bitwise.

Knob changes are QUANTIZED to bucketed grids so the jitted aggregation steps
recompile at most a handful of times per run: ``staleness_alpha`` snaps to a
1/16 grid in [0, 2], ``buffer_size`` moves along powers of two, cohort size
moves in steps of 2. The aggregators only ever apply updates between jitted
steps (round/flush boundaries), so a knob change is a host-side rebuild, never
a mid-graph mutation.

Policies:

* :class:`StaticPolicy` — the identity: observes nothing, changes nothing.
  ``--control static`` (and the flag omitted) is bitwise PR-7 behavior.
* :class:`StalenessGovernor` (async) — drives ``staleness_alpha`` and
  ``buffer_size`` toward a target admitted-staleness quantile read off the
  cumulative histogram. Staleness is measured in server rounds, so a large
  buffer (rare flushes) *lowers* the observed quantile: below-target staleness
  means headroom — shrink the buffer (more frequent outer updates) and relax
  the discount; above-target means the buffer absorbs ancient work — raise α
  and grow the buffer so each flush averages more, fresher mass.
* :class:`CohortTuner` (sync) — adjusts the straggler deadline and
  ``clients_per_round`` from the realized effective-K fraction and the
  partial-progress rescued/wasted-work monitors: too few contributors →
  loosen the deadline (then widen the cohort once the deadline saturates);
  over-provisioned rounds → tighten the deadline (then shrink the cohort).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.metrics.fedmetrics import (
    histogram_quantile,
    staleness_hist_counts,
    window_concat,
    window_mean,
)

#: grid step for the staleness-discount exponent: 1/16 is exactly
#: representable in binary, so quantized values round-trip JSON/float exactly
ALPHA_STEP = 0.0625
ALPHA_MAX = 2.0
#: grid step for the deadline knob (median-client-round units)
DEADLINE_STEP = 0.0625


def _snap(value: float, step: float) -> float:
    """Quantize onto the bucketed grid that bounds recompile churn."""
    return round(float(value) / step) * step


def _pow2_toward(current: int, up: bool, lo: int, hi: int) -> int:
    """Next power-of-two buffer size in the given direction, clipped."""
    nxt = current * 2 if up else max(1, current // 2)
    return max(lo, min(hi, nxt))


@dataclass(frozen=True)
class KnobUpdate:
    """One applied (or to-apply) knob change plus its triggering evidence.

    Only the knobs a policy actually moved are set; ``None`` means "leave this
    knob alone". ``evidence`` carries the observed metrics that justified the
    move — it rides the obs event and the benchmark JSON verbatim, so every
    knob change in a trace is auditable."""

    staleness_alpha: Optional[float] = None
    buffer_size: Optional[int] = None
    clients_per_round: Optional[int] = None
    deadline: Optional[float] = None
    evidence: Dict[str, float] = field(default_factory=dict)

    @property
    def changed(self) -> bool:
        return any(
            v is not None
            for v in (
                self.staleness_alpha,
                self.buffer_size,
                self.clients_per_round,
                self.deadline,
            )
        )

    def knob_dict(self) -> Dict[str, float]:
        """The set knobs as a flat float dict (event attrs / CSV columns)."""
        out: Dict[str, float] = {}
        for k in ("staleness_alpha", "buffer_size", "clients_per_round", "deadline"):
            v = getattr(self, k)
            if v is not None:
                out[k] = float(v)
        return out


class ControlPolicy:
    """The policy seam: a deterministic, JSON-serializable knob state machine.

    ``observe`` sees the controller's metrics window (newest row last) and
    returns a :class:`KnobUpdate` when the policy moves a knob, else ``None``.
    ``knobs()`` reports the policy's CURRENT knob values — after a resume this
    is what the trainer rebuilds the aggregator configuration from."""

    name = "base"

    def observe(self, window: List[Dict[str, Any]]) -> Optional[KnobUpdate]:
        raise NotImplementedError

    def knobs(self) -> Dict[str, float]:
        return {}

    def state_dict(self) -> Dict[str, Any]:
        """JSON-able policy state; floats round-trip exactly through the
        checkpoint manifest's JSON reprs."""
        return {k: v for k, v in vars(self).items() if not k.startswith("_")}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        for k, v in state.items():
            if not hasattr(self, k):
                raise ValueError(f"{self.name} policy has no state field {k!r}")
            setattr(self, k, v)


class StaticPolicy(ControlPolicy):
    """The identity policy: never observes, never updates — ``--control
    static`` is bitwise the uncontrolled run (asserted in tests)."""

    name = "static"

    def observe(self, window: List[Dict[str, Any]]) -> Optional[KnobUpdate]:
        return None


class StalenessGovernor(ControlPolicy):
    """Async knob governor: hold the admitted-staleness quantile at a target.

    Control law (proportional, on the bucket-edge quantile ``q_obs`` from
    :func:`histogram_quantile`):

        error = q_obs - target
        |error| <= deadband        -> no update
        error > deadband  (stale)  -> alpha += gain * error (stronger discount)
                                      buffer *= 2 (fresher mass per flush)
        error < -deadband (fresh)  -> alpha += gain * error (relax discount)
                                      buffer /= 2 (flush more often)

    α is clipped to [0, ALPHA_MAX] and snapped to the 1/16 grid; the buffer
    moves on powers of two in [buffer_min, buffer_max]. Because staleness is
    counted in server rounds, shrinking the buffer RAISES future staleness
    (more version bumps per unit time) — the loop converges on the target from
    either side instead of ratcheting. A below-target reading is headroom: the
    operator tolerates staler deltas than the system produces, so the governor
    trades that slack for update frequency (the adaptive-control benchmark's
    win condition)."""

    name = "staleness"

    def __init__(
        self,
        *,
        staleness_alpha: float = 0.5,
        buffer_size: int = 4,
        target: float = 1.0,
        quantile: float = 0.9,
        gain: float = 0.5,
        deadband: float = 0.25,
        buffer_min: int = 1,
        buffer_max: Optional[int] = None,
    ):
        if not 0.0 < quantile <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {quantile}")
        if target < 0.0:
            raise ValueError(f"target staleness must be >= 0, got {target}")
        self.staleness_alpha = _snap(min(max(staleness_alpha, 0.0), ALPHA_MAX), ALPHA_STEP)
        self.buffer_size = int(buffer_size)
        self.target = float(target)
        self.quantile = float(quantile)
        self.gain = float(gain)
        self.deadband = float(deadband)
        self.buffer_min = int(buffer_min)
        self.buffer_max = int(buffer_max if buffer_max is not None else buffer_size)

    def knobs(self) -> Dict[str, float]:
        return {
            "staleness_alpha": float(self.staleness_alpha),
            "buffer_size": float(self.buffer_size),
        }

    def observe(self, window: List[Dict[str, Any]]) -> Optional[KnobUpdate]:
        staleness = window_concat(window, "admitted_staleness")
        if not staleness:
            return None
        counts = staleness_hist_counts(staleness)
        q_obs = histogram_quantile(counts, self.quantile)
        error = q_obs - self.target
        evidence = {
            "staleness_quantile": float(q_obs),
            "quantile": self.quantile,
            "target": self.target,
            "error": float(error),
            "n_admitted": float(len(staleness)),
            "buffer_occupancy": window_mean(window, "buffer_occupancy", 1.0),
        }
        if abs(error) <= self.deadband:
            return None
        alpha = _snap(
            min(max(self.staleness_alpha + self.gain * error, 0.0), ALPHA_MAX),
            ALPHA_STEP,
        )
        buffer = _pow2_toward(
            self.buffer_size, up=error > 0, lo=self.buffer_min, hi=self.buffer_max
        )
        update = KnobUpdate(
            staleness_alpha=alpha if alpha != self.staleness_alpha else None,
            buffer_size=buffer if buffer != self.buffer_size else None,
            evidence=evidence,
        )
        if not update.changed:
            return None  # both knobs pinned at their bounds
        self.staleness_alpha = alpha
        self.buffer_size = buffer
        return update


class CohortTuner(ControlPolicy):
    """Sync knob tuner: hold the realized effective-K fraction at a target.

    Reads the per-round ``effective_k`` (contributors after availability,
    dropout and the straggler rule) plus the partial-progress rescued/wasted
    monitors, and compares ``effective_k / clients_per_round`` to ``target``:

        fraction < target - deadband (starved rounds)
            -> deadline *= (1 + gain): give stragglers more time;
               once the deadline saturates at ``deadline_max``, widen the
               cohort by ``k_step`` instead (more candidates per round)
        fraction > target + deadband (over-provisioned rounds)
            -> deadline *= (1 - gain): stop paying for slack;
               once the deadline saturates at ``deadline_min``, shrink the
               cohort

    The deadline snaps to a 1/16 grid (a host-side scalar — free to change);
    ``clients_per_round`` moves in even steps within [k_min, population] and
    is the one sync knob that re-traces the round jit (a bucketed cohort
    shape, a handful per run)."""

    name = "cohort"

    def __init__(
        self,
        *,
        clients_per_round: int,
        deadline: float,
        population: int,
        target: float = 0.9,
        gain: float = 0.25,
        deadband: float = 0.05,
        deadline_min: float = 0.25,
        deadline_max: float = 4.0,
        k_min: int = 2,
        k_step: int = 2,
    ):
        if deadline <= 0.0:
            raise ValueError(
                "cohort control needs a finite straggler deadline to tune "
                f"(got {deadline}) — pick a straggler profile or --deadline"
            )
        if not 0.0 < target <= 1.0:
            raise ValueError(f"target effective-K fraction must be in (0, 1], got {target}")
        self.clients_per_round = int(clients_per_round)
        self.deadline = _snap(deadline, DEADLINE_STEP)
        self.population = int(population)
        self.target = float(target)
        self.gain = float(gain)
        self.deadband = float(deadband)
        self.deadline_min = float(deadline_min)
        self.deadline_max = float(deadline_max)
        self.k_min = int(k_min)
        self.k_step = int(k_step)

    def knobs(self) -> Dict[str, float]:
        return {
            "clients_per_round": float(self.clients_per_round),
            "deadline": float(self.deadline),
        }

    def observe(self, window: List[Dict[str, Any]]) -> Optional[KnobUpdate]:
        eff_k = window_mean(window, "effective_k", default=-1.0)
        if eff_k < 0.0:
            return None  # window carries no participation rows yet
        fraction = eff_k / float(self.clients_per_round)
        error = fraction - self.target
        evidence = {
            "effective_k_mean": float(eff_k),
            "effective_k_fraction": float(fraction),
            "target": self.target,
            "error": float(error),
            "rescued_work": window_mean(window, "partial_rescued_work", 0.0),
            "wasted_work": window_mean(window, "partial_wasted_work", 0.0),
        }
        if abs(error) <= self.deadband:
            return None
        starved = error < 0.0
        factor = (1.0 + self.gain) if starved else (1.0 - self.gain)
        deadline = _snap(
            min(max(self.deadline * factor, self.deadline_min), self.deadline_max),
            DEADLINE_STEP,
        )
        k = self.clients_per_round
        if deadline == self.deadline:
            # deadline pinned at its bound: move the cohort-size knob instead
            k = k + self.k_step if starved else k - self.k_step
            k = max(self.k_min, min(self.population, k))
        update = KnobUpdate(
            deadline=deadline if deadline != self.deadline else None,
            clients_per_round=k if k != self.clients_per_round else None,
            evidence=evidence,
        )
        if not update.changed:
            return None  # every knob pinned at its bounds
        self.deadline = deadline
        self.clients_per_round = k
        return update


#: registry behind ``--control {static,staleness,cohort}``
CONTROL_POLICIES = {
    StaticPolicy.name: StaticPolicy,
    StalenessGovernor.name: StalenessGovernor,
    CohortTuner.name: CohortTuner,
}
