"""Closed-loop aggregation control: telemetry in, knob updates out
(docs/control.md)."""
from repro.control.controller import (  # noqa: F401
    CONTROL_KEYS,
    FederationController,
    build_controller,
)
from repro.control.policy import (  # noqa: F401
    ALPHA_MAX,
    ALPHA_STEP,
    CONTROL_POLICIES,
    CohortTuner,
    ControlPolicy,
    DEADLINE_STEP,
    KnobUpdate,
    StalenessGovernor,
    StaticPolicy,
)
