"""Byzantine-resilient aggregation (core/robust.py + docs/robustness.md).

Keystone identities:
  - every defense OFF is BITWISE the undefended round — sync, async and
    tiled: an inactive ``RobustAggConfig`` installs no apply_fn at all;
  - the delta screen catches what it must: non-finite deltas never touch the
    model (sync zero-weight + sanitize, async door rejection), and the
    NaN-aware aggregation metrics stay finite with a poisoned lane;
  - robust rules beat the plain mean under attack on constructed cohorts
    (trimmed/median ignore the outlier lane entirely; normclip bounds it);
  - tiled folds reproduce the flat robust rules (allclose — the summation
    order differs by construction);
  - quarantine/guard/rollback state rides the checkpoint manifest: a
    killed-and-resumed defended run is bitwise the uninterrupted one, and a
    legacy (pre-robust) manifest restores to a clean slate;
  - the CRC-framed transport turns in-flight byte flips into a typed,
    retryable error instead of feeding garbage to the decoder.
"""
import json
import socket

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_batches, make_params, quad_loss, sgd_inner

from repro.checkpoint import CheckpointManager
from repro.core import (
    AsyncAggConfig,
    AsyncFederationDriver,
    FederatedConfig,
    OuterOptConfig,
    ParticipationConfig,
    RobustAggConfig,
    RobustState,
    SyncAggregator,
    aggregation_metrics,
    corrupt_tree,
    make_byzantine_fn,
    make_robust_apply_fn,
    masked_median,
    screen_cohort,
    trimmed_mean_clients,
    median_clients,
    normclip_scale,
    sanitize_deltas,
    apply_aggregate,
    init_federated_state,
)


def _fed(c, tau, **kw):
    return FederatedConfig(
        clients_per_round=c, local_steps=tau, inner=sgd_inner(),
        outer=OuterOptConfig(name="fedavg", lr=1.0), **kw,
    )


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_trees_close(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                                   atol=atol)


# ---------------------------------------------------------------------------
# screen + rule primitives
# ---------------------------------------------------------------------------


def test_masked_median_matches_numpy_over_valid_lanes():
    x = jnp.asarray([5.0, 1.0, 9.0, 3.0, 7.0])
    mask = jnp.asarray([True, True, False, True, True])
    assert float(masked_median(x, mask)) == float(np.median([5.0, 1.0, 3.0, 7.0]))
    assert float(masked_median(x, jnp.ones(5, bool))) == 5.0
    assert float(masked_median(x, jnp.zeros(5, bool))) == 0.0


def test_screen_cohort_flags_nonfinite_and_outliers_only():
    norms = jnp.asarray([1.0, 1.1, 0.9, 1.05, jnp.nan, 64.0])
    w = jnp.ones(6)
    new_w, flagged, finite = screen_cohort(norms, w, z=6.0)
    np.testing.assert_array_equal(
        np.asarray(flagged), [False, False, False, False, True, True]
    )
    np.testing.assert_array_equal(
        np.asarray(finite), [True, True, True, True, False, True]
    )
    np.testing.assert_array_equal(np.asarray(new_w), [1, 1, 1, 1, 0, 0])

    # a clean, tight cohort passes through BITWISE (all-False where is exact)
    clean = jnp.asarray([1.0, 1.1, 0.9, 1.05])
    w4 = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    kept, flagged, _ = screen_cohort(clean, w4, z=6.0)
    assert not bool(flagged.any())
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(w4))


def test_sanitize_deltas_zeroes_only_nonfinite_lanes():
    deltas = {"w": jnp.asarray([[1.0, 2.0], [jnp.nan, jnp.inf], [3.0, 4.0]])}
    finite = jnp.asarray([True, False, True])
    out = sanitize_deltas(deltas, finite)
    np.testing.assert_array_equal(
        np.asarray(out["w"]), [[1.0, 2.0], [0.0, 0.0], [3.0, 4.0]]
    )
    # all-finite is bitwise passthrough
    clean = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
    out2 = sanitize_deltas(clean, jnp.asarray([True, True]))
    np.testing.assert_array_equal(np.asarray(out2["w"]), np.asarray(clean["w"]))


def test_trimmed_mean_and_median_match_numpy_reference():
    rng = np.random.default_rng(0)
    vals = rng.normal(size=(7, 3, 2)).astype(np.float32)
    deltas = {"w": jnp.asarray(vals)}
    admit = jnp.ones(7, bool)

    got = np.asarray(trimmed_mean_clients(deltas, admit, trim_fraction=0.2)["w"])
    k = int(0.2 * 7)  # = 1 from each tail
    srt = np.sort(vals, axis=0)
    np.testing.assert_allclose(got, srt[k:7 - k].mean(axis=0), rtol=1e-6)

    med = np.asarray(median_clients(deltas, admit)["w"])
    np.testing.assert_allclose(med, np.median(vals, axis=0), rtol=1e-6)

    # non-admitted lanes are excluded from both
    admit2 = jnp.asarray([True] * 5 + [False] * 2)
    med2 = np.asarray(median_clients(deltas, admit2)["w"])
    np.testing.assert_allclose(med2, np.median(vals[:5], axis=0), rtol=1e-6)


def test_normclip_scale_bounds_outliers_and_zeroes_unadmitted():
    norms = jnp.asarray([1.0, 2.0, 100.0, jnp.inf])
    admit = jnp.asarray([True, True, True, False])
    s = np.asarray(normclip_scale(norms, admit, tau=4.0))
    np.testing.assert_allclose(s, [1.0, 1.0, 0.04, 0.0], rtol=1e-6)


def test_robust_rules_resist_scale_attack_where_mean_fails():
    """One attacker amplifies its delta ×1000: the plain mean is dragged far
    off the honest mean, trimmed/median stay within the honest spread."""
    rng = np.random.default_rng(1)
    honest = rng.normal(size=(7, 4)).astype(np.float32)
    attack = np.concatenate([honest, honest[:1] * 1000.0], axis=0)
    deltas = {"w": jnp.asarray(attack)}
    admit = jnp.ones(8, bool)
    honest_mean = honest.mean(axis=0)

    plain = np.asarray(deltas["w"]).mean(axis=0)
    trimmed = np.asarray(trimmed_mean_clients(deltas, admit, trim_fraction=0.15)["w"])
    med = np.asarray(median_clients(deltas, admit)["w"])

    assert np.abs(plain - honest_mean).max() > 10.0
    assert np.abs(trimmed - honest_mean).max() < 1.0
    assert np.abs(med - honest_mean).max() < 2.0


# ---------------------------------------------------------------------------
# NaN-aware aggregation metrics (satellite: NaN propagation fix)
# ---------------------------------------------------------------------------


def test_aggregation_metrics_survive_nonfinite_lane():
    norms = jnp.asarray([1.0, 1.0, jnp.nan])
    pg = jnp.asarray(0.5)
    m = aggregation_metrics(norms, pg, None)
    assert float(m["nonfinite_deltas"]) == 1.0
    for key in ("client_delta_norm_mean", "client_consensus",
                "effective_clients", "weight_entropy"):
        assert np.isfinite(float(m[key])), key
    # the poisoned lane is excluded from the norm mean, not averaged in
    np.testing.assert_allclose(float(m["client_delta_norm_mean"]), 1.0,
                               rtol=1e-6)
    assert float(m["effective_clients"]) == 2.0

    # weighted variant: the poisoned lane's weight drops out of every sum
    w = jnp.asarray([1.0, 1.0, 5.0])
    mw = aggregation_metrics(norms, pg, w)
    assert float(mw["nonfinite_deltas"]) == 1.0
    for key in ("client_delta_norm_mean", "client_consensus",
                "weight_entropy"):
        assert np.isfinite(float(mw[key])), key
    assert float(mw["effective_clients"]) == 2.0

    # all-finite cohorts are numerically unchanged (the where is all-True)
    clean = jnp.asarray([1.0, 1.0, 1.0])
    mc = aggregation_metrics(clean, pg, None)
    assert float(mc["nonfinite_deltas"]) == 0.0
    np.testing.assert_allclose(float(mc["client_delta_norm_mean"]), 1.0,
                               rtol=1e-6)


def test_window_reductions_skip_nonfinite():
    from repro.metrics import window_mean
    from repro.metrics.fedmetrics import window_concat

    rows = [{"a": 1.0}, {"a": float("nan")}, {"a": 3.0}, {"a": float("inf")}]
    assert window_mean(rows, "a") == 2.0
    rows2 = [{"s": [0.0, float("nan"), 2.0]}]
    assert window_concat(rows2, "s") == [0.0, 2.0]


# ---------------------------------------------------------------------------
# robust apply_fn at the aggregation seam
# ---------------------------------------------------------------------------


def _state(c, seed=3):
    return init_federated_state(
        _fed(c, 2), make_params(), rng=jax.random.PRNGKey(seed)
    )


def test_inactive_robust_apply_fn_refuses_construction():
    with pytest.raises(ValueError):
        make_robust_apply_fn(_fed(4, 2), RobustAggConfig())


def test_robust_apply_none_rule_with_screen_matches_plain_when_clean():
    """Screen on, rule none, clean tight cohort: the screen flags nobody and
    the aggregate equals the plain weighted mean bitwise (all-True wheres)."""
    c = 4
    fed = _fed(c, 2)
    deltas = {"w": jax.random.normal(jax.random.PRNGKey(7), (c, 4, 4)) * 0.01}
    w = jnp.ones(c)
    s0, m0 = apply_aggregate(fed, _state(c), deltas, client_weights=w)
    fn = make_robust_apply_fn(fed, RobustAggConfig(screen=True))
    s1, m1 = fn(fed, _state(c), deltas, client_weights=w)
    _assert_trees_equal(s0["params"], s1["params"])
    assert float(m1["screened_clients"]) == 0.0


def test_robust_apply_screen_neutralizes_nan_lane():
    c = 4
    fed = _fed(c, 2)
    good = jax.random.normal(jax.random.PRNGKey(8), (c, 4, 4)) * 0.01
    deltas = {"w": good.at[1].set(jnp.nan)}
    fn = make_robust_apply_fn(fed, RobustAggConfig(screen=True))
    s1, m1 = fn(fed, _state(c), deltas, client_weights=jnp.ones(c))
    assert bool(jnp.all(jnp.isfinite(s1["params"]["w"])))
    assert float(m1["screened_clients"]) >= 1.0
    assert float(m1["nonfinite_deltas"]) == 1.0
    assert bool(np.asarray(m1["screen_mask"])[1])
    # plain mean on the same cohort is destroyed
    s0, _ = apply_aggregate(fed, _state(c), deltas, client_weights=jnp.ones(c))
    assert not bool(jnp.all(jnp.isfinite(s0["params"]["w"])))


# ---------------------------------------------------------------------------
# bitwise-off identity + tiled composition through the SyncAggregator
# ---------------------------------------------------------------------------


def _sync(robust=None, cohort_tile=None, seed=0, pop=8, c=4, tau=2):
    fed = _fed(c, tau)
    pcfg = ParticipationConfig(population=pop, clients_per_round=c)
    return SyncAggregator(
        quad_loss, fed, pcfg, seed=seed, params=make_params(),
        rng=jax.random.PRNGKey(seed + 1), robust=robust, cohort_tile=cohort_tile,
    )


@pytest.mark.parametrize("tile", [None, 2])
def test_sync_robust_fully_off_is_bitwise_plain(tile):
    a = _sync(cohort_tile=tile)
    b = _sync(robust=RobustAggConfig(), cohort_tile=tile)
    for r in range(3):
        batches = make_batches(2, 4, seed=r)
        ma = a.run_round(batches, a.plan(r))
        mb = b.run_round(batches, b.plan(r))
    _assert_trees_equal(a.state, b.state)
    assert float(ma["pseudo_grad_norm"]) == float(mb["pseudo_grad_norm"])


@pytest.mark.parametrize("rule", ["trimmed", "median"])
def test_tiled_robust_rule_matches_flat(rule):
    cfg = RobustAggConfig(rule=rule, trim_fraction=0.25)
    flat, tiled = _sync(robust=cfg), _sync(robust=cfg, cohort_tile=2)
    for r in range(2):
        batches = make_batches(2, 4, seed=r)
        flat.run_round(batches, flat.plan(r))
        tiled.run_round(batches, tiled.plan(r))
    _assert_trees_close(flat.state["params"], tiled.state["params"])


def test_tiled_normclip_requires_absolute_tau_and_matches_flat():
    with pytest.raises(ValueError):
        _sync(robust=RobustAggConfig(rule="normclip"), cohort_tile=2)
    with pytest.raises(ValueError):
        _sync(robust=RobustAggConfig(screen=True), cohort_tile=2)
    cfg = RobustAggConfig(rule="normclip", clip_norm=0.05)
    flat, tiled = _sync(robust=cfg), _sync(robust=cfg, cohort_tile=2)
    for r in range(2):
        batches = make_batches(2, 4, seed=r)
        flat.run_round(batches, flat.plan(r))
        tiled.run_round(batches, tiled.plan(r))
    _assert_trees_close(flat.state["params"], tiled.state["params"])


def test_sync_screen_quarantines_poisoned_client():
    agg = _sync(robust=RobustAggConfig(screen=True, quarantine_rounds=2))
    plan = agg.plan(0)
    batches = make_batches(2, 4, seed=0)
    batches["x"] = batches["x"].at[:, 1].set(jnp.nan)  # poison cohort lane 1
    m = agg.run_round(batches, plan)
    assert bool(jnp.all(jnp.isfinite(agg.state["params"]["w"])))
    assert float(m["nonfinite_deltas"]) == 1.0
    bad_cid = int(np.asarray(plan.selected)[1])
    assert agg.robust_state.is_quarantined(bad_cid, 1)
    assert not agg.robust_state.is_quarantined(bad_cid, 1 + 2)  # expiry


# ---------------------------------------------------------------------------
# corruption primitives + byzantine simulator
# ---------------------------------------------------------------------------


def test_corrupt_tree_kinds():
    tree = {"w": jnp.ones((2, 2)), "idx": jnp.zeros((2,), jnp.int32)}
    assert bool(jnp.all(jnp.isnan(corrupt_tree(tree, "nan")["w"])))
    assert bool(jnp.all(jnp.isinf(corrupt_tree(tree, "inf")["w"])))
    np.testing.assert_array_equal(
        np.asarray(corrupt_tree(tree, "scale")["w"]), np.full((2, 2), 64.0)
    )
    np.testing.assert_array_equal(
        np.asarray(corrupt_tree(tree, "sign_flip")["w"]), np.full((2, 2), -1.0)
    )
    for kind in ("nan", "inf", "scale", "sign_flip"):
        # integer planes (codec index lanes) are never touched
        np.testing.assert_array_equal(
            np.asarray(corrupt_tree(tree, kind)["idx"]), np.zeros(2)
        )
    with pytest.raises(ValueError):
        corrupt_tree(tree, "replay")


def test_make_byzantine_fn_targets_low_ids_only():
    fn = make_byzantine_fn(0.25, "nan", population=8)  # clients 0, 1
    delta = {"w": jnp.ones(3)}
    assert bool(jnp.all(jnp.isnan(fn(0, 0, delta)["w"])))
    assert bool(jnp.all(jnp.isnan(fn(1, 5, delta)["w"])))
    np.testing.assert_array_equal(np.asarray(fn(2, 1, delta)["w"]), np.ones(3))
    assert make_byzantine_fn(0.0, "nan", 8) is None
    with pytest.raises(ValueError):
        make_byzantine_fn(0.5, "replay", 8)


def test_chaos_on_payload_corruption_is_seeded_and_replay_works():
    from repro.runtime import ChaosConfig
    from repro.runtime.chaos import ChaosMonkey

    cfg = ChaosConfig(corrupt=1.0, corrupt_kinds=("replay",), seed=3)
    mk = ChaosMonkey(cfg, "w0")
    t0 = {"w": jnp.ones(2)}
    t1 = {"w": jnp.full(2, 2.0)}
    out0, kind0 = mk.on_payload(t0, 0)
    assert kind0 == "sign_flip"  # no previous push → replay degrades
    np.testing.assert_array_equal(np.asarray(out0["w"]), -np.ones(2))
    out1, kind1 = mk.on_payload(t1, 1)
    assert kind1 == "replay"
    np.testing.assert_array_equal(np.asarray(out1["w"]), np.ones(2))  # t0 replayed

    # deterministic per (seed, role): same dice, same kinds
    mk2 = ChaosMonkey(cfg, "w0")
    a = mk2.on_payload(t0, 0)[1]
    b = mk2.on_payload(t1, 1)[1]
    assert (a, b) == (kind0, kind1)

    off = ChaosMonkey(ChaosConfig(kill=0.1), "w0")
    same, kind = off.on_payload(t0, 0)
    assert kind is None and same is t0

    with pytest.raises(ValueError):
        ChaosConfig(corrupt=0.5, corrupt_kinds=("bogus",))


# ---------------------------------------------------------------------------
# async: screen at the door, quarantine, robust-off bitwise
# ---------------------------------------------------------------------------


def _adriver(robust=None, state=None, dispatch=None, pop=8, c=4, tau=2, buf=3):
    fed = _fed(c, tau)
    acfg = AsyncAggConfig(buffer_size=buf, staleness_alpha=0.5)
    pcfg = ParticipationConfig(population=pop, clients_per_round=c)
    drv = AsyncFederationDriver(
        quad_loss, fed, acfg, pcfg,
        lambda cid: make_batches(tau, 1, seed=100 + cid % 4),
        seed=0, params=make_params(), rng=jax.random.PRNGKey(1),
        robust=robust, state=state, dispatch=dispatch,
    )
    return drv, fed, acfg, pcfg


def test_async_robust_fully_off_is_bitwise_plain():
    a, *_ = _adriver()
    b, *_ = _adriver(robust=RobustAggConfig())
    ha = a.run_updates(4)
    hb = b.run_updates(4)
    _assert_trees_equal(a.state, b.state)
    assert ha == hb


def test_async_screen_rejects_byzantine_and_quarantines():
    drv, *_ = _adriver(
        robust=RobustAggConfig(screen=True, screen_warmup=3, screen_z=4.0)
    )
    drv.corrupt_fn = make_byzantine_fn(0.25, "nan", 8)  # clients 0, 1
    drv.run_updates(5, max_events=600)
    rs = drv.robust_state
    assert bool(jnp.all(jnp.isfinite(drv.state["params"]["w"])))
    assert rs.counters["screen_rejects"] > 0
    assert set(rs.quarantine) <= {0, 1}  # only the attackers
    assert len(rs.norm_history) > 0


def test_async_robust_kill_and_resume_is_bitwise(tmp_path):
    """Defended async run: quarantine table, screen history and counters ride
    the manifest — the resumed continuation is bitwise the uninterrupted run."""
    robust = RobustAggConfig(screen=True, screen_warmup=3, screen_z=4.0)
    atk = make_byzantine_fn(0.25, "nan", 8)

    a, *_ = _adriver(robust=robust)
    a.corrupt_fn = atk
    a.run_updates(6, max_events=800)

    b, fed, acfg, pcfg = _adriver(robust=robust)
    b.corrupt_fn = atk
    b.run_updates(3, max_events=800)
    tree, manifest = b.checkpoint()
    assert "robust" in manifest
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save_server(2, tree, extra={"aggregator": manifest})

    from repro.core import AsyncBufferAggregator

    like = AsyncBufferAggregator.checkpoint_template(
        fed, acfg, pcfg, make_params(), None
    )
    restored, man = ckpt.load_server(2, like)
    c, *_ = _adriver(
        robust=robust, state=restored, dispatch=man["extra"]["aggregator"]
    )
    c.corrupt_fn = atk
    assert c.robust_state.state_dict() == b.robust_state.state_dict()
    c.run_updates(3, max_events=800)

    _assert_trees_equal(a.state, c.state)
    assert a.robust_state.state_dict() == c.robust_state.state_dict()


def test_legacy_manifest_without_robust_key_restores_clean_slate():
    plain, *_ = _adriver()
    plain.run_updates(2)
    tree, manifest = plain.checkpoint()
    assert "robust" not in manifest  # undefended checkpoints are unchanged
    drv, *_ = _adriver(
        robust=RobustAggConfig(screen=True), state=tree, dispatch=manifest
    )
    rs = drv.robust_state
    assert rs.quarantine == {} and len(rs.norm_history) == 0
    assert rs.last_good == -1


# ---------------------------------------------------------------------------
# sync: defended kill-and-resume (quarantine expiry + guard window ride along)
# ---------------------------------------------------------------------------


def test_sync_robust_kill_and_resume_is_bitwise():
    robust = RobustAggConfig(screen=True, rollback=True, quarantine_rounds=3)

    def poisoned(r):
        batches = make_batches(2, 4, seed=r)
        if r == 1:  # one poisoned round populates quarantine + history
            batches["x"] = batches["x"].at[:, 2].set(jnp.nan)
        return batches

    a = _sync(robust=robust)
    for r in range(5):
        m = a.run_round(poisoned(r), a.plan(r))
        a.robust_state.observe_update(m["pseudo_grad_norm"])
        a.robust_state.mark_good(r)

    b = _sync(robust=robust)
    for r in range(2):
        m = b.run_round(poisoned(r), b.plan(r))
        b.robust_state.observe_update(m["pseudo_grad_norm"])
        b.robust_state.mark_good(r)
    tree, manifest = b.checkpoint()
    assert "robust" in manifest
    # the manifest is JSON-serializable (it rides CheckpointManager's JSON)
    manifest = json.loads(json.dumps(manifest))

    c = _sync(robust=robust)
    c.restore(tree, manifest)
    assert c.robust_state.state_dict() == b.robust_state.state_dict()
    for r in range(2, 5):
        m = c.run_round(poisoned(r), c.plan(r))
        c.robust_state.observe_update(m["pseudo_grad_norm"])
        c.robust_state.mark_good(r)

    _assert_trees_equal(a.state, c.state)
    assert a.robust_state.state_dict() == c.robust_state.state_dict()


# ---------------------------------------------------------------------------
# divergence guard + RobustState mechanics
# ---------------------------------------------------------------------------


def test_guard_trips_on_spike_and_nonfinite_only_when_warm():
    rs = RobustState(RobustAggConfig(rollback=True, rollback_window=4,
                                     rollback_factor=4.0))
    assert rs.observe_update(float("nan"))  # non-finite always trips
    for v in (1.0, 1.1, 0.9, 1.0):
        assert not rs.observe_update(v)
    assert not rs.observe_update(1.2)  # within factor
    assert rs.observe_update(40.0)  # spike vs window median ~1.0
    # the triggering value is NOT absorbed into the window
    assert rs.observe_update(40.0)


def test_norm_bound_floors_at_twice_median():
    rs = RobustState(RobustAggConfig(screen=True, screen_warmup=3, screen_z=6.0))
    assert rs.norm_bound() == float("inf")  # cold start
    for v in (1.0, 1.0, 1.0):
        rs.observe_norm(v)
    # MAD = 0 → the bound still leaves 2× headroom for honest drift
    assert rs.norm_bound() == 2.0
    rs.observe_norm(float("nan"))  # ignored
    assert len(rs.norm_history) == 3


def test_robust_state_dict_roundtrips_by_json():
    rs = RobustState(RobustAggConfig(screen=True, rollback=True))
    rs.add_quarantine([3, 5], rnd=2)
    rs.observe_norm(1.5)
    rs.observe_update(0.7)
    rs.mark_good(2)
    rs.note_screen_rejects(2)
    rs.note_rollback()
    sd = json.loads(json.dumps(rs.state_dict()))
    rs2 = RobustState(rs.cfg)
    rs2.load_state_dict(sd)
    assert rs2.state_dict() == rs.state_dict()
    assert rs2.is_quarantined(3, 2) and not rs2.is_quarantined(3, 99)


# ---------------------------------------------------------------------------
# CRC-framed transport (satellite: integrity on the wire)
# ---------------------------------------------------------------------------


def test_frame_crc_roundtrip_and_detects_byte_flip():
    from repro.runtime.transport import (
        FrameCorruptError,
        TransportError,
        encode_msg,
        recv_msg,
        send_frame,
        send_msg,
    )

    assert issubclass(FrameCorruptError, TransportError)  # retryable

    a, b = socket.socketpair()
    try:
        assert send_msg(a, "push", {"index": 7}, {"payload": jnp.ones(3)})
        msg = recv_msg(b)
        assert msg.meta["index"] == 7
        np.testing.assert_array_equal(np.asarray(msg.trees["payload"]), np.ones(3))

        # flip one payload byte mid-frame (after the 8B length + 4B CRC)
        raw = encode_msg("push", {"index": 8}, {"payload": jnp.ones(3)})
        import struct
        import zlib

        frame = struct.pack("!Q", len(raw)) + struct.pack("!I", zlib.crc32(raw))
        corrupted = bytearray(raw)
        corrupted[len(corrupted) // 2] ^= 0xFF
        a.sendall(frame + bytes(corrupted))
        with pytest.raises(FrameCorruptError):
            recv_msg(b)
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# report: corruption-coverage audit
# ---------------------------------------------------------------------------


def test_corruption_coverage_audit():
    from repro.obs.events import Event
    from repro.obs.report import check_run, corruption_coverage

    def _ev(name, ph, ts, span="", attrs=None):
        return Event(name=name, ph=ph, ts=ts, mono=ts, proc="server", pid=1,
                     trace="t", span=span, attrs=attrs or {})

    def dispatch(idx, outcome, ts):
        return [
            _ev("dispatch", "B", ts, span=f"d{idx}",
                attrs={"index": idx, "client": idx, "version": 0}),
            _ev("dispatch", "E", ts + 1.0, span=f"d{idx}",
                attrs={"outcome": outcome}),
        ]

    def fault(idx, kind, ts):
        return _ev("fault", "i", ts,
                   attrs={"kind": f"corrupt_{kind}", "index": idx,
                          "role": "w0"})

    # admitted NaN corruption with no defense → audit failure
    evs = dispatch(0, "admitted", 0.0) + [fault(0, "nan", 0.5)]
    assert corruption_coverage(evs)
    assert any("ADMITTED" in p for p in check_run(evs))

    # same, but screened → clean
    evs = dispatch(0, "admitted", 0.0) + [
        fault(0, "nan", 0.5),
        _ev("screen_reject", "i", 0.7, attrs={"index": 0, "client": 0}),
    ]
    assert corruption_coverage(evs) == []

    # quarantined outcome → clean; scale kind → excused (warmup-legal)
    evs = dispatch(1, "quarantined", 0.0) + [fault(1, "nan", 0.5)]
    assert corruption_coverage(evs) == []
    evs = dispatch(2, "admitted", 0.0) + [fault(2, "scale", 0.5)]
    assert corruption_coverage(evs) == []

    # a later rollback excuses an admitted NaN
    evs = dispatch(3, "admitted", 0.0) + [
        fault(3, "nan", 0.5),
        _ev("rollback", "i", 2.0, attrs={"round": 1, "restored_round": 0}),
    ]
    assert corruption_coverage(evs) == []
