"""Focused unit tests: outer optimizers, attention masks/positions, MoE dispatch,
SSM decode consistency, compression, autobatch, roofline parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.compression import (
    cast_compress,
    cast_decompress,
    init_error_feedback,
    int8_compress,
    int8_decompress,
    topk_compress,
    uplink_bytes,
)
from repro.core.inner_opt import InnerOptConfig, init_inner_state, inner_update
from repro.core.outer_opt import OuterOptConfig, init_outer_state, outer_update
from repro.models.attention import make_mask, sdpa, sdpa_chunked
from repro.models.common import alibi_slopes, apply_rope

# ---------------------------------------------------------------------------
# outer optimizers
# ---------------------------------------------------------------------------


def test_fedavg_unit_lr_is_plain_averaging():
    params = {"w": jnp.ones((3,))}
    delta = {"w": jnp.full((3,), 0.25)}  # theta - mean(theta_k)
    cfg = OuterOptConfig(name="fedavg", lr=1.0)
    new, _ = outer_update(cfg, params, delta, init_outer_state(cfg, params))
    np.testing.assert_allclose(np.asarray(new["w"]), 0.75)


def test_fedmom_nesterov_accelerates_constant_gradient():
    params = {"w": jnp.zeros((1,))}
    delta = {"w": jnp.ones((1,))}
    cfg = OuterOptConfig(name="fedmom", lr=1.0, momentum=0.9, nesterov=True)
    st = init_outer_state(cfg, params)
    p = params
    steps = []
    for _ in range(3):
        p, st = outer_update(cfg, p, delta, st)
        steps.append(float(p["w"][0]))
    # displacement per round grows under momentum
    assert steps[0] > steps[1] > steps[2]
    assert (steps[0] - steps[1]) < (steps[1] - steps[2])


def test_fedadam_bounded_step():
    params = {"w": jnp.zeros((4,))}
    delta = {"w": jnp.array([1e3, -1e3, 1e-3, 0.0])}
    cfg = OuterOptConfig(name="fedadam", lr=0.1)
    new, _ = outer_update(cfg, params, delta, init_outer_state(cfg, params))
    assert float(jnp.max(jnp.abs(new["w"]))) <= 0.11  # lr-bounded regardless of scale


def test_adamw_weight_decay_shrinks_params_with_zero_grad():
    cfg = InnerOptConfig(lr_max=0.1, weight_decay=0.5, warmup_steps=0, total_steps=10, alpha=1.0)
    params = {"w": jnp.ones((2,))}
    st = init_inner_state(cfg, params)
    grads = {"w": jnp.zeros((2,))}
    new, _, _ = inner_update(cfg, params, grads, st, jnp.int32(5))
    assert float(new["w"][0]) < 1.0


# ---------------------------------------------------------------------------
# attention internals
# ---------------------------------------------------------------------------


def test_make_mask_causal_window_and_decode_len():
    m = make_mask(jnp.arange(4), jnp.arange(4), causal=True, window=2)
    mm = np.asarray(m[0, 0, 0])
    assert mm[0, 1] == False and mm[1, 0] == True and mm[3, 1] == False  # window=2
    md = make_mask(jnp.array([5]), jnp.arange(8), causal=True, window=None, k_len=jnp.int32(6))
    assert np.asarray(md[0, 0, 0, 0]).sum() == 6


def test_chunked_attention_equals_dense():
    B, S, H, hd = 2, 512, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.arange(S)
    dense = sdpa(q, k, v, make_mask(pos, pos, True, None))
    chunked = sdpa_chunked(q, k, v, q_pos=pos, k_pos=pos, causal=True, window=None,
                           k_len=None, slopes=None, chunk=128)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked), rtol=2e-5, atol=2e-5)


def test_chunked_attention_alibi_matches_dense_bias():
    B, S, H, hd = 1, 256, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.arange(S)
    slopes = alibi_slopes(H)
    dist = (pos[:, None] - pos[None, :]).astype(jnp.float32)
    bias = (-slopes[:, None, None] * jnp.maximum(dist, 0.0))[None]
    dense = sdpa(q, k, v, make_mask(pos, pos, True, None), bias)
    chunked = sdpa_chunked(q, k, v, q_pos=pos, k_pos=pos, causal=True, window=None,
                           k_len=None, slopes=slopes, chunk=64)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked), rtol=2e-5, atol=2e-5)


def test_rope_preserves_norm_and_relativity():
    hd = 64
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, hd))
    rx = apply_rope(x, jnp.arange(8), 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(rx), axis=-1),
        rtol=1e-5,
    )
    # relativity: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, hd))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([i]), 10_000.0)
        kj = apply_rope(k, jnp.array([j]), 10_000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4


def test_alibi_slopes_monotone_positive():
    for h in (8, 12, 16, 20):
        s = np.asarray(alibi_slopes(h))
        assert (s > 0).all() and len(s) == h


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------


def test_moe_capacity_drops_overflow_tokens():
    from repro.models import moe as moe_mod

    cfg = get_config("deepseek-moe-16b").reduced()
    model_desc = moe_mod.moe_ffn_desc(cfg)
    from repro.models.common import init_params

    p = init_params(jax.random.PRNGKey(0), model_desc)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out_full, aux = moe_mod.moe_ffn(cfg, p, x, capacity_factor=8.0)  # nothing dropped
    out_tiny, _ = moe_mod.moe_ffn(cfg, p, x, capacity_factor=0.05)  # nearly all dropped
    assert np.isfinite(np.asarray(out_full)).all()
    assert float(jnp.abs(out_tiny).mean()) < float(jnp.abs(out_full).mean())
    assert float(aux) >= 1.0 - 1e-3  # Switch aux lower bound at uniform routing


def test_moe_shared_expert_always_active():
    from repro.models import moe as moe_mod
    from repro.models.common import init_params

    cfg = get_config("deepseek-moe-16b").reduced()
    p = init_params(jax.random.PRNGKey(0), moe_mod.moe_ffn_desc(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    out_drop_all, _ = moe_mod.moe_ffn(cfg, p, x, capacity_factor=1e-9)
    # with all routed tokens dropped, output == shared expert path (nonzero)
    assert float(jnp.abs(out_drop_all).mean()) > 0


# ---------------------------------------------------------------------------
# SSM decode vs scan consistency (sequence processed both ways)
# ---------------------------------------------------------------------------


def test_ssm_block_decode_matches_full_scan():
    from repro.models import ssm as ssm_mod
    from repro.models.common import init_params

    cfg = get_config("mamba2-1.3b").reduced()
    p = init_params(jax.random.PRNGKey(0), ssm_mod.ssm_desc(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model))
    y_full, _ = ssm_mod.ssm_block(cfg, p, x)
    cache = ssm_mod.empty_ssm_cache(cfg, 1)
    cache = {"conv": jnp.zeros_like(cache["conv"]), "ssd": cache["ssd"]}
    ys = []
    for t in range(12):
        y_t, cache = ssm_mod.ssm_block(cfg, p, x[:, t : t + 1], cache=cache, decode=True)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_bf16_cast_roundtrip_and_stochastic_rounding_unbiased():
    tree = {"w": jnp.full((2000,), 0.1001, jnp.float32)}
    det = cast_decompress(cast_compress(tree))
    assert abs(float(det["w"][0]) - 0.1001) < 1e-3
    sr = cast_decompress(cast_compress(tree, rng=jax.random.PRNGKey(0)))
    # stochastic rounding: mean over many entries approaches the true value
    assert abs(float(sr["w"].mean()) - 0.1001) < 2e-4


def test_topk_error_feedback_conserves_mass():
    tree = {"w": jnp.arange(1.0, 101.0)}
    sparse, err = topk_compress(tree, k_fraction=0.1)
    nnz = int((np.asarray(sparse["w"]) != 0).sum())
    assert nnz == 10
    np.testing.assert_allclose(
        np.asarray(sparse["w"] + err["w"]), np.asarray(tree["w"]), rtol=1e-6
    )
    # second round re-injects the residual
    sparse2, err2 = topk_compress({"w": jnp.zeros(100)}, 0.1, error=err)
    assert float(jnp.abs(sparse2["w"]).sum()) > 0  # residual mass surfaces


def test_int8_roundtrip_error_bounded():
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (512,))}
    out = int8_decompress(int8_compress(x))
    scale = float(jnp.max(jnp.abs(x["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(out["w"] - x["w"]))) <= scale * 0.5 + 1e-6


def test_uplink_bytes_ordering():
    tree = {"w": jnp.zeros((1000,)), "b": jnp.zeros((10,))}
    f32 = uplink_bytes(tree, "float32")
    assert uplink_bytes(tree, "bfloat16") == f32 / 2
    assert uplink_bytes(tree, "int8") < f32 / 2
    assert uplink_bytes(tree, "topk", 0.01) < uplink_bytes(tree, "int8")


# ---------------------------------------------------------------------------
# autobatch
# ---------------------------------------------------------------------------


def test_autobatch_estimates_sane():
    from repro.launch.autobatch import estimate_micro_batch

    small = get_config("qwen3-1.7b")
    big = get_config("chameleon-34b")
    mb_small = estimate_micro_batch(small, 4096)
    mb_big = estimate_micro_batch(big, 4096)
    assert mb_small >= 1
    assert mb_big <= mb_small


# ---------------------------------------------------------------------------
# roofline parsing
# ---------------------------------------------------------------------------


def test_hlo_analyzer_nested_scan_multiplication():
    from repro.roofline.hlo_analyzer import analyze

    a = jnp.zeros((256, 256))

    def f(x):
        def inner(c, _):
            return c @ a, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    compiled = jax.jit(f).lower(jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    r = analyze(compiled.as_text())
    expected = 15 * 2 * 256**3
    assert expected * 0.95 <= r.flops <= expected * 1.3


def test_analyzer_matches_xla_on_scanfree_graph():
    from repro.roofline.hlo_analyzer import analyze

    f = jax.jit(lambda a, b: jnp.tanh(a @ b))
    c = f.lower(
        jax.ShapeDtypeStruct((128, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
    ).compile()
    r = analyze(c.as_text())
    ca = c.cost_analysis()  # list-of-dicts on jax<=0.4.x, plain dict afterwards
    xla = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    assert abs(r.flops - xla) / xla < 0.1
