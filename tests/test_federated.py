"""Federated optimization semantics: equivalence identities tying the paper's algorithm
to SGD, plus outer-optimizer behaviour and hierarchical aggregation. The shared tiny
quadratic model lives in conftest.py."""
import jax
import jax.numpy as jnp
import numpy as np
from conftest import make_batches, make_params, quad_loss, sgd_inner

from repro.core import (
    FederatedConfig,
    InnerOptConfig,
    OuterOptConfig,
    centralized_step,
    federated_round,
    hierarchical_mean,
    init_centralized_state,
    init_federated_state,
)


def test_one_client_one_step_fedavg_equals_centralized_sgd():
    """K=1, τ=1, FedAvg(η=1) must be EXACTLY one inner-optimizer step."""
    fed = FederatedConfig(
        clients_per_round=1, local_steps=1, inner=sgd_inner(),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    params = make_params()
    batches = make_batches(1, 1)
    state = init_federated_state(fed, params)
    new_state, _ = federated_round(quad_loss, fed, state, batches)

    c_state = init_centralized_state(fed.inner, params)
    c_batch = {k: v[0, 0] for k, v in batches.items()}
    c_new, _ = centralized_step(quad_loss, fed.inner, c_state, c_batch)

    # SGD has momentum buffer; first step: mom = g, update = lr*g — matches
    np.testing.assert_allclose(
        np.asarray(new_state["params"]["w"]), np.asarray(c_new["params"]["w"]), rtol=1e-6
    )


def test_identical_clients_equal_single_client():
    """All clients seeing identical data produce Δ_k identical; the average equals any
    single client — FedAvg is then exactly local SGD (Local SGD ≡ FedAvg, §2.2)."""
    tau, c = 5, 4
    fed = FederatedConfig(
        clients_per_round=c, local_steps=tau, inner=sgd_inner(),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    params = make_params()
    b1 = make_batches(tau, 1)
    batches = {k: jnp.broadcast_to(v, (tau, c) + v.shape[2:]) for k, v in b1.items()}
    state = init_federated_state(fed, params)
    out_multi, m_multi = federated_round(quad_loss, fed, state, batches)

    fed1 = FederatedConfig(
        clients_per_round=1, local_steps=tau, inner=sgd_inner(),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    out_single, _ = federated_round(quad_loss, fed1, init_federated_state(fed1, params), b1)

    np.testing.assert_allclose(
        np.asarray(out_multi["params"]["w"]),
        np.asarray(out_single["params"]["w"]),
        rtol=1e-5,
    )
    # consensus metric must be ~1 for identical deltas
    assert float(m_multi["client_consensus"]) > 0.999


def test_client_order_permutation_invariance():
    tau, c = 3, 4
    fed = FederatedConfig(
        clients_per_round=c, local_steps=tau, inner=sgd_inner(),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    params = make_params()
    batches = make_batches(tau, c)
    perm = jnp.array([2, 0, 3, 1])
    batches_p = {k: v[:, perm] for k, v in batches.items()}
    s0 = init_federated_state(fed, params)
    out_a, _ = federated_round(quad_loss, fed, s0, batches)
    out_b, _ = federated_round(quad_loss, fed, s0, batches_p)
    np.testing.assert_allclose(
        np.asarray(out_a["params"]["w"]), np.asarray(out_b["params"]["w"]), rtol=1e-5
    )


def test_hierarchical_mean_equals_flat_mean():
    deltas = {"w": jax.random.normal(jax.random.PRNGKey(3), (8, 4, 4))}
    flat = jax.tree_util.tree_map(lambda x: jnp.mean(x, 0), deltas)
    for g in (1, 2, 4, 8):
        two = hierarchical_mean(deltas, g)
        # equal up to float32 reassociation of the two-phase reduction
        np.testing.assert_allclose(
            np.asarray(two["w"]), np.asarray(flat["w"]), rtol=1e-5, atol=1e-7
        )


def test_federated_converges_on_quadratic():
    """Multi-round federated optimization must drive the quadratic loss down."""
    tau, c = 10, 4
    fed = FederatedConfig(
        clients_per_round=c, local_steps=tau,
        inner=InnerOptConfig(name="adamw", lr_max=0.05, weight_decay=0.0,
                             warmup_steps=0, total_steps=1000, alpha=1.0),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    params = make_params()
    state = init_federated_state(fed, params)
    step = jax.jit(lambda s, b: federated_round(quad_loss, fed, s, b))
    losses = []
    for r in range(8):
        batches = make_batches(tau, c, seed=100 + r)
        state, m = step(state, batches)
        losses.append(float(m["train_loss_mean"]))
    assert losses[-1] < 0.5 * losses[0], losses


def test_fedprox_pulls_towards_global():
    """The proximal term shrinks client drift (stable regime: μ·lr < 1)."""
    tau, c = 20, 2
    base = dict(clients_per_round=c, local_steps=tau, inner=sgd_inner(lr=0.01),
                outer=OuterOptConfig(name="fedavg", lr=1.0))
    params = make_params()
    batches = make_batches(tau, c)
    _, m_free = federated_round(
        quad_loss, FederatedConfig(**base), init_federated_state(FederatedConfig(**base), params), batches
    )
    fed_prox = FederatedConfig(**base, fedprox_mu=20.0)
    _, m_prox = federated_round(
        quad_loss, fed_prox, init_federated_state(fed_prox, params), batches
    )
    assert float(m_prox["pseudo_grad_norm"]) < float(m_free["pseudo_grad_norm"])


def test_dp_clip_bounds_client_deltas():
    tau, c = 5, 4
    fed = FederatedConfig(
        clients_per_round=c, local_steps=tau, inner=sgd_inner(lr=0.5),
        outer=OuterOptConfig(name="fedavg", lr=1.0), dp_clip=0.01,
    )
    params = make_params()
    _, m = federated_round(quad_loss, fed, init_federated_state(fed, params), make_batches(tau, c))
    assert float(m["client_delta_norm_mean"]) <= 0.01 + 1e-5


def test_outer_optimizers_all_progress():
    tau, c = 5, 4
    params = make_params()
    batches = make_batches(tau, c)
    for outer in (
        OuterOptConfig(name="fedavg", lr=1.0),
        OuterOptConfig(name="fedmom", lr=0.7, momentum=0.9),
        OuterOptConfig(name="fedadam", lr=0.01),
    ):
        fed = FederatedConfig(clients_per_round=c, local_steps=tau,
                              inner=sgd_inner(lr=0.05), outer=outer)
        state = init_federated_state(fed, params)
        new_state, m = federated_round(quad_loss, fed, state, batches)
        moved = float(
            jnp.abs(new_state["params"]["w"] - params["w"]).sum()
        )
        assert moved > 0, outer.name
        assert np.isfinite(float(m["pseudo_grad_norm"]))


def test_keep_inner_state_carries_momentum():
    tau, c = 3, 2
    fed = FederatedConfig(
        clients_per_round=c, local_steps=tau, inner=sgd_inner(lr=0.05),
        outer=OuterOptConfig(name="fedavg", lr=1.0), keep_inner_state=True,
    )
    params = make_params()
    state = init_federated_state(fed, params)
    assert "inner" in state
    state2, _ = federated_round(quad_loss, fed, state, make_batches(tau, c))
    mom_norm = float(jnp.abs(state2["inner"]["mom"]["w"]).sum())
    assert mom_norm > 0  # momentum survived the round boundary


def test_bf16_pseudo_gradient_close_to_fp32():
    tau, c = 5, 4
    params = make_params()
    batches = make_batches(tau, c)
    outs = {}
    for dt in ("float32", "bfloat16"):
        fed = FederatedConfig(clients_per_round=c, local_steps=tau,
                              inner=sgd_inner(lr=0.05),
                              outer=OuterOptConfig(name="fedavg", lr=1.0),
                              pseudo_grad_dtype=dt)
        s, _ = federated_round(quad_loss, fed, init_federated_state(fed, params), batches)
        outs[dt] = np.asarray(s["params"]["w"])
    np.testing.assert_allclose(outs["bfloat16"], outs["float32"], rtol=0.02, atol=1e-3)
