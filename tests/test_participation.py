"""Elastic-participation semantics (paper §7): the weighted round subsumes the flat
mean exactly, masking reduces to smaller cohorts, and the participation subsystem is
pure/seeded so any round samples identically regardless of execution history."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_batches, make_params, quad_loss, sgd_inner

from repro.core import (
    STRAGGLER_PROFILES,
    FederatedConfig,
    OuterOptConfig,
    ParticipationConfig,
    client_example_counts,
    dirichlet_popularity,
    federated_round,
    hierarchical_mean,
    init_federated_state,
    markov_availability,
    participation_counts,
    plan_round,
    sample_round,
)
from repro.metrics import effective_clients, weight_entropy


def _fed(c, tau, **kw):
    return FederatedConfig(
        clients_per_round=c, local_steps=tau, inner=sgd_inner(),
        outer=OuterOptConfig(name="fedavg", lr=1.0), **kw,
    )


# ---------------------------------------------------------------------------
# Elastic round == legacy round (the acceptance identity)
# ---------------------------------------------------------------------------


def test_uniform_weight_elastic_round_bitwise_equals_flat_mean_round():
    """All-ones weights must reproduce the legacy flat-mean round EXACTLY (bitwise):
    the elastic path multiplies by 1.0 and divides by Σ1 = C, both exact in IEEE."""
    tau, c = 5, 4
    fed = _fed(c, tau)
    params = make_params()
    batches = make_batches(tau, c)
    s0 = init_federated_state(fed, params)

    legacy, m_legacy = jax.jit(lambda s, b: federated_round(quad_loss, fed, s, b))(
        s0, batches
    )
    elastic, m_elastic = jax.jit(
        lambda s, b, w: federated_round(quad_loss, fed, s, b, client_weights=w)
    )(s0, batches, jnp.ones((c,), jnp.float32))

    for leg, ela in zip(
        jax.tree_util.tree_leaves(legacy["params"]),
        jax.tree_util.tree_leaves(elastic["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(leg), np.asarray(ela))
    # round metrics agree too (weighted formulas reduce to the uniform ones)
    for k in ("train_loss", "pseudo_grad_norm", "client_consensus"):
        np.testing.assert_allclose(
            float(m_legacy[k]), float(m_elastic[k]), rtol=1e-6, atol=1e-7
        )
    assert float(m_elastic["effective_clients"]) == c


def test_mask_all_but_one_equals_single_client_round():
    """Zero weights excise clients: only client j's delta reaches the aggregate, so
    the update equals a C=1 round on client j's batches (weight scale is irrelevant)."""
    tau, c, j = 4, 4, 2
    params = make_params()
    batches = make_batches(tau, c)
    w = np.zeros(c, np.float32)
    w[j] = 37.0  # any positive scale — a lone client's weight cancels
    masked, m = federated_round(
        quad_loss, _fed(c, tau), init_federated_state(_fed(c, tau), params), batches,
        client_weights=jnp.asarray(w),
    )

    fed1 = _fed(1, tau)
    single, _ = federated_round(
        quad_loss, fed1, init_federated_state(fed1, params),
        {k: v[:, j : j + 1] for k, v in batches.items()},
    )
    np.testing.assert_allclose(
        np.asarray(masked["params"]["w"]), np.asarray(single["params"]["w"]),
        rtol=1e-6, atol=1e-7,
    )
    assert float(m["effective_clients"]) == 1
    assert float(m["client_consensus"]) == pytest.approx(1.0)  # lone client: trivial


def test_weighted_round_is_scale_invariant():
    tau, c = 3, 4
    params = make_params()
    batches = make_batches(tau, c)
    fed = _fed(c, tau)
    s0 = init_federated_state(fed, params)
    w = jnp.asarray([1.0, 2.0, 0.0, 5.0], jnp.float32)
    a, _ = federated_round(quad_loss, fed, s0, batches, client_weights=w)
    b, _ = federated_round(quad_loss, fed, s0, batches, client_weights=w * 4.0)
    np.testing.assert_allclose(
        np.asarray(a["params"]["w"]), np.asarray(b["params"]["w"]), rtol=1e-5
    )


def test_weighted_hierarchical_mean_equals_weighted_flat_mean():
    deltas = {"w": jax.random.normal(jax.random.PRNGKey(3), (8, 4, 4))}
    w = jnp.asarray([3.0, 0.0, 1.0, 7.0, 2.0, 0.0, 5.0, 1.0], jnp.float32)
    flat = jax.tree_util.tree_map(
        lambda x: jnp.sum(x * w[:, None, None], 0) / jnp.sum(w), deltas
    )
    for g in (1, 2, 4, 8):
        two = hierarchical_mean(deltas, g, weights=w)
        np.testing.assert_allclose(
            np.asarray(two["w"]), np.asarray(flat["w"]), rtol=1e-5, atol=1e-7
        )


def test_all_zero_weights_freeze_fedavg_params():
    """A fully-failed round (every weight zero) contributes a zero pseudo-gradient:
    under plain FedAvg the global params must not move."""
    tau, c = 3, 2
    fed = _fed(c, tau)
    params = make_params()
    out, _ = federated_round(
        quad_loss, fed, init_federated_state(fed, params), make_batches(tau, c),
        client_weights=jnp.zeros((c,), jnp.float32),
    )
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), np.asarray(params["w"]))


# ---------------------------------------------------------------------------
# Sampler / availability models: determinism and statistics
# ---------------------------------------------------------------------------


def test_sample_round_deterministic_and_valid():
    for r in range(5):
        a = sample_round(7, r, 64, 16)
        b = sample_round(7, r, 64, 16)
        np.testing.assert_array_equal(a, b)
        assert len(set(a.tolist())) == 16 and a.min() >= 0 and a.max() < 64


def test_dirichlet_popularity_skews_selection():
    probs = dirichlet_popularity(0, 32, alpha=0.1)
    assert probs.shape == (32,) and probs.min() > 0
    np.testing.assert_allclose(probs.sum(), 1.0, rtol=1e-9)
    np.testing.assert_array_equal(probs, dirichlet_popularity(0, 32, alpha=0.1))
    counts = participation_counts(0, 400, 32, 4, probs=probs)
    uniform = participation_counts(0, 400, 32, 4)
    # popularity-weighted visits concentrate far beyond uniform sampling noise
    assert counts.max() > 2.0 * uniform.max()


def test_markov_availability_matches_stationary_rate():
    p_drop, p_join = 0.2, 0.6
    rates = [
        markov_availability(3, r, 256, p_drop, p_join).mean() for r in range(0, 60, 4)
    ]
    target = p_join / (p_join + p_drop)
    assert abs(float(np.mean(rates)) - target) < 0.08
    # chains persist: availability is correlated round-to-round, not i.i.d.
    a = markov_availability(3, 10, 256, 0.05, 0.05)
    b = markov_availability(3, 11, 256, 0.05, 0.05)
    assert (a == b).mean() > 0.8


def test_example_counts_fixed_and_positive():
    n1 = client_example_counts(5, 64)
    n2 = client_example_counts(5, 64)
    np.testing.assert_array_equal(n1, n2)
    assert n1.min() >= 1 and len(np.unique(n1)) > 10  # genuinely heterogeneous


def test_plan_round_statistics_and_invariants():
    cfg = ParticipationConfig(
        population=32, clients_per_round=16, model="markov", dropout_rate=0.3,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="examples",
    )
    drop_frac, total = [], 0
    for r in range(30):
        plan = plan_round(cfg, 11, r)
        assert plan.selected.shape == (16,) and len(set(plan.selected.tolist())) == 16
        assert plan.effective_k >= 1  # never an empty aggregate
        assert (plan.weights[~plan.mask] == 0).all()
        assert (plan.weights[plan.mask] > 0).all()
        started = plan.mask | plan.stragglers
        if started.sum():
            drop_frac.append(plan.n_dropped / max(1, plan.n_dropped + started.sum()))
        total += plan.effective_k
    assert 0.15 < float(np.mean(drop_frac)) < 0.45  # dropout rate within noise
    assert total < 30 * 16  # heterogeneity actually removed clients


def test_straggler_cut_respects_deadline_and_speeds():
    cfg_cut = ParticipationConfig(
        population=16, clients_per_round=16,
        straggler=STRAGGLER_PROFILES["heavy"],
    )
    cfg_wait = ParticipationConfig(
        population=16, clients_per_round=16,
        straggler=type(STRAGGLER_PROFILES["heavy"])("wait", 0.8, 0.0),  # no deadline
    )
    plan_cut = plan_round(cfg_cut, 2, 0)
    plan_wait = plan_round(cfg_wait, 2, 0)
    assert plan_wait.n_stragglers == 0 and plan_wait.effective_k == 16
    # cut rounds finish at the deadline; wait-for-all rounds run as slow as the tail
    assert plan_cut.round_time <= STRAGGLER_PROFILES["heavy"].deadline + 1e-9
    assert plan_wait.round_time >= plan_cut.round_time
    # every straggler is genuinely slower than the deadline
    assert (1.0 / plan_cut.speeds[plan_cut.stragglers]
            > STRAGGLER_PROFILES["heavy"].deadline).all()


# ---------------------------------------------------------------------------
# Resume semantics: round r is independent of execution history
# ---------------------------------------------------------------------------


def test_sample_round_independent_of_prior_rounds():
    """Regression: sampling round r must not depend on whether rounds 0..r-1 ran."""
    fresh = sample_round(9, 7, 40, 8)
    replayed = None
    for r in range(8):  # "execute" rounds 0..7 in order
        replayed = sample_round(9, r, 40, 8)
    np.testing.assert_array_equal(fresh, replayed)
    # counts over n rounds == sum of independent per-round draws
    counts = participation_counts(9, 8, 40, 8)
    manual = np.zeros(40, np.int64)
    for r in range(8):
        manual[sample_round(9, r, 40, 8)] += 1
    np.testing.assert_array_equal(counts, manual)


def test_plan_round_independent_of_prior_rounds():
    for model in ("uniform", "dirichlet", "markov"):
        cfg = ParticipationConfig(
            population=24, clients_per_round=8, model=model, dropout_rate=0.2,
            straggler=STRAGGLER_PROFILES["mild"], weighting="examples",
        )
        fresh = plan_round(cfg, 13, 6)  # jump straight to round 6
        for r in range(7):
            replayed = plan_round(cfg, 13, r)
        np.testing.assert_array_equal(fresh.selected, replayed.selected)
        np.testing.assert_array_equal(fresh.mask, replayed.mask)
        np.testing.assert_array_equal(fresh.weights, replayed.weights)


# ---------------------------------------------------------------------------
# Host-side metrics helpers
# ---------------------------------------------------------------------------


def test_participation_metric_helpers():
    assert effective_clients([0.0, 2.0, 0.0, 1.0]) == 2
    assert weight_entropy([1.0, 1.0, 1.0, 1.0]) == pytest.approx(np.log(4))
    assert weight_entropy([5.0, 0.0, 0.0]) == pytest.approx(0.0)
    assert weight_entropy([]) == 0.0
