"""Per-architecture smoke tests: instantiate the REDUCED variant of each assigned
architecture, run one forward + one train step on CPU, assert output shapes and no NaNs.
Also exercises prefill + decode for every family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model

SEQ = 64
BATCH = 2

# the default lane smoke-tests two cheap representative archs; the full sweep over
# every assigned architecture runs in the slow lane
FAST_ARCHS = {"gemma3-4b", "deepseek-coder-33b"}
ARCH_PARAMS = [
    arch if arch in FAST_ARCHS else pytest.param(arch, marks=pytest.mark.slow)
    for arch in ASSIGNED_ARCHS
]


def make_batch(cfg, rng=0):
    r = np.random.RandomState(rng)
    batch = {"tokens": jnp.asarray(r.randint(0, cfg.vocab_size, (BATCH, SEQ)), jnp.int32)}
    if cfg.enc_dec:
        batch["audio_embed"] = jnp.asarray(
            r.randn(BATCH, cfg.n_audio_frames, cfg.d_model), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def reduced_models():
    return {}


def _get(reduced_models, arch):
    if arch not in reduced_models:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        reduced_models[arch] = (cfg, model, params)
    return reduced_models[arch]


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_shapes_and_finite(arch, reduced_models):
    cfg, model, params = _get(reduced_models, arch)
    batch = make_batch(cfg)
    logits, aux, _ = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN/Inf in logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_step_reduces_loss_and_finite(arch, reduced_models):
    cfg, model, params = _get(reduced_models, arch)
    batch = make_batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p2 = jax.tree_util.tree_map(lambda w, g: w - 1e-2 * g, p, grads)
        return loss, metrics, p2

    loss0, metrics, params2 = step(params, batch)
    assert np.isfinite(float(loss0)), f"non-finite loss for {arch}"
    # gradients finite
    loss1, _, _ = step(params2, batch)
    assert np.isfinite(float(loss1))
    assert float(loss1) < float(loss0) + 0.5  # training step did not explode


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_then_decode_matches_full_forward(arch, reduced_models):
    cfg, model, params = _get(reduced_models, arch)
    batch = make_batch(cfg)
    tokens = batch["tokens"]

    # Full forward logits at the last position
    logits_full, _, _ = jax.jit(lambda p, b: model.forward(p, b))(params, batch)

    # Prefill on S-1 tokens, then decode token S-1
    prefill_batch = dict(batch)
    prefill_batch["tokens"] = tokens[:, : SEQ - 1]
    _, cache = jax.jit(lambda p, b: model.prefill(p, b))(params, prefill_batch)

    # Build a max-length cache and copy prefill contents in.
    full_cache = model.init_cache(BATCH, SEQ, dtype=jnp.float32)

    def merge(dst, src):
        if isinstance(dst, dict):
            return {k: merge(dst[k], src[k]) if k in src else dst[k] for k in dst}
        if isinstance(dst, list):
            return [merge(d, s) for d, s in zip(dst, src)]
        if hasattr(dst, "shape") and dst.shape != src.shape:
            # attention k/v: src has seq S-1, dst Smax
            pad_width = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad_width)
        return src.astype(dst.dtype)

    merged = merge(full_cache, cache)
    logits_dec, _ = jax.jit(
        lambda p, c, t, i: model.decode_step(p, c, t, i)
    )(params, merged, tokens[:, SEQ - 1 :], jnp.int32(SEQ - 1))

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=0.15,
        atol=0.15,
    )


def test_reduced_configs_respect_limits():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch).reduced()
        assert cfg.n_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4
