"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the ref.py
pure-jnp oracles. Kernels execute in interpret mode on the CPU host."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_decode.ref import decode_attention_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan import ssd
from repro.kernels.ssd_scan.ref import ssd_naive

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,hd,causal,window",
    [
        (1, 2, 2, 64, 32, True, None),
        (2, 4, 2, 128, 64, True, None),
        pytest.param(2, 8, 1, 256, 64, True, None, marks=pytest.mark.slow),  # MQA
        (1, 4, 4, 128, 64, False, None),  # bidirectional (encoder)
        pytest.param(2, 4, 2, 256, 32, True, 64, marks=pytest.mark.slow),  # window
        (1, 2, 2, 96, 64, True, None),  # non-128 seq -> smaller block
    ],
)
def test_flash_attention_matches_ref(B, Hq, Hkv, S, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(hash((B, Hq, S)) % 2**31), 3)
    q = _rand(ks[0], (B, S, Hq, hd), dtype)
    k = _rand(ks[1], (B, S, Hkv, hd), dtype)
    v = _rand(ks[2], (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    ref = attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(jnp.swapaxes(ref, 1, 2), np.float32),
        **TOL[dtype],
    )


def test_flash_attention_q_offset_decode_tail():
    """q_offset positions the query block at the end of the kv (chunked prefill)."""
    B, H, S, hd = 1, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q_full = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, H, hd), jnp.float32)
    v = _rand(ks[2], (B, S, H, hd), jnp.float32)
    full = flash_attention(q_full, k, v, causal=True, interpret=True)
    tail = flash_attention(q_full[:, 64:], k, v, causal=True, q_offset=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(tail), np.asarray(full[:, 64:]), rtol=2e-5, atol=2e-5
    )


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,hd,kv_len,window",
    [
        pytest.param(2, 4, 2, 256, 64, 200, None, marks=pytest.mark.slow),
        pytest.param(1, 8, 8, 512, 32, 512, None, marks=pytest.mark.slow),
        (2, 4, 1, 128, 64, 77, None),
        pytest.param(2, 4, 2, 512, 64, 400, 128, marks=pytest.mark.slow),  # window
    ],
)
def test_flash_decode_matches_ref(B, Hq, Hkv, S, hd, kv_len, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, 1, Hq, hd), dtype)
    k = _rand(ks[1], (B, S, Hkv, hd), dtype)
    v = _rand(ks[2], (B, S, Hkv, hd), dtype)
    out = flash_decode(q, k, v, jnp.int32(kv_len), window=window, interpret=True)
    ref = decode_attention_ref(
        q[:, 0], jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        jnp.int32(kv_len), window=window,
    )
    np.testing.assert_allclose(
        np.asarray(out[:, 0], np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,nh,hd,G,ds,chunk",
    [
        (1, 64, 2, 32, 1, 16, 16),
        pytest.param(2, 128, 4, 64, 1, 32, 32, marks=pytest.mark.slow),
        pytest.param(1, 128, 4, 32, 2, 16, 64, marks=pytest.mark.slow),  # multi-group
        (1, 100, 2, 32, 1, 16, 32),  # non-multiple seq -> padding path
    ],
)
def test_ssd_kernel_matches_naive_recurrence(B, S, nh, hd, G, ds, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = _rand(ks[0], (B, S, nh, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = _rand(ks[3], (B, S, G, ds), dtype)
    Cm = _rand(jax.random.PRNGKey(9), (B, S, G, ds), dtype)

    y_k, st_k = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_n, st_n = ssd_naive(x, dt, A, Bm, Cm)
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 else dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(y_k, np.float32), np.asarray(y_n, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_n), rtol=1e-3, atol=1e-3)


@pytest.mark.slow
def test_ssd_initial_state_continuation():
    """Processing [first half] then [second half | state] == processing whole."""
    B, S, nh, hd, G, ds = 1, 128, 2, 32, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = _rand(ks[0], (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = _rand(ks[3], (B, S, G, ds), jnp.float32)
    Cm = _rand(jax.random.PRNGKey(7), (B, S, G, ds), jnp.float32)

    y_full, st_full = ssd(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    y1, st1 = ssd(x[:, :64], dt[:, :64], A, Bm[:, :64], Cm[:, :64], chunk=32, interpret=True)
    y2, st2 = ssd(
        x[:, 64:], dt[:, 64:], A, Bm[:, 64:], Cm[:, 64:], chunk=32,
        initial_state=st1, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 64:]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_model_chunked_matches_naive():
    """The model-level jnp SSD (dry-run lowering path) against the recurrence."""
    from repro.models.ssm import ssd_chunked

    B, S, nh, hd, G, ds = 2, 96, 4, 32, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = _rand(ks[0], (B, S, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    Bm = _rand(ks[3], (B, S, G, ds), jnp.float32)
    Cm = _rand(jax.random.PRNGKey(8), (B, S, G, ds), jnp.float32)
    y_c, st_c = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y_n, st_n = ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_n), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 64), (2, 8, 128), (3, 5, 7, 32)])
def test_rmsnorm_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = _rand(k1, shape, dtype)
    s = 1.0 + 0.1 * jax.random.normal(k2, shape[-1:])
    out = rmsnorm(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )
