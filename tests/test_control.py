"""The adaptive aggregation control loop (src/repro/control, docs/control.md).

Keystone identities:
  - ``--control static`` (and no controller at all) is BITWISE the
    uncontrolled run — metric rows, server state, checkpoint manifest (no
    ``control`` key) all identical;
  - a governed run is deterministic in its observation history: a killed
    governed async run restored through the checkpoint manifest's ``control``
    state replays the remaining knob decisions and metric rows bitwise;
  - knob changes only ever land at round/flush boundaries, on quantized grids,
    and every applied update is observable (history, ``knob_*`` row echoes,
    ``knob_update`` trace events).

Plus the fedmetrics window/histogram helpers the policies consume: empty
windows, degenerate single-bucket histograms, quantiles at bucket edges.
"""
import json

import jax
import numpy as np
import pytest
from conftest import make_batches, make_params, quad_loss, sgd_inner

from repro.checkpoint import CheckpointManager
from repro.control import (
    ALPHA_MAX,
    ALPHA_STEP,
    CohortTuner,
    FederationController,
    KnobUpdate,
    StalenessGovernor,
    StaticPolicy,
    build_controller,
)
from repro.core import (
    STRAGGLER_PROFILES,
    AsyncAggConfig,
    AsyncBufferAggregator,
    AsyncFederationDriver,
    FederatedConfig,
    OuterOptConfig,
    ParticipationConfig,
    SyncAggregator,
)
from repro.metrics import (
    histogram_quantile,
    participation_metrics,
    staleness_hist_counts,
    window_concat,
    window_mean,
)


def _strip_update(rows):
    # run_updates numbers rows from 0 per CALL; the resume identity is about
    # the federation state, not the local loop counter
    return [{k: v for k, v in r.items() if k != "update"} for r in rows]


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# fedmetrics window/histogram helpers (the policies' input reducers)
# ---------------------------------------------------------------------------


def test_histogram_helpers_empty_window():
    counts = staleness_hist_counts([])
    np.testing.assert_array_equal(counts, np.zeros(5))
    assert histogram_quantile(counts, 0.9) == 0.0  # empty histogram -> 0.0
    assert window_concat([], "admitted_staleness") == []
    assert window_mean([], "effective_k", default=-1.0) == -1.0
    # rows present but none carrying the key: still the default
    assert window_mean([{"x": 1.0}], "effective_k", default=7.0) == 7.0


def test_staleness_hist_counts_bucket_alignment():
    # buckets: [0], [1], [2,3], [4,7], [8, inf)
    counts = staleness_hist_counts([0, 1, 2, 3, 4, 7, 8, 100])
    np.testing.assert_array_equal(counts, [1.0, 1.0, 2.0, 2.0, 2.0])


def test_histogram_quantile_single_bucket_degenerate():
    # every admitted delta in one bucket: any quantile is that bucket's edge
    all_fresh = staleness_hist_counts([0.0, 0.0, 0.0])
    all_mid = staleness_hist_counts([2, 3, 2])
    all_tail = staleness_hist_counts([9, 12, 64])
    for q in (0.01, 0.5, 0.9, 1.0):
        assert histogram_quantile(all_fresh, q) == 0.0
        assert histogram_quantile(all_mid, q) == 3.0  # upper edge of [2,3]
        # the open-ended bucket has no finite upper edge: its LOWER edge
        assert histogram_quantile(all_tail, q) == 8.0


def test_histogram_quantile_at_bucket_edges():
    counts = np.ones(5)  # one delta per bucket, total 5
    # rank q*5 lands exactly on each cumulative boundary; ties resolve INTO
    # that bucket (conservative upper edge), so the edges walk {0,1,3,7,8}
    assert histogram_quantile(counts, 0.2) == 0.0
    assert histogram_quantile(counts, 0.4) == 1.0
    assert histogram_quantile(counts, 0.6) == 3.0
    assert histogram_quantile(counts, 0.8) == 7.0
    assert histogram_quantile(counts, 1.0) == 8.0
    # just past a boundary spills into the next bucket
    assert histogram_quantile(counts, 0.41) == 3.0
    with pytest.raises(ValueError):
        histogram_quantile(np.ones(3), 0.5)  # wrong bucket arity


def test_window_helpers_reduce_across_rows():
    rows = [
        {"effective_k": 4.0, "admitted_staleness": [0.0, 1.0]},
        {"admitted_staleness": []},  # falsy list contributes nothing
        {"effective_k": 2.0, "admitted_staleness": [3.0]},
    ]
    assert window_mean(rows, "effective_k") == 3.0
    assert window_concat(rows, "admitted_staleness") == [0.0, 1.0, 3.0]


# ---------------------------------------------------------------------------
# policies: directions, deadband, bounds, quantization, serialization
# ---------------------------------------------------------------------------


def _stale_row(values):
    return {"admitted_staleness": [float(v) for v in values]}


def test_governor_raises_discount_and_grows_buffer_when_stale():
    g = StalenessGovernor(staleness_alpha=0.5, buffer_size=2, target=1.0,
                          buffer_max=8)
    up = g.observe([_stale_row([8, 8, 8, 8])])  # q90 = 8, error = +7
    assert up is not None
    assert up.staleness_alpha == ALPHA_MAX  # 0.5 + 0.5*7 clipped to 2.0
    assert up.buffer_size == 4  # powers of two, upward
    assert up.evidence["staleness_quantile"] == 8.0
    assert g.knobs() == {"staleness_alpha": 2.0, "buffer_size": 4.0}


def test_governor_trades_headroom_for_update_frequency():
    # observed staleness far below target: relax alpha, shrink the buffer
    g = StalenessGovernor(staleness_alpha=1.0, buffer_size=4, target=3.0)
    up = g.observe([_stale_row([0, 0, 0, 0])])  # q90 = 0, error = -3
    assert up.staleness_alpha == 0.0 and up.buffer_size == 2
    # alpha quantizes onto the 1/16 grid
    g2 = StalenessGovernor(staleness_alpha=1.0, buffer_size=4, target=1.1,
                           gain=0.33)
    up2 = g2.observe([_stale_row([0, 0, 0])])  # error = -1.1, step = -0.363
    assert up2.staleness_alpha == pytest.approx(
        round((1.0 - 0.33 * 1.1) / ALPHA_STEP) * ALPHA_STEP
    )


def test_governor_deadband_and_empty_window_hold_fire():
    g = StalenessGovernor(staleness_alpha=0.5, buffer_size=4, target=1.0)
    assert g.observe([{"buffer_fill": 4.0}]) is None  # no staleness yet
    assert g.observe([_stale_row([1, 1, 1])]) is None  # exactly on target
    assert g.knobs() == {"staleness_alpha": 0.5, "buffer_size": 4.0}


def test_governor_pinned_at_bounds_returns_none():
    g = StalenessGovernor(staleness_alpha=2.0, buffer_size=4, target=0.0,
                          buffer_max=4)
    # stale reading, but alpha is at ALPHA_MAX and the buffer at buffer_max:
    # nothing can move, and a no-op must not masquerade as an update
    assert g.observe([_stale_row([8, 8, 8])]) is None
    g2 = StalenessGovernor(staleness_alpha=0.0, buffer_size=1, target=8.0)
    assert g2.observe([_stale_row([0, 0, 0])]) is None


def test_governor_validates_and_serializes():
    with pytest.raises(ValueError):
        StalenessGovernor(quantile=0.0)
    with pytest.raises(ValueError):
        StalenessGovernor(target=-1.0)
    g = StalenessGovernor(staleness_alpha=0.5, buffer_size=2, target=1.0,
                          buffer_max=8)
    g.observe([_stale_row([8, 8, 8])])
    blob = json.dumps(g.state_dict())  # JSON round-trip, exactly
    g2 = StalenessGovernor()
    g2.load_state_dict(json.loads(blob))
    assert g2.knobs() == g.knobs()
    # identical histories keep producing identical decisions
    w = [_stale_row([0, 0, 0, 0])]
    assert g.observe(list(w)) == g2.observe(list(w))
    with pytest.raises(ValueError):
        g2.load_state_dict({"no_such_field": 1.0})


def test_cohort_tuner_directions_and_saturation():
    heavy = STRAGGLER_PROFILES["heavy"].deadline
    t = CohortTuner(clients_per_round=8, deadline=heavy, population=16,
                    target=0.9)
    up = t.observe([{"effective_k": 2.0}])  # fraction 0.25: starved
    assert up.deadline is not None and up.deadline > heavy
    assert up.clients_per_round is None  # deadline not saturated yet
    # pin the deadline at its max: the next starved reading moves K instead
    t.deadline = t.deadline_max
    up2 = t.observe([{"effective_k": 2.0}])
    assert up2.deadline is None and up2.clients_per_round == 10
    # over-provisioned rounds walk the deadline back down
    t2 = CohortTuner(clients_per_round=8, deadline=2.0, population=16,
                     target=0.5)
    up3 = t2.observe([{"effective_k": 8.0}])  # fraction 1.0 > target
    assert up3.deadline is not None and up3.deadline < 2.0
    # deadband and no-participation-rows hold fire
    t3 = CohortTuner(clients_per_round=8, deadline=1.0, population=16,
                     target=0.5, deadband=0.05)
    assert t3.observe([{"effective_k": 4.1}]) is None
    assert t3.observe([{"sim_time": 1.0}]) is None
    with pytest.raises(ValueError):
        CohortTuner(clients_per_round=8, deadline=0.0, population=16)
    with pytest.raises(ValueError):
        CohortTuner(clients_per_round=8, deadline=1.0, population=16,
                    target=1.5)


def test_controller_window_interval_and_factory():
    ctl = FederationController(
        StalenessGovernor(staleness_alpha=0.5, buffer_size=4, target=1.0),
        window=2, interval=2,
    )
    assert ctl.enabled
    assert ctl.observe(_stale_row([8, 8, 8])) is None  # cadence: row 1 of 2
    up = ctl.observe(_stale_row([8, 8, 8]))  # cadence fires on row 2
    assert up is not None and ctl.n_updates == 1
    assert len(ctl.rows) == 2  # window stays bounded
    assert ctl.history[0]["knobs"] == up.knob_dict()
    # static is no controller at all; unknown names are refused
    assert build_controller("static") is None
    with pytest.raises(ValueError):
        build_controller("pid")
    # a static controller attached anyway reports disabled
    assert not FederationController(StaticPolicy()).enabled
    # resume refuses a policy mismatch (the --control flag changed)
    other = FederationController(StaticPolicy())
    with pytest.raises(ValueError):
        other.load_state_dict(ctl.state_dict())
    # state_dict is JSON-clean and round-trips the decision state
    clone = FederationController(
        StalenessGovernor(), window=4, interval=1
    )
    clone.load_state_dict(json.loads(json.dumps(ctl.state_dict())))
    assert clone.seen == ctl.seen and clone.rows == ctl.rows
    assert clone.knobs() == ctl.knobs()


# ---------------------------------------------------------------------------
# aggregator integration: bitwise-static, live knob application, kill/resume
# ---------------------------------------------------------------------------


def _driver(controller=None, state=None, dispatch=None, buffer_size=4,
            alpha=0.5, tracer=None):
    tau, k = 3, 4
    fed = FederatedConfig(
        clients_per_round=k, local_steps=tau, inner=sgd_inner(lr=0.05),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    acfg = AsyncAggConfig(buffer_size=buffer_size, staleness_alpha=alpha)
    pcfg = ParticipationConfig(
        population=8, clients_per_round=k, dropout_rate=0.1,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="examples",
    )
    drv = AsyncFederationDriver(
        quad_loss, fed, acfg, pcfg,
        lambda cid: make_batches(tau, 1, seed=100 + cid),
        seed=3, params=make_params(), rng=jax.random.PRNGKey(1),
        state=state, dispatch=dispatch, controller=controller, tracer=tracer,
    )
    return drv, fed, acfg, pcfg


def _governor_controller(buffer_size=4, alpha=0.5, target=3.0):
    return FederationController(
        StalenessGovernor(staleness_alpha=alpha, buffer_size=buffer_size,
                          target=target, buffer_max=8),
        window=2,
    )


def test_async_static_controller_is_bitwise_uncontrolled():
    bare, *_ = _driver(controller=None)
    hist_bare = bare.run_updates(4)
    static, *_ = _driver(controller=FederationController(StaticPolicy()))
    hist_static = static.run_updates(4)
    assert hist_bare == hist_static
    tree_a, man_a = bare.checkpoint()
    tree_b, man_b = static.checkpoint()
    assert man_a == man_b
    assert "control" not in man_b  # checkpoint bytes identical to PR-7 schema
    _assert_trees_equal(tree_a, tree_b)


def test_async_governor_moves_knobs_at_flush_boundaries():
    drv, _, acfg, _ = _driver(controller=_governor_controller())
    hist = drv.run_updates(4)
    ctl = drv.controller
    assert ctl.history, "governor never fired under an over-provisioned buffer"
    # observed staleness sits below target 3: the governor trades headroom,
    # shrinking the buffer (and the buffer lanes resize with it)
    assert drv.acfg.buffer_size < acfg.buffer_size
    m = drv.acfg.buffer_size
    assert drv.state["buf_weights"].shape == (m,)
    assert jax.tree_util.tree_leaves(drv.state["buffer"])[0].shape[0] == m
    # applied updates are echoed into the flush rows for the CSV/bench trail
    echoed = [r for r in hist if any(k.startswith("knob_") for k in r)]
    assert len(echoed) == len(ctl.history)
    # ...and the checkpoint manifest carries the controller state
    _, manifest = drv.checkpoint()
    assert manifest["control"]["policy"] == "staleness"
    assert manifest["control"]["n_updates"] == len(ctl.history)


def test_async_governed_kill_and_resume_is_bitwise_uninterrupted(tmp_path):
    """The governed version of THE resume criterion: checkpoint a governed
    run mid-flight, rebuild controller + aggregator from the manifest, and the
    continuation (including every future knob decision) is bitwise the
    uninterrupted run."""
    drv_a, *_ = _driver(controller=_governor_controller())
    hist_a = drv_a.run_updates(6)

    drv_b, fed, _, pcfg = _driver(controller=_governor_controller())
    drv_b.run_updates(3)
    tree, manifest = drv_b.checkpoint()
    assert "control" in manifest
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save_server(2, tree, extra={"aggregator": manifest})

    # restore exactly as train.py does: controller first, then the aggregator
    # config re-derived from the GOVERNED knob values (not the CLI defaults)
    ctl_c = _governor_controller()
    ctl_c.load_state_dict(json.loads(json.dumps(manifest["control"])))
    knobs = ctl_c.knobs()
    acfg_c = AsyncAggConfig(
        buffer_size=int(knobs["buffer_size"]),
        staleness_alpha=float(knobs["staleness_alpha"]),
    )
    like = AsyncBufferAggregator.checkpoint_template(
        fed, acfg_c, pcfg, make_params()
    )
    restored, loaded = ckpt.load_server(2, like)
    assert loaded["extra"]["aggregator"] == manifest  # JSON floats exact
    drv_c, *_ = _driver(
        controller=ctl_c, state=restored,
        dispatch=loaded["extra"]["aggregator"],
        buffer_size=acfg_c.buffer_size, alpha=acfg_c.staleness_alpha,
    )
    hist_c = drv_c.run_updates(3)

    assert _strip_update(hist_a[3:]) == _strip_update(hist_c)
    tree_a, man_a = drv_a.checkpoint()
    tree_c, man_c = drv_c.checkpoint()
    assert man_a == man_c  # controller state + slots + clocks all match
    _assert_trees_equal(tree_a, tree_c)


def test_async_apply_knobs_guards():
    drv, *_ = _driver()
    with pytest.raises(ValueError):  # sync knobs refused on the async side
        drv.apply_knobs(KnobUpdate(clients_per_round=2))
    while int(drv.state["buf_count"]) == 0:
        drv.step()
    with pytest.raises(RuntimeError):  # resize only at a flush boundary
        drv.apply_knobs(KnobUpdate(buffer_size=2))


def test_async_knob_update_events_are_traced(tmp_path):
    from repro.obs import JsonlSink, Tracer

    path = tmp_path / "trace.jsonl"
    tracer = Tracer(sink=JsonlSink(str(path)), proc="test", trace_id="ctl")
    drv, *_ = _driver(controller=_governor_controller(), tracer=tracer)
    drv.run_updates(3)
    tracer.close()
    events = [json.loads(line) for line in path.read_text().splitlines()]
    knob_events = [e for e in events if e.get("name") == "knob_update"]
    assert len(knob_events) == len(drv.controller.history)
    attrs = knob_events[0]["attrs"]
    assert any(k.startswith("knob_") for k in attrs)
    assert any(k.startswith("evidence_") for k in attrs)
    assert attrs["evidence_target"] == 3.0


# ---------------------------------------------------------------------------
# sync cohort control
# ---------------------------------------------------------------------------


def _sync_agg(controller=None, k=8):
    tau = 3
    fed = FederatedConfig(
        clients_per_round=k, local_steps=tau, inner=sgd_inner(lr=0.05),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    pcfg = ParticipationConfig(
        population=8, clients_per_round=k,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="examples",
    )
    agg = SyncAggregator(
        quad_loss, fed, pcfg, seed=5, params=make_params(),
        rng=jax.random.PRNGKey(9), controller=controller,
    )
    return agg, tau


def test_sync_static_controller_is_bitwise_uncontrolled():
    bare, tau = _sync_agg()
    ctl, _ = _sync_agg(controller=FederationController(StaticPolicy()))
    for r in range(3):
        b = make_batches(tau, 8, seed=40 + r)
        m_a = bare.run_round(b, bare.plan(r))
        assert bare.control_step({"effective_k": 1.0}) is None
        m_b = ctl.run_round(b, ctl.plan(r))
        assert ctl.control_step({"effective_k": 1.0}) is None
        for k in m_a:
            np.testing.assert_array_equal(
                np.asarray(m_a[k]), np.asarray(m_b[k]), err_msg=k
            )
    _assert_trees_equal(bare.state, ctl.state)
    _, man_a = bare.checkpoint()
    _, man_b = ctl.checkpoint()
    assert man_a == man_b and "control" not in man_b


def test_sync_cohort_tuner_loosens_deadline_for_starved_rounds():
    heavy = STRAGGLER_PROFILES["heavy"].deadline
    controller = FederationController(
        CohortTuner(clients_per_round=8, deadline=heavy, population=8,
                    target=0.99),
        window=2,
    )
    agg, tau = _sync_agg(controller=controller)
    updates = []
    for r in range(4):
        plan = agg.plan(r)
        agg.run_round(make_batches(tau, 8, seed=60 + r), plan)
        up = agg.control_step(participation_metrics(plan))
        if up is not None:
            updates.append(up)
    assert updates, "heavy stragglers under target 0.99 must starve rounds"
    assert agg.pcfg.straggler.deadline > heavy  # the knob actually landed
    assert all(u.deadline is not None for u in updates)
    _, manifest = agg.checkpoint()
    assert manifest["control"]["policy"] == "cohort"


def test_sync_cohort_resize_rebuilds_round_and_guards_keep_opt():
    agg, tau = _sync_agg(k=8)
    agg.apply_knobs(KnobUpdate(clients_per_round=6))
    assert agg.fed.clients_per_round == 6 and agg.pcfg.clients_per_round == 6
    plan = agg.plan(0)
    assert len(plan.selected) == 6
    m = agg.run_round(make_batches(tau, 6, seed=70), plan)  # retraced at K=6
    assert float(m["train_loss"]) > 0.0
    with pytest.raises(ValueError):  # async knobs refused on the sync side
        agg.apply_knobs(KnobUpdate(buffer_size=2))
    # --keep-opt persists (K, ...)-shaped inner lanes: resize refused
    fed_keep = FederatedConfig(
        clients_per_round=4, local_steps=tau, inner=sgd_inner(lr=0.05),
        outer=OuterOptConfig(name="fedavg", lr=1.0), keep_inner_state=True,
    )
    pcfg = ParticipationConfig(population=8, clients_per_round=4)
    keep = SyncAggregator(quad_loss, fed_keep, pcfg, seed=5,
                          params=make_params())
    with pytest.raises(ValueError):
        keep.apply_knobs(KnobUpdate(clients_per_round=6))
