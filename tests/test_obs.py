"""Federation telemetry layer (PR 7).

Four layers, cheapest first:

1. **Event schema** — versioned round-trip, torn-tail tolerance vs loud
   interior corruption, incarnation-keyed span pairing.
2. **Tracer** — deterministic span ids, counters/gauges/ring, and the no-op
   guarantee: the disabled tracer records nothing and costs (almost) nothing.
3. **Exports** — a golden Chrome-trace conversion on synthetic fixed-clock
   events, round rollups, the Prometheus endpoint, report-CLI invariants.
4. **Read-only invariant** — the tentpole acceptance: an async federation run
   with tracing ON is BITWISE the run with tracing OFF (plain, int8, and the
   top-k error-feedback lane), because the tracer only reads host floats the
   metrics path already computed.

Satellite coverage rides along: the MetricLogger schema-growth fix (a late
``val_ppl`` column must widen the CSV, not vanish).
"""
import json
import time
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batches, make_params, quad_loss, sgd_inner
from repro.core import (
    AsyncAggConfig,
    AsyncFederationDriver,
    FederatedConfig,
    Int8Codec,
    OuterOptConfig,
    ParticipationConfig,
    STRAGGLER_PROFILES,
    SyncAggregator,
    TopKCodec,
)
from repro.metrics import MetricLogger
from repro.obs import (
    EVENT_SCHEMA_VERSION,
    Event,
    JsonlSink,
    MetricsServer,
    NULL_TRACER,
    Tracer,
    check_run,
    chrome_trace,
    decode_event,
    dispatch_table,
    encode_event,
    load_run,
    observe_staleness,
    read_events,
    render_metrics,
    round_rollups,
    span_pairs,
)


# ---------------------------------------------------------------------------
# Event schema + JSONL durability
# ---------------------------------------------------------------------------


def test_event_roundtrip_and_version_refusal():
    ev = Event(
        name="dispatch", ph="B", ts=1.5, mono=0.25, proc="server", pid=42,
        trace="seed3", span="d7", parent="u2", attrs={"index": 7, "client": 1},
    )
    back = decode_event(encode_event(ev))
    assert back == ev
    stale = encode_event(ev)
    stale["v"] = EVENT_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema version"):
        decode_event(stale)
    with pytest.raises(ValueError, match="phase"):
        Event(name="x", ph="Z", ts=0, mono=0, proc="p", pid=1, trace="t")


def _mk(name, ph, ts, mono, proc="server", pid=1, span="", parent=None, attrs=None):
    return Event(name=name, ph=ph, ts=ts, mono=mono, proc=proc, pid=pid,
                 trace="t", span=span, parent=parent, attrs=attrs or {})


def test_jsonl_sink_appends_and_torn_tail_is_dropped(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    sink = JsonlSink(path)
    sink.emit(_mk("a", "i", 1.0, 1.0))
    sink.emit(_mk("b", "i", 2.0, 2.0))
    sink.close()
    # crash tears the final line mid-append: the torn event never committed
    with open(path, "a") as f:
        f.write('{"v":1,"name":"torn","ph":"i","ts":3.0')
    events = read_events(path)
    assert [e.name for e in events] == ["a", "b"]
    # a respawned incarnation appends to the same file
    sink2 = JsonlSink(path)
    sink2.emit(_mk("c", "i", 4.0, 4.0, pid=2))
    sink2.close()
    # ...but the torn fragment now sits INTERIOR to the log: that is real
    # corruption (the line-commit discipline cannot produce it) — loud error
    with pytest.raises(ValueError, match="corrupt event line"):
        read_events(path)


def test_read_events_raises_on_interior_corruption(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    good = json.dumps(encode_event(_mk("a", "i", 1.0, 1.0)))
    with open(path, "w") as f:
        f.write(good + "\n" + "NOT JSON\n" + good + "\n")
    with pytest.raises(ValueError, match=r"ev\.jsonl:2"):
        read_events(path)


def test_span_pairs_keyed_by_process_incarnation():
    events = [
        _mk("work", "B", 1.0, 1.0, proc="w0", pid=10, span="d0@w0", attrs={"i": 0}),
        # pid 10 died; respawned incarnation pid 11 reopens the SAME span id
        _mk("work", "B", 2.0, 1.0, proc="w0", pid=11, span="d0@w0"),
        _mk("end", "E", 3.0, 2.5, proc="w0", pid=11, span="d0@w0",
            attrs={"outcome": "pushed"}),
        _mk("end", "E", 4.0, 9.0, proc="w1", pid=20, span="never-opened"),
    ]
    closed, opened = span_pairs(events)
    assert len(closed) == 1  # pid 11's close never matches pid 10's open
    assert closed[0]["pid"] == 11
    assert closed[0]["dur"] == 1.5  # same-process mono delta
    assert closed[0]["attrs"] == {"outcome": "pushed"}
    assert [ev.pid for ev in opened] == [10]  # the dead incarnation stays open


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_tracer_spans_counters_gauges_and_ring(tmp_path):
    sink = JsonlSink(str(tmp_path / "t.jsonl"))
    tr = Tracer(sink, proc="server", trace_id="seed0", ring_size=3)
    sid = tr.begin("dispatch", span_id="d0", parent="u0", index=0)
    assert sid == "d0"
    with tr.span("train", span_id="d0/t", parent="d0"):
        pass
    tr.end("d0", outcome="admitted")
    tr.point("admit", parent="d0", accepted=True)
    tr.count("admits")
    tr.count("bytes", 128.0)
    tr.gauge("round", 2.0)
    snap = tr.snapshot()
    assert snap["counters"] == {"admits": 1.0, "bytes": 128.0}
    assert snap["gauges"] == {"round": 2.0}
    assert len(tr.ring) == 3  # bounded flight recorder, oldest evicted
    tr.close()
    events = read_events(str(tmp_path / "t.jsonl"))
    closed, opened = span_pairs(events)
    assert opened == []
    assert {c["span"]: c["name"] for c in closed} == {"d0": "dispatch",
                                                      "d0/t": "train"}
    d0 = next(c for c in closed if c["span"] == "d0")
    assert d0["parent"] == "u0"
    assert d0["attrs"]["outcome"] == "admitted"  # end-attrs land on the span
    assert events[-1].ph == "C"  # close() snapshots the counters
    assert events[-1].attrs["counters"]["admits"] == 1.0


def test_null_tracer_records_nothing_and_is_cheap():
    n = 100_000
    t0 = time.perf_counter()
    for i in range(n):
        NULL_TRACER.count("x")
        NULL_TRACER.point("y", index=i)
        NULL_TRACER.begin("s", span_id="a")
        NULL_TRACER.end("a")
    dt = time.perf_counter() - t0
    assert NULL_TRACER.snapshot() == {"counters": {}, "gauges": {}}
    assert len(NULL_TRACER.ring) == 0
    # generous absolute guard: 400k disabled calls must stay trivially cheap
    # (no locks, no clocks, no allocation beyond the call itself)
    assert dt < 2.0, f"{n} no-op tracer loops took {dt:.2f}s"


# ---------------------------------------------------------------------------
# Chrome export + rollups (golden, on fixed-clock synthetic events)
# ---------------------------------------------------------------------------


def _golden_events():
    return [
        _mk("round", "B", 10.0, 1.0, pid=100, span="u0",
            attrs={"round": 0, "track": 0}),
        _mk("dispatch", "B", 10.25, 1.25, pid=100, span="d0", parent="u0",
            attrs={"index": 0, "client": 2, "track": 3}),
        _mk("assignment", "B", 10.5, 5.0, proc="w0", pid=200, span="d0@w0",
            parent="d0"),
        _mk("end", "E", 10.75, 5.5, proc="w0", pid=200, span="d0@w0",
            parent="d0", attrs={"outcome": "pushed"}),
        _mk("admit", "i", 11.0, 1.75, pid=100, parent="d0",
            attrs={"accepted": True, "staleness": 1.0}),
        _mk("end", "E", 11.25, 2.0, pid=100, span="d0",
            attrs={"outcome": "admitted"}),
        # "u0" stays open: rendered with the remainder of the server timeline
    ]


def test_chrome_trace_golden():
    got = chrome_trace(_golden_events())
    assert got == {
        "traceEvents": [
            {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
             "args": {"name": "server"}},
            {"ph": "M", "name": "process_name", "pid": 2, "tid": 0,
             "args": {"name": "w0"}},
            {"ph": "X", "name": "assignment", "pid": 2, "tid": 0,
             "ts": 10.5e6, "dur": 0.5e6, "cat": "fed",
             "args": {"outcome": "pushed", "span": "d0@w0"}},
            {"ph": "X", "name": "dispatch", "pid": 1, "tid": 3,
             "ts": 10.25e6, "dur": 0.75e6, "cat": "fed",
             "args": {"index": 0, "client": 2, "outcome": "admitted",
                      "span": "d0"}},
            {"ph": "X", "name": "round", "pid": 1, "tid": 0,
             "ts": 10.0e6, "dur": 1.0e6, "cat": "fed",
             "args": {"round": 0, "span": "u0", "unclosed": True,
                      "pid_real": 100}},
            {"ph": "i", "s": "p", "name": "admit", "pid": 1, "tid": 0,
             "ts": 11.0e6, "cat": "fed",
             "args": {"accepted": True, "staleness": 1.0}},
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 0,
             "args": {"name": "main"}},
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 3,
             "args": {"name": "slot c2"}},
            {"ph": "M", "name": "thread_name", "pid": 2, "tid": 0,
             "args": {"name": "main"}},
        ],
        "displayTimeUnit": "ms",
    }


def test_round_rollups_attribute_admits_to_their_flush():
    events = [
        _mk("admit", "i", 1.0, 1.0, attrs={"accepted": True, "staleness": 2.0}),
        _mk("admit", "i", 2.0, 2.0, attrs={"accepted": False, "staleness": 9.0}),
        _mk("flush", "i", 3.0, 3.0, attrs={"round": 0, "train_loss": 1.5}),
        _mk("admit", "i", 4.0, 4.0, attrs={"accepted": True, "staleness": 0.0}),
        _mk("flush", "i", 5.0, 5.0, attrs={"round": 1, "train_loss": 1.2}),
    ]
    rows = round_rollups(events)
    assert [r["round"] for r in rows] == [0, 1]
    assert rows[0]["n_admitted"] == 1 and rows[0]["n_rejected"] == 1
    assert rows[0]["staleness_admitted_max"] == 2.0  # rejected age not counted
    assert rows[1]["n_admitted"] == 1 and rows[1]["staleness_admitted_max"] == 0.0


# ---------------------------------------------------------------------------
# Metrics endpoint
# ---------------------------------------------------------------------------


def test_staleness_histogram_buckets_are_cumulative():
    tr = Tracer(proc="server")
    for s in (0.0, 1.0, 2.0, 5.0, 11.0):
        observe_staleness(tr, s)
    text = render_metrics(tr)
    assert 'fed_staleness_admitted_rounds_bucket{le="0"} 1' in text
    assert 'fed_staleness_admitted_rounds_bucket{le="1"} 2' in text
    assert 'fed_staleness_admitted_rounds_bucket{le="3"} 3' in text
    assert 'fed_staleness_admitted_rounds_bucket{le="7"} 4' in text
    assert 'fed_staleness_admitted_rounds_bucket{le="+Inf"} 5' in text
    assert "fed_staleness_admitted_rounds_sum 19" in text
    assert "fed_staleness_admitted_rounds_count 5" in text


def test_metrics_server_serves_prometheus_text():
    tr = Tracer(proc="server")
    tr.count("pushes", 3)
    tr.gauge("round", 7.0)
    srv = MetricsServer(tr, port=0, extra=lambda: {"workers_alive": 2})
    try:
        url = f"http://{srv.host}:{srv.port}/metrics"
        body = urllib.request.urlopen(url, timeout=5).read().decode()
        assert "# TYPE fed_pushes_total counter" in body
        assert "fed_pushes_total 3" in body
        assert "fed_round 7" in body
        assert "fed_workers_alive 2" in body  # live extras (worker liveness)
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://{srv.host}:{srv.port}/nope", timeout=5
            )
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Report invariants
# ---------------------------------------------------------------------------


def test_check_run_flags_unclosed_spans_and_orphans():
    dispatch_open = _mk("dispatch", "B", 1.0, 1.0, span="d0",
                        attrs={"index": 0})
    assert check_run([dispatch_open])  # unclosed, no kill recorded → problem
    kill = _mk("fault", "i", 2.0, 2.0, attrs={"kind": "kill"})
    assert check_run([dispatch_open, kill]) == []  # crash is in the audit

    orphan = [
        _mk("assignment", "B", 1.0, 1.0, proc="w0", pid=9, span="d9@w0",
            parent="d9"),
        _mk("end", "E", 2.0, 2.0, proc="w0", pid=9, span="d9@w0", parent="d9"),
    ]
    problems = check_run(orphan)
    assert any("orphan" in p for p in problems)

    bad_outcome = [
        _mk("dispatch", "B", 1.0, 1.0, span="d0", attrs={"index": 0}),
        _mk("end", "E", 2.0, 2.0, span="d0", attrs={"outcome": "whatever"}),
    ]
    assert any("non-terminal" in p for p in check_run(bad_outcome))

    assert any("expected injected faults" in p
               for p in check_run([], expect_faults=True))


def test_dispatch_table_collects_leases_and_pushes():
    events = [
        _mk("dispatch", "B", 1.0, 1.0, span="d0",
            attrs={"index": 0, "client": 3, "version": 0}),
        _mk("lease_grant", "i", 1.1, 1.1, parent="d0",
            attrs={"index": 0, "worker": "w0", "regrant": False,
                   "expired": False}),
        _mk("lease_grant", "i", 1.5, 1.5, parent="d0",
            attrs={"index": 0, "worker": "w1", "regrant": True,
                   "expired": True}),
        _mk("push_recv", "i", 2.0, 2.0, parent="d0",
            attrs={"index": 0, "worker": "w1", "dup": False}),
        _mk("end", "E", 2.5, 2.5, span="d0",
            attrs={"outcome": "admitted", "staleness": 1.0}),
    ]
    (row,) = dispatch_table(events)
    assert row["outcome"] == "admitted"
    assert [l["worker"] for l in row["leases"]] == ["w0", "w1"]
    assert row["leases"][1]["expired"] is True
    assert [p["worker"] for p in row["pushes"]] == ["w1"]


# ---------------------------------------------------------------------------
# MetricLogger schema growth (satellite: the silent-field-drop fix)
# ---------------------------------------------------------------------------


def test_metric_logger_grows_schema_instead_of_dropping_fields(tmp_path):
    path = str(tmp_path / "log.csv")
    log = MetricLogger(path)
    log.log({"round": 0, "train_loss": 2.0})
    # the val_ppl column appears only later (eval rounds) — the old logger
    # silently discarded it forever; now the header widens atomically
    log.log({"round": 1, "train_loss": 1.5, "val_ppl": 33.0})
    log.log({"round": 2, "train_loss": 1.2, "val_ppl": 30.0})
    rows = log.read()
    assert [r["val_ppl"] for r in rows] == ["", "33.0", "30.0"]
    with open(path) as f:
        header = f.readline().strip().split(",")
    assert header == ["round", "train_loss", "val_ppl"]


def test_metric_logger_resume_unions_existing_header(tmp_path):
    path = str(tmp_path / "log.csv")
    MetricLogger(path).log({"round": 0, "train_loss": 2.0})
    # a resumed run constructs a fresh logger against the existing file and
    # logs a wider row: old rows pad, nothing is lost
    log2 = MetricLogger(path)
    log2.log({"round": 1, "train_loss": 1.5, "val_ppl": 28.0})
    rows = log2.read()
    assert [r["round"] for r in rows] == ["0.0", "1.0"]
    assert rows[0]["val_ppl"] == "" and rows[1]["val_ppl"] == "28.0"


# ---------------------------------------------------------------------------
# The read-only invariant: tracing changes NOTHING (bitwise)
# ---------------------------------------------------------------------------


def _cfgs(partial=False):
    tau = 3
    fed = FederatedConfig(
        clients_per_round=2, local_steps=tau, inner=sgd_inner(lr=0.05),
        outer=OuterOptConfig(name="fedadam", lr=0.3),
    )
    acfg = AsyncAggConfig(buffer_size=2, staleness_alpha=0.5, max_staleness=0)
    pcfg = ParticipationConfig(
        population=6, clients_per_round=2, dropout_rate=0.1,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="uniform",
        partial_progress=partial, local_steps=tau if partial else 0,
    )
    mb = lambda cid: make_batches(tau, 1, seed=100 + cid)
    return fed, acfg, pcfg, mb


def _async_driver(codec, partial, tracer):
    fed, acfg, pcfg, mb = _cfgs(partial)
    return AsyncFederationDriver(
        quad_loss, fed, acfg, pcfg, mb, seed=3,
        params=make_params(), rng=jax.random.PRNGKey(0), codec=codec,
        tracer=tracer,
    )


@pytest.mark.parametrize(
    "codec,partial",
    [(None, False), (Int8Codec(), False), (TopKCodec(k_fraction=0.25), True)],
    ids=["plain", "int8", "topk-ef-partial"],
)
def test_tracing_leaves_async_run_bitwise_unchanged(codec, partial, tmp_path):
    ref = _async_driver(codec, partial, tracer=None)
    h_ref = ref.run_updates(5)

    tracer = Tracer(JsonlSink(str(tmp_path / "server.jsonl")), proc="server",
                    trace_id="seed3")
    drv = _async_driver(codec, partial, tracer=tracer)
    h = drv.run_updates(5)

    assert h == h_ref  # every host-side metric row, float for float
    t_ref, m_ref = ref.checkpoint()
    t, m = drv.checkpoint()
    assert m == m_ref
    for a, b in zip(jax.tree_util.tree_leaves(t_ref),
                    jax.tree_util.tree_leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    drv.finalize_trace()
    tracer.close()
    events = load_run(str(tmp_path))
    assert check_run(events) == []  # and the trace it left behind is coherent
    closed, _ = span_pairs(events)
    assert any(c["name"] == "dispatch" and c["attrs"].get("outcome") == "admitted"
               for c in closed)


def test_tracing_leaves_sync_round_bitwise_unchanged(tmp_path):
    tau, c = 2, 3
    fed = FederatedConfig(
        clients_per_round=c, local_steps=tau, inner=sgd_inner(),
        outer=OuterOptConfig(name="fedadam", lr=0.1),
    )
    pcfg = ParticipationConfig(population=4, clients_per_round=c)
    ref = SyncAggregator(
        quad_loss, fed, pcfg, seed=0, params=make_params(),
        rng=jax.random.PRNGKey(1),
    )
    tracer = Tracer(JsonlSink(str(tmp_path / "sync.jsonl")), proc="server")
    traced = SyncAggregator(
        quad_loss, fed, pcfg, seed=0, params=make_params(),
        rng=jax.random.PRNGKey(1), tracer=tracer,
    )
    for r in range(3):
        b = make_batches(tau, c, seed=70 + r)
        m_ref = {k: float(v) for k, v in ref.run_round(b, ref.plan(r)).items()}
        m_tr = {k: float(v) for k, v in traced.run_round(b, traced.plan(r)).items()}
        assert m_ref == m_tr
    for a, b in zip(jax.tree_util.tree_leaves(ref.state),
                    jax.tree_util.tree_leaves(traced.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tracer.close()
    closed, opened = span_pairs(read_events(str(tmp_path / "sync.jsonl")))
    assert opened == []
    assert [c["span"] for c in closed] == ["r0", "r1", "r2"]
    assert all("train_loss" in c["attrs"] for c in closed)
