"""Cross-process federation runtime (PR 6).

Three layers of guarantees, cheapest first:

1. **Transport units** — the length-prefixed wire format round-trips arbitrary
   pytrees (bfloat16 included) and fails loudly on truncation; backoff gives
   up; chaos dice are seeded and validated.
2. **Seam parity** — :class:`FederationDriver` over :class:`LocalClientBackend`
   IS the legacy ``AsyncFederationDriver``, bitwise: same flush rows, same
   checkpoint pytree, same manifest.
3. **Socket runtime** — a real server socket plus worker threads produces the
   same bits as the in-process simulator; an abandoned lease redispatches;
   killing the server between updates and resuming from its checkpoint yields
   a bitwise-matching remainder; deadline flushes fire on stalls and are
   harmless no-ops on an empty buffer.

Everything here runs the 4×4 quadratic model — seconds, not minutes. The
3-process (real subprocess) acceptance test lives in this file too, marked
``slow`` aside from a trimmed smoke.
"""
import os
import socket
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batches, make_params, quad_loss, sgd_inner
from repro.core import (
    AsyncAggConfig,
    AsyncFederationDriver,
    Bf16Codec,
    FederatedConfig,
    IdentityCodec,
    Int8Codec,
    OuterOptConfig,
    ParticipationConfig,
    STRAGGLER_PROFILES,
    TopKCodec,
)
from repro.obs import JsonlSink, Tracer, check_run, load_run
from repro.runtime import (
    Backoff,
    ChaosConfig,
    ChaosMonkey,
    ClientWorker,
    FederationDriver,
    LocalClientBackend,
    SocketBackend,
    TransportError,
    connect,
    decode_msg,
    encode_msg,
    recv_msg,
    send_msg,
)
from repro.runtime.transport import SEP, flatten_tree, unflatten_tree


# ---------------------------------------------------------------------------
# Wire format
# ---------------------------------------------------------------------------


def test_tree_flatten_roundtrip_including_bfloat16():
    tree = {
        "block": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                  "b": jnp.zeros((3,), jnp.int32)},
        "scale": jnp.asarray(2.5, jnp.float32),
    }
    items = flatten_tree(tree, "f")
    back = unflatten_tree({path.partition(SEP)[2]: arr for path, arr in items})
    la, lb = jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)
    assert jax.tree_util.tree_structure(tree) == jax.tree_util.tree_structure(back)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tree_flatten_roundtrip_with_list_and_tuple_nodes():
    # the transformer params keep per-layer segments as a LIST — container
    # types must survive the wire exactly or tree_map against live state fails
    tree = {
        "segments": [
            {"w": jnp.ones((2,))}, {"w": jnp.full((2,), 2.0)},
        ],
        "pair": (jnp.zeros((1,)), jnp.ones((1,))),
    }
    back = unflatten_tree(
        {p.partition(SEP)[2]: a for p, a in flatten_tree(tree, "f")}
    )
    assert jax.tree_util.tree_structure(tree) == jax.tree_util.tree_structure(back)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tree_flatten_rejects_separator_in_keys():
    with pytest.raises(ValueError):
        flatten_tree({"a\x1fb": jnp.zeros(2)}, "f")


def test_message_roundtrip_with_bare_array_tree():
    trees = {"payload": {"w": jnp.ones((2, 2))}, "rng": jax.random.PRNGKey(7)}
    raw = encode_msg("work", {"index": 3, "client": 1, "nested": {"t": [1, 2]}}, trees)
    msg = decode_msg(raw)
    assert msg.type == "work"
    assert msg.meta == {"index": 3, "client": 1, "nested": {"t": [1, 2]}}
    np.testing.assert_array_equal(
        np.asarray(msg.trees["payload"]["w"]), np.ones((2, 2), np.float32)
    )
    np.testing.assert_array_equal(  # bare (non-dict) tree survives
        np.asarray(msg.trees["rng"]), np.asarray(jax.random.PRNGKey(7))
    )


def test_truncated_frame_raises_transport_error():
    a, b = socket.socketpair()
    try:
        raw = encode_msg("pull", {"worker": "w0"})
        frame = len(raw).to_bytes(8, "big") + raw
        a.sendall(frame[: len(frame) // 2])
        a.close()
        with pytest.raises(TransportError):
            recv_msg(b)
    finally:
        b.close()


def test_socket_send_recv_roundtrip():
    a, b = socket.socketpair()
    try:
        assert send_msg(a, "push", {"index": 1, "loss": 0.5}, {"payload": jnp.ones(3)})
        msg = recv_msg(b)
        assert msg.type == "push" and msg.meta["index"] == 1
    finally:
        a.close()
        b.close()


def test_backoff_bounded_and_gives_up():
    bo = Backoff(base=0.001, cap=0.002, give_up_after=0.01)
    results = [bo.sleep() for _ in range(40)]
    assert results[0] is True
    assert results[-1] is False  # exhausted the give-up budget
    bo.reset()
    assert bo.sleep() is True  # reset re-arms the budget


# ---------------------------------------------------------------------------
# Chaos
# ---------------------------------------------------------------------------


def test_chaos_config_validates_probabilities():
    with pytest.raises(ValueError):
        ChaosConfig(drop=1.5)
    with pytest.raises(ValueError):
        ChaosConfig(kill=-0.1)
    assert not ChaosConfig().active
    assert ChaosConfig(delay=0.2).active


def test_chaos_rolls_are_seeded_per_role():
    cfg = ChaosConfig(drop=0.5, delay=0.25, seed=11)
    rolls = lambda role: [ChaosMonkey(cfg, role)._rng.random() for _ in range(8)]
    assert rolls("w0") == rolls("w0")  # reproducible
    assert rolls("w0") != rolls("server")  # independent per role
    assert ChaosMonkey(ChaosConfig(drop=1.0), "x").on_send() is True
    assert ChaosMonkey(ChaosConfig(), "x").on_send() is False


# ---------------------------------------------------------------------------
# Shared fixtures for driver parity
# ---------------------------------------------------------------------------


def _cfgs(partial=False, max_staleness=0):
    tau = 3
    fed = FederatedConfig(
        clients_per_round=2, local_steps=tau, inner=sgd_inner(lr=0.05),
        outer=OuterOptConfig(name="fedadam", lr=0.3),
    )
    acfg = AsyncAggConfig(
        buffer_size=2, staleness_alpha=0.5, max_staleness=max_staleness
    )
    pcfg = ParticipationConfig(
        population=6, clients_per_round=2, dropout_rate=0.1,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="uniform",
        partial_progress=partial, local_steps=tau if partial else 0,
    )
    mb = lambda cid: make_batches(tau, 1, seed=100 + cid)
    return fed, acfg, pcfg, mb


def _reference(codec=None, partial=False, max_staleness=0, n=5):
    fed, acfg, pcfg, mb = _cfgs(partial, max_staleness)
    drv = AsyncFederationDriver(
        quad_loss, fed, acfg, pcfg, mb, seed=3,
        params=make_params(), rng=jax.random.PRNGKey(0), codec=codec,
    )
    return drv, drv.run_updates(n)


def _assert_same_run(ref, drv, h_ref, h_drv):
    assert h_ref == h_drv
    t_ref, m_ref = ref.checkpoint()
    t_drv, m_drv = drv.checkpoint()
    assert m_ref == m_drv
    for a, b in zip(jax.tree_util.tree_leaves(t_ref), jax.tree_util.tree_leaves(t_drv)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _strip_update(rows):
    return [{k: v for k, v in r.items() if k != "update"} for r in rows]


# ---------------------------------------------------------------------------
# Seam parity: LocalClientBackend == legacy in-process driver, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "codec,partial,max_staleness",
    [(None, False, 0), (TopKCodec(k_fraction=0.25), False, 0),
     (TopKCodec(k_fraction=0.25), True, 2)],
    ids=["plain", "topk", "topk-partial-stale"],
)
def test_local_backend_is_bitwise_equal_to_async_driver(codec, partial, max_staleness):
    ref, h_ref = _reference(codec, partial, max_staleness)
    fed, acfg, pcfg, mb = _cfgs(partial, max_staleness)
    backend = LocalClientBackend(quad_loss, fed, pcfg, mb, codec=codec)
    drv = FederationDriver(
        backend, fed, acfg, pcfg, seed=3,
        params=make_params(), rng=jax.random.PRNGKey(0), codec=codec,
    )
    _assert_same_run(ref, drv, h_ref, drv.run_updates(5))


# ---------------------------------------------------------------------------
# Socket runtime (worker threads against a real localhost socket)
# ---------------------------------------------------------------------------


def _start_workers(fed, pcfg, mb, port, codec, n=2, **kw):
    workers = [
        ClientWorker(
            quad_loss, fed, pcfg, make_batches=mb, port=port, codec=codec,
            name=f"w{i}", io_timeout=5.0, **kw,
        )
        for i in range(n)
    ]
    threads = [threading.Thread(target=w.run, daemon=True) for w in workers]
    for t in threads:
        t.start()
    return workers, threads


def _stop(backend, threads):
    backend.close(linger=0.2)
    for t in threads:
        t.join(timeout=10)


def test_socket_round_is_bitwise_equal_to_inprocess():
    codec = TopKCodec(k_fraction=0.25)
    ref, h_ref = _reference(codec)
    fed, acfg, pcfg, mb = _cfgs()
    backend = SocketBackend(port=0, lease_timeout=10.0, io_timeout=5.0)
    _, threads = _start_workers(fed, pcfg, mb, backend.port, codec)
    try:
        drv = FederationDriver(
            backend, fed, acfg, pcfg, seed=3,
            params=make_params(), rng=jax.random.PRNGKey(0), codec=codec,
        )
        _assert_same_run(ref, drv, h_ref, drv.run_updates(5))
    finally:
        _stop(backend, threads)


def test_expired_lease_is_redispatched_to_a_live_worker():
    """A worker that pulls an assignment and dies must not wedge the round:
    after ``lease_timeout`` the slot is re-granted and the run still produces
    the in-process simulator's exact bits (idempotent assignments)."""
    codec = TopKCodec(k_fraction=0.25)
    ref, h_ref = _reference(codec, n=3)
    fed, acfg, pcfg, mb = _cfgs()
    backend = SocketBackend(port=0, lease_timeout=0.4, io_timeout=5.0)
    drv = FederationDriver(  # constructing dispatches the first K slots
        backend, fed, acfg, pcfg, seed=3,
        params=make_params(), rng=jax.random.PRNGKey(0), codec=codec,
    )
    # the vulture: pulls a work assignment, then dies without pushing
    vulture = connect("127.0.0.1", backend.port, timeout=5.0)
    send_msg(vulture, "pull", {"worker": "vulture"})
    stolen = recv_msg(vulture)
    assert stolen.type == "work"
    vulture.close()
    with backend._lock:
        assert stolen.meta["index"] in backend._leases
    _, threads = _start_workers(fed, pcfg, mb, backend.port, codec)
    try:
        _assert_same_run(ref, drv, h_ref, drv.run_updates(3))
    finally:
        _stop(backend, threads)


def test_server_kill_and_resume_is_bitwise():
    """The acceptance shape: run two outer updates, checkpoint, tear the whole
    server+workers world down (the 'kill'), rebuild from the checkpoint alone,
    and finish the run — every remaining row and the final state must match the
    uninterrupted run bit for bit."""
    codec = TopKCodec(k_fraction=0.25)
    ref, h_ref = _reference(codec, n=5)

    fed, acfg, pcfg, mb = _cfgs()
    backend = SocketBackend(port=0, lease_timeout=10.0, io_timeout=5.0)
    _, threads = _start_workers(fed, pcfg, mb, backend.port, codec)
    drv = FederationDriver(
        backend, fed, acfg, pcfg, seed=3,
        params=make_params(), rng=jax.random.PRNGKey(0), codec=codec,
    )
    h_pre = drv.run_updates(2)
    tree, manifest = drv.checkpoint()
    _stop(backend, threads)  # SIGKILL stand-in: nothing survives but the ckpt
    del drv, backend

    fed, acfg, pcfg, mb = _cfgs()
    backend2 = SocketBackend(port=0, lease_timeout=10.0, io_timeout=5.0)
    _, threads2 = _start_workers(fed, pcfg, mb, backend2.port, codec)
    try:
        drv2 = FederationDriver(  # _restore_dispatch re-submits in-flight slots
            backend2, fed, acfg, pcfg, seed=3, codec=codec,
            state=tree, dispatch=manifest,
        )
        h_post = drv2.run_updates(3)
        assert _strip_update(h_pre) == _strip_update(h_ref[:2])
        assert _strip_update(h_post) == _strip_update(h_ref[2:])
        t_ref, m_ref = ref.checkpoint()
        t2, m2 = drv2.checkpoint()
        assert m_ref == m2
        for a, b in zip(
            jax.tree_util.tree_leaves(t_ref), jax.tree_util.tree_leaves(t2)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        _stop(backend2, threads2)


# ---------------------------------------------------------------------------
# Deadline flush
# ---------------------------------------------------------------------------


class _StallingBackend(LocalClientBackend):
    """Simulates a straggling network: raises TimeoutError for the first
    ``stalls`` driver waits (when armed with a deadline), then serves."""

    def __init__(self, *a, stalls=0, **kw):
        super().__init__(*a, **kw)
        self.stalls = stalls
        self.calls = 0

    def result(self, index, timeout=None):
        self.calls += 1
        if timeout is not None and self.stalls > 0:
            self.stalls -= 1
            raise TimeoutError(f"slot {index} stalled (injected)")
        return super().result(index, timeout)


def test_deadline_flush_on_empty_buffer_is_a_state_noop():
    """Stalls before anything was admitted: the deadline flush fires on an
    empty buffer and must change NOTHING — the run's remaining history is
    bitwise-identical to the never-stalled run."""
    ref, h_ref = _reference(None, n=4)
    fed, acfg, pcfg, mb = _cfgs()
    backend = _StallingBackend(quad_loss, fed, pcfg, mb, stalls=3)
    drv = FederationDriver(
        backend, fed, acfg, pcfg, seed=3, flush_deadline=0.01,
        params=make_params(), rng=jax.random.PRNGKey(0),
    )
    h = drv.run_updates(4)
    assert backend.calls > 4  # the stalls really happened
    _assert_same_run(ref, drv, h_ref, h)


def test_deadline_flush_emits_partial_round_when_buffer_nonempty():
    fed, acfg, pcfg, mb = _cfgs()
    backend = _StallingBackend(quad_loss, fed, pcfg, mb, stalls=0)
    drv = FederationDriver(
        backend, fed, acfg, pcfg, seed=3, flush_deadline=0.01,
        params=make_params(), rng=jax.random.PRNGKey(0),
    )
    # drain to a known half-full buffer, then stall the next wait: the deadline
    # flush must emit a PARTIAL (fill < buffer_size) outer update
    drv.run_updates(1)
    while int(drv.state["buf_count"]) != 1:
        drv.step()
    round_before = int(drv.state["round"])
    backend.stalls = 1
    rows = []
    while not rows:
        rows = drv.step()
    assert rows[0]["buffer_fill"] == 1.0  # flushed half-full, not buffer_size
    assert int(drv.state["round"]) > round_before
    assert backend.stalls == 0


# ---------------------------------------------------------------------------
# Uplink byte accounting: the wire agrees with the codec's analytic claim
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "codec", [IdentityCodec(), Bf16Codec(), Int8Codec()],
    ids=["float32", "bf16", "int8"],
)
def test_encoded_payload_bytes_match_codec_analytic(codec):
    """For the dense codecs, the bytes that actually cross the wire (the sum
    of the encoded payload's leaf buffers — exactly what the socket frame
    ships and what the server's ``payload_bytes_rx`` sums) must equal the
    analytic ``uplink_bytes`` claim the comm tables are built from. Top-k is
    deliberately excluded: its wire payload is dense-with-zeros while the
    analytic count bills the (index, value) sparse format."""
    params = make_params()
    delta = jax.tree_util.tree_map(lambda x: x * 0.1, params)
    rng = jax.random.PRNGKey(5) if codec.needs_rng else None
    payload, _ = codec.encode(delta, rng=rng)
    wire = float(
        sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(payload))
    )
    assert wire == codec.payload_nbytes(payload) == codec.nbytes(params)


def test_transport_counts_framed_bytes_symmetrically():
    sender, receiver = Tracer(proc="tx"), Tracer(proc="rx")
    a, b = socket.socketpair()
    try:
        assert send_msg(a, "push", {"index": 1}, {"payload": jnp.ones(3)},
                        tracer=sender)
        msg = recv_msg(b, tracer=receiver)
        assert msg.meta["index"] == 1
    finally:
        a.close()
        b.close()
    tx, rx = sender.snapshot()["counters"], receiver.snapshot()["counters"]
    raw = encode_msg("push", {"index": 1}, {"payload": jnp.ones(3)})
    assert tx["bytes_tx"] == rx["bytes_rx"] == len(raw) + 12  # + len prefix + CRC
    assert tx["msgs_tx"] == rx["msgs_rx"] == 1


def test_traced_socket_run_byte_counters_and_parity(tmp_path):
    """End-to-end traced socket run (int8 uplink): (a) the server's measured
    per-push payload bytes equal the codec's analytic bytes × accepted pushes;
    (b) the driver's analytic ``uplink_bytes_total`` counts exactly its
    processed uploads; (c) the run's bits are IDENTICAL to the untraced run —
    tracing is read-only; (d) the merged trace passes the structural check."""
    codec = Int8Codec()
    ref, h_ref = _reference(codec)
    fed, acfg, pcfg, mb = _cfgs()
    tracer = Tracer(JsonlSink(str(tmp_path / "server.jsonl")), proc="server",
                    trace_id="t")
    backend = SocketBackend(port=0, lease_timeout=10.0, io_timeout=5.0,
                            tracer=tracer)
    wtracers = [
        Tracer(JsonlSink(str(tmp_path / f"w{i}.jsonl")), proc=f"w{i}",
               trace_id="t")
        for i in range(2)
    ]
    workers = [
        ClientWorker(quad_loss, fed, pcfg, make_batches=mb, port=backend.port,
                     codec=codec, name=f"w{i}", io_timeout=5.0, tracer=wtracers[i])
        for i in range(2)
    ]
    threads = [threading.Thread(target=w.run, daemon=True) for w in workers]
    for t in threads:
        t.start()
    try:
        drv = FederationDriver(
            backend, fed, acfg, pcfg, seed=3,
            params=make_params(), rng=jax.random.PRNGKey(0), codec=codec,
            tracer=tracer,
        )
        _assert_same_run(ref, drv, h_ref, drv.run_updates(5))
    finally:
        _stop(backend, threads)
    drv.finalize_trace()
    tracer.close()
    for wt in wtracers:
        wt.close()

    per_upload = codec.nbytes(make_params())
    counters = tracer.snapshot()["counters"]
    accepted_pushes = counters["pushes"] - counters.get("dedup_drops", 0)
    assert backend.payload_bytes_rx == per_upload * accepted_pushes
    assert backend.payload_bytes_rx == counters["payload_bytes_rx"]
    # uploads whose payload bytes the driver actually accounted: admitted or
    # rejected-at-admission (no_show never uploads; inflight never arrived;
    # a stale stateless upload is discarded before the byte accounting)
    processed = sum(
        v for k, v in counters.items()
        if k in ("outcome_admitted", "outcome_rejected")
    )
    assert drv.uplink_bytes_total == per_upload * processed
    assert counters["bytes_tx"] > 0 and counters["bytes_rx"] > 0

    events = load_run(str(tmp_path))
    assert check_run(events) == []


# ---------------------------------------------------------------------------
# Real 3-process acceptance (1 server + 2 worker subprocesses of train.py)
# ---------------------------------------------------------------------------


@pytest.mark.slow  # full train.py subprocesses with jit compiles (~1-2 min each)
@pytest.mark.parametrize("demo", ["round", "kill-resume", "chaos"])
def test_three_process_localhost_round(demo):
    """Drives examples/socket_federation.py, which asserts internally:
    ``round`` — socket final server.npz bitwise == inproc; ``kill-resume`` —
    SIGKILL the server after its first checkpoint, resume, final state bitwise
    == uninterrupted; ``chaos`` — drop/delay/kill injection still completes."""
    script = os.path.join(
        os.path.dirname(__file__), "..", "examples", "socket_federation.py"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, script, "--demo", demo],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "PASS" in out.stdout
