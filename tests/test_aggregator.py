"""The unified Aggregator seam (core/aggregator.py): straggler partial
progress and resumable async dispatch.

Keystone identities:
  - partial progress with every client at full speed is BITWISE the PR-3
    round (rng + DP + uplink-residual lanes included) — the τ-mask and the
    τ_i/τ weight scale are exact no-ops at τ_i = τ;
  - a client credited τ_i < τ steps produces exactly the delta of a τ_i-step
    round on the same data (the mask really freezes the spent lanes);
  - a killed-and-resumed async run is BITWISE the uninterrupted run — buffer
    lanes, dispatch cursor, in-flight snapshots/version tags, uplink residuals
    and the simulated clock all round-trip through the canonical checkpoint
    schema (state pytree + JSON manifest).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_batches, make_params, quad_loss, sgd_inner

from repro.checkpoint import CheckpointManager
from repro.core import (
    STRAGGLER_PROFILES,
    AsyncAggConfig,
    AsyncBufferAggregator,
    AsyncFederationDriver,
    AsyncTimeline,
    FederatedConfig,
    OuterOptConfig,
    ParticipationConfig,
    StragglerProfile,
    SyncAggregator,
    TopKCodec,
    federated_round,
    init_federated_state,
    partial_progress_weights,
    plan_round,
    run_clients,
)


def _fed(c, tau, **kw):
    return FederatedConfig(
        clients_per_round=c, local_steps=tau, inner=sgd_inner(),
        outer=OuterOptConfig(name="fedavg", lr=1.0), **kw,
    )


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# plan_round partial progress: τ_i derivation + admission rule
# ---------------------------------------------------------------------------


def test_plan_round_partial_progress_derives_tau_and_admits_stragglers():
    tau = 8
    cfg = ParticipationConfig(
        population=16, clients_per_round=16,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="examples",
        partial_progress=True, local_steps=tau,
    )
    cut = ParticipationConfig(
        population=16, clients_per_round=16,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="examples",
    )
    deadline = STRAGGLER_PROFILES["heavy"].deadline
    saw_partial = False
    for r in range(10):
        plan = plan_round(cfg, 11, r)
        ref = plan_round(cut, 11, r)
        assert plan.local_steps is not None
        # τ_i = min(τ, ⌊τ·speed·deadline⌋) wherever admitted
        expect = np.minimum(tau, np.floor(tau * plan.speeds * deadline))
        np.testing.assert_array_equal(
            plan.local_steps[plan.mask], expect[plan.mask]
        )
        assert (plan.local_steps[~plan.mask] == 0).all()
        assert (plan.local_steps[plan.mask] >= 1).all()
        # the admission rule got STRICTLY more permissive than the deadline cut:
        # every deadline-cut contributor still contributes, and slow-but-not-
        # hopeless clients join with τ_i < τ
        assert (plan.mask | ~ref.mask).all()
        rescued = plan.mask & ~ref.mask
        if rescued.any():
            saw_partial = True
            assert (plan.local_steps[rescued] < tau).all()
        # raw plan weights stay UNSCALED n_k·mask — the τ_i/τ scale is the
        # aggregator's weight policy, not the sampler's
        assert (plan.weights[plan.mask] > 0).all()
    assert saw_partial, "heavy profile produced no partial clients in 10 rounds"


def test_rescued_client_keeps_its_realized_budget():
    """dropout 1.0 forces the empty-round rescue every round: the resurrected
    client must be credited its REAL τ_i (floored at 1), not a hardcoded single
    step — at full speed that is the full τ, so the bitwise full-speed identity
    survives the rescue firing."""
    tau = 8
    for profile in (StragglerProfile("eq", 0.0, 1.5), STRAGGLER_PROFILES["heavy"]):
        cfg = ParticipationConfig(
            population=8, clients_per_round=4, dropout_rate=1.0,
            straggler=profile, partial_progress=True, local_steps=tau,
        )
        for r in range(5):
            plan = plan_round(cfg, 5, r)
            assert plan.effective_k == 1
            idx = int(np.flatnonzero(plan.mask)[0])
            expect = min(tau, int(np.floor(tau * plan.speeds[idx] * profile.deadline)))
            assert plan.local_steps[idx] == max(1, expect)


def test_partial_progress_requires_tau():
    with pytest.raises(ValueError):
        ParticipationConfig(
            population=4, clients_per_round=2, partial_progress=True
        )


def test_partial_progress_weight_policy():
    w = np.asarray([2.0, 0.0, 4.0, 1.0], np.float32)
    ls = np.asarray([4, 0, 2, 1], np.int64)
    out = partial_progress_weights(w, ls, 4)
    np.testing.assert_allclose(out, [2.0, 0.0, 2.0, 0.25], rtol=1e-7)
    # τ_i = τ everywhere: bitwise the unscaled weights (×1.0 is exact)
    np.testing.assert_array_equal(
        partial_progress_weights(w, np.full(4, 4, np.int64), 4), w
    )
    # no τ-vector: pass-through
    np.testing.assert_array_equal(partial_progress_weights(w, None, 4), w)


try:
    from hypothesis import given, settings, strategies as st

    @given(
        n=st.integers(2, 12),
        tau=st.integers(1, 32),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=25, deadline=None)
    def test_partial_weights_are_convex_normalization(n, tau, seed):
        """Normalized partial-progress weights form a convex combination:
        Σw = 1, w_i ∝ n_k,i·τ_i/τ, and zero exactly where masked."""
        rng = np.random.default_rng(seed)
        n_k = rng.lognormal(0.0, 1.0, n).astype(np.float32)
        mask = rng.random(n) < 0.7
        if not mask.any():
            mask[int(rng.integers(n))] = True
        ls = np.where(mask, rng.integers(1, tau + 1, n), 0)
        raw = (n_k * mask).astype(np.float32)
        w = partial_progress_weights(raw, ls, tau)
        assert (w[~mask] == 0).all()
        assert (w[mask] > 0).all()
        p = np.asarray(w, np.float64) / np.sum(w, dtype=np.float64)
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-9)
        ref = n_k * mask * (ls / tau)
        np.testing.assert_allclose(p, ref / ref.sum(), rtol=1e-4, atol=1e-7)
except ImportError:  # pragma: no cover — optional dep
    pass


# ---------------------------------------------------------------------------
# The τ-mask inside the scan
# ---------------------------------------------------------------------------


def test_full_tau_mask_is_bitwise_no_mask():
    """τ_i = τ for every client must reproduce the PR-3 round BITWISE — rng,
    DP clip/noise and top-k error-feedback residual lanes included."""
    tau, c = 4, 4
    params = make_params()
    batches = make_batches(tau, c)
    w = jnp.asarray([1.0, 2.0, 0.5, 3.0], jnp.float32)
    full = jnp.full((c,), tau, jnp.int32)
    for codec in (None, TopKCodec(k_fraction=0.25)):
        fed = _fed(c, tau, dp_clip=0.1, dp_noise=0.01)
        s0 = init_federated_state(fed, params, jax.random.PRNGKey(3))
        res = (
            jax.tree_util.tree_map(lambda p: jnp.zeros((c,) + p.shape), params)
            if codec is not None else None
        )
        base, m_base = jax.jit(
            lambda s, b: federated_round(
                quad_loss, fed, s, b, client_weights=w, codec=codec, residuals=res
            )
        )(s0, batches)
        masked, m_masked = jax.jit(
            lambda s, b, t: federated_round(
                quad_loss, fed, s, b, client_weights=w, codec=codec,
                residuals=res, tau_steps=t,
            )
        )(s0, batches, full)
        _assert_trees_equal(base, masked)
        for k in m_base:
            np.testing.assert_array_equal(
                np.asarray(m_base[k]), np.asarray(m_masked[k]), err_msg=k
            )


def test_all_partial_cohort_metrics_forward_fill_dead_steps():
    """When every contributor realizes τ_i < τ, the scan's tail steps have no
    active client — the round metrics must carry the LAST LIVE step's signal,
    not report train_loss = 0 (regression: zero-diluted loss trajectories)."""
    tau, c = 4, 3
    fed = _fed(c, tau)
    params = make_params()
    batches = make_batches(tau, c)
    w = jnp.ones((c,), jnp.float32)
    taus = jnp.asarray([2, 2, 1], jnp.int32)  # nobody reaches τ
    s0 = init_federated_state(fed, params)
    _, m = federated_round(
        quad_loss, fed, s0, batches, client_weights=w, tau_steps=taus
    )
    assert float(m["train_loss"]) > 0.1  # the τ_i=2 clients' step-1 loss
    assert float(m["train_loss_mean"]) > 0.1
    # the filled last step equals a truncated run's genuine last step
    ref, m_ref = federated_round(
        quad_loss, _fed(c, 2),
        init_federated_state(_fed(c, 2), params),
        {k: v[:2] for k, v in batches.items()},
        client_weights=w, tau_steps=jnp.asarray([2, 2, 1], jnp.int32),
    )
    np.testing.assert_array_equal(
        np.asarray(m["train_loss"]), np.asarray(m_ref["train_loss"])
    )


def test_async_partial_flush_rows_never_report_zero_loss():
    drv, *_ = _driver(partial=True)
    hist = drv.run_updates(6)
    assert all(r["train_loss_mean"] > 0.01 for r in hist), [
        r["train_loss_mean"] for r in hist
    ]


def test_partial_client_delta_equals_truncated_round():
    """A client masked to τ_i steps must emit exactly the delta of a τ_i-step
    round on the same leading batches — the held lanes really are frozen."""
    tau, tau_i, c = 5, 2, 3
    fed = _fed(c, tau)
    params = make_params()
    batches = make_batches(tau, c)
    taus = jnp.asarray([tau_i, tau, tau], jnp.int32)
    s0 = init_federated_state(fed, params)  # round 0: LR schedules align

    deltas, _ = run_clients(quad_loss, fed, s0, batches, tau_steps=taus)

    fed_short = _fed(c, tau_i)
    short_b = {k: v[:tau_i] for k, v in batches.items()}
    deltas_short, _ = run_clients(
        quad_loss, fed_short, init_federated_state(fed_short, params), short_b
    )
    np.testing.assert_array_equal(
        np.asarray(deltas["w"][0]), np.asarray(deltas_short["w"][0])
    )
    # the full-τ clients are untouched by their neighbors' masks
    full_deltas, _ = run_clients(quad_loss, fed, s0, batches)
    np.testing.assert_array_equal(
        np.asarray(deltas["w"][1]), np.asarray(full_deltas["w"][1])
    )


# ---------------------------------------------------------------------------
# SyncAggregator: seam == direct kernel; partial rescues stragglers
# ---------------------------------------------------------------------------


def test_sync_aggregator_full_speed_partial_bitwise_equals_plain():
    """Under a deadline nobody misses (speeds ≡ 1), the partial-progress
    aggregator must be BITWISE the plain one, dropout masks and all."""
    tau, c = 3, 4
    fed = _fed(c, tau, dp_clip=0.5, dp_noise=0.01)
    pcfg = ParticipationConfig(
        population=8, clients_per_round=c, dropout_rate=0.3,
        straggler=StragglerProfile("eq", 0.0, 1.5), weighting="examples",
    )
    params = make_params()
    plain = SyncAggregator(
        quad_loss, fed, pcfg, seed=7, params=params,
        rng=jax.random.PRNGKey(9),
    )
    partial = SyncAggregator(
        quad_loss, fed, pcfg, seed=7, params=params,
        rng=jax.random.PRNGKey(9), partial_progress=True,
    )
    for r in range(3):
        b = make_batches(tau, c, seed=30 + r)
        pl_a, pl_b = plain.plan(r), partial.plan(r)
        assert pl_b.local_steps is not None
        assert (pl_b.local_steps[pl_b.mask] == tau).all()
        np.testing.assert_array_equal(pl_a.mask, pl_b.mask)
        m_a = plain.run_round(b, pl_a)
        m_b = partial.run_round(b, pl_b)
        _assert_trees_equal(plain.state, partial.state)
        for k in m_a:
            np.testing.assert_array_equal(
                np.asarray(m_a[k]), np.asarray(m_b[k]), err_msg=k
            )


def test_sync_aggregator_partial_rescues_straggler_work():
    """Heavy profile: the partial aggregator admits more clients per round at
    fractional weights, and its checkpoint round-trips through the manager."""
    tau, c = 4, 8
    fed = _fed(c, tau)
    pcfg = ParticipationConfig(
        population=8, clients_per_round=c,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="examples",
    )
    params = make_params()
    cut = SyncAggregator(quad_loss, fed, pcfg, seed=5, params=params)
    part = SyncAggregator(
        quad_loss, fed, pcfg, seed=5, params=params, partial_progress=True
    )
    admitted_cut = admitted_part = 0
    for r in range(6):
        admitted_cut += cut.plan(r).effective_k
        plan = part.plan(r)
        admitted_part += plan.effective_k
        w = part.round_weights(plan)
        frac = plan.local_steps[plan.mask] / tau
        np.testing.assert_allclose(
            w[plan.mask], plan.weights[plan.mask] * frac, rtol=1e-6
        )
    assert admitted_part > admitted_cut  # stragglers rescued, not cut


def test_sync_aggregator_checkpoint_schema_roundtrip(tmp_path):
    tau, c = 2, 2
    fed = _fed(c, tau)
    pcfg = ParticipationConfig(population=4, clients_per_round=c)
    agg = SyncAggregator(
        quad_loss, fed, pcfg, codec=TopKCodec(k_fraction=0.5), seed=0,
        params=make_params(), partial_progress=True,
    )
    plan = agg.plan(0)
    agg.run_round(make_batches(tau, c), plan)
    tree, manifest = agg.checkpoint()
    assert manifest["kind"] == "sync" and manifest["round"] == 1
    # the residual lane is sparse: one row per ever-selected client, with the
    # id set recorded in the manifest (never a dense (P, ...) expansion)
    assert manifest["uplink_ids"] == agg.residual_store.ids()
    assert jax.tree_util.tree_leaves(tree["uplink_residuals"])[0].shape[0] == len(
        manifest["uplink_ids"]
    )
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save_server(0, tree, extra={"aggregator": manifest})
    like = SyncAggregator.checkpoint_template(
        fed, agg.pcfg, make_params(), codec=TopKCodec(k_fraction=0.5),
        uplink_ids=manifest["uplink_ids"],
    )
    restored, man = ckpt.load_server(0, like)
    _assert_trees_equal(tree, restored)
    assert man["extra"]["aggregator"] == manifest

    # restore() routes the sparse lane back into an equivalent store
    agg2 = SyncAggregator(
        quad_loss, fed, agg.pcfg, codec=TopKCodec(k_fraction=0.5), seed=0,
        params=make_params(), partial_progress=True,
    )
    agg2.restore(restored, man["extra"]["aggregator"])
    assert agg2.residual_store.ids() == agg.residual_store.ids()
    _assert_trees_equal(agg2.residual_store.stacked(), agg.residual_store.stacked())


# ---------------------------------------------------------------------------
# AsyncTimeline under partial progress
# ---------------------------------------------------------------------------


def test_async_timeline_partial_progress_budgets_dispatches():
    tau = 8
    pcfg = ParticipationConfig(
        population=16, clients_per_round=8, dropout_rate=0.1,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="examples",
        partial_progress=True, local_steps=tau,
    )
    deadline = STRAGGLER_PROFILES["heavy"].deadline
    tl = AsyncTimeline(pcfg, 7)
    events = [tl.dispatch(n) for n in range(60)]
    completing = [e for e in events if e.completes]
    assert len(completing) > 20
    for e in completing:
        assert 1 <= e.local_steps <= tau
        # the deadline is a budget: no completion takes longer than it
        assert e.duration <= deadline + 1e-9
        assert e.weight > 0  # unscaled n_k — policy scaling happens at admit
    assert any(e.local_steps < tau for e in completing)  # genuinely partial
    # purity: dispatch n is a function of (cfg, seed, n) alone
    tl2 = AsyncTimeline(pcfg, 7)
    for n in (0, 17, 59):
        assert tl2.dispatch(n) == events[n]


# ---------------------------------------------------------------------------
# Resumable async dispatch (the acceptance criterion)
# ---------------------------------------------------------------------------


def _driver(codec=None, partial=False, state=None, dispatch=None, pop=8, k=4):
    tau = 3
    fed = FederatedConfig(
        clients_per_round=k, local_steps=tau, inner=sgd_inner(lr=0.05),
        outer=OuterOptConfig(name="fedavg", lr=1.0),
    )
    acfg = AsyncAggConfig(buffer_size=2, staleness_alpha=0.5)
    pcfg = ParticipationConfig(
        population=pop, clients_per_round=k, dropout_rate=0.1,
        straggler=STRAGGLER_PROFILES["heavy"], weighting="examples",
        partial_progress=partial, local_steps=tau if partial else 0,
    )
    drv = AsyncFederationDriver(
        quad_loss, fed, acfg, pcfg,
        lambda cid: make_batches(tau, 1, seed=100 + cid),
        seed=3, params=make_params(), rng=jax.random.PRNGKey(1),
        codec=codec, state=state, dispatch=dispatch,
    )
    return drv, fed, acfg, pcfg


def _strip_update(rows):
    return [{k: v for k, v in r.items() if k != "update"} for r in rows]


@pytest.mark.parametrize(
    "codec,partial",
    [(None, False), (None, True), (TopKCodec(k_fraction=0.25), False)],
    ids=["plain", "partial", "topk"],
)
def test_async_kill_and_resume_is_bitwise_uninterrupted(tmp_path, codec, partial):
    """THE resume criterion: checkpoint mid-run through the canonical schema
    (CheckpointManager npz + JSON manifest), rebuild a fresh driver from it,
    and the continuation must be bitwise the uninterrupted run — server state,
    buffer lanes, dispatch cursor, residual store, sim clock and every metric
    row included."""
    drv_a, fed, acfg, pcfg = _driver(codec, partial)
    hist_a = drv_a.run_updates(6)

    drv_b, *_ = _driver(codec, partial)
    drv_b.run_updates(3)
    tree, manifest = drv_b.checkpoint()
    ckpt = CheckpointManager(str(tmp_path))
    ckpt.save_server(2, tree, extra={"aggregator": manifest})

    like = AsyncBufferAggregator.checkpoint_template(
        fed, acfg, pcfg, make_params(), codec,
        uplink_ids=manifest.get("uplink_ids"),
    )
    restored, man = ckpt.load_server(2, like)
    assert man["extra"]["aggregator"] == manifest  # JSON floats exact

    drv_c, *_ = _driver(
        codec, partial, state=restored, dispatch=man["extra"]["aggregator"]
    )
    assert drv_c.n_dispatched == drv_b.n_dispatched
    assert drv_c.sim_time == drv_b.sim_time
    assert drv_c._busy == drv_b._busy
    hist_c = drv_c.run_updates(3)

    # continuation rows match the uninterrupted run's rows exactly
    assert _strip_update(hist_a[3:]) == _strip_update(hist_c)
    # final state machines are bitwise identical — manifest and pytree
    tree_a, man_a = drv_a.checkpoint()
    tree_c, man_c = drv_c.checkpoint()
    assert man_a == man_c
    _assert_trees_equal(tree_a, tree_c)
    assert drv_a.work_completed == drv_c.work_completed
    assert drv_a.work_wasted == drv_c.work_wasted
    assert drv_a.uplink_bytes_total == drv_c.uplink_bytes_total


def test_async_resume_refuses_wrong_manifest():
    drv, fed, acfg, pcfg = _driver()
    tree, manifest = drv.checkpoint()
    with pytest.raises(ValueError):  # schema drift
        _driver(state=tree, dispatch=dict(manifest, schema=999))
    with pytest.raises(ValueError):  # kind mismatch
        _driver(state=tree, dispatch=dict(manifest, kind="sync"))
    with pytest.raises(ValueError):  # slot table truncated
        _driver(
            state=tree,
            dispatch=dict(manifest, slots=manifest["slots"][:-1]),
        )
    with pytest.raises(ValueError):  # manifest without the snapshot lanes
        bad = {k: v for k, v in tree.items() if k != "inflight_params"}
        _driver(state=bad, dispatch=manifest)


def test_async_checkpoint_keeps_legacy_subset():
    """checkpoint() extends checkpoint_state() — the PR-3 buffer round-trip
    schema stays recoverable: every legacy lane matches, with the legacy DENSE
    residual lane being exactly the dense expansion of the canonical sparse
    lane (manifest ids + stacked rows)."""
    drv, *_ = _driver(TopKCodec(k_fraction=0.25))
    for _ in range(5):
        drv.step()
    legacy = drv.checkpoint_state()
    tree, manifest = drv.checkpoint()
    for key, val in legacy.items():
        if key == "uplink_residuals":
            continue  # layouts differ by design — compared below
        _assert_trees_equal(val, tree[key])
    assert set(tree) - set(legacy) == {"inflight_params", "uplink_rng"}
    # sparse lane + manifest ids expand to exactly the legacy dense store
    from repro.core.federated import SparseResidualStore

    sparse = SparseResidualStore.from_stacked(
        make_params(), manifest["uplink_ids"], tree["uplink_residuals"]
    )
    _assert_trees_equal(
        sparse.to_dense(drv.pcfg.population), legacy["uplink_residuals"]
    )
    assert len(manifest["slots"]) == 4
    assert manifest["cursor"] == drv.n_dispatched


def test_async_driver_partial_progress_trains_and_scales_weights():
    """Partial-progress async e2e: partial completions admit at fractional
    weight (τ_i/τ · n_k, pre-discount), the loop trains, the clock advances."""
    drv, fed, acfg, pcfg = _driver(partial=True)
    saw_partial = False
    for _ in range(60):
        ev = drv._heap[0][2]
        if ev.completes and 0 < ev.local_steps < fed.local_steps:
            saw_partial = True
            expect = ev.weight * ev.local_steps / fed.local_steps
            assert drv.event_weight(ev) == pytest.approx(expect)
            assert drv.event_weight(ev) < ev.weight
        drv.step()
    assert saw_partial, "heavy profile produced no partial dispatches"
    assert drv.sim_time > 0 and drv.work_completed > 0
