"""Shared tier-1 fixtures and helpers.

The tiny quadratic "model" (loss = ||W x - y||², params {'w': (4,4)}) is the
workhorse of the federated-semantics tests: exact-equivalence identities are only
provable on a model where the optimizer math is transparent. Import these from
``conftest`` instead of redefining them per test module.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import InnerOptConfig


def quad_loss(params, batch):
    pred = batch["x"] @ params["w"]
    loss = jnp.mean(jnp.square(pred - batch["y"]))
    return loss, {"loss": loss, "grad_norm": jnp.zeros(())}


def make_params(seed=0):
    return {"w": jax.random.normal(jax.random.PRNGKey(seed), (4, 4))}


def make_batches(tau, c, n=8, seed=1):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "x": jax.random.normal(k1, (tau, c, n, 4)),
        "y": jax.random.normal(k2, (tau, c, n, 4)),
    }


def sgd_inner(lr=0.1, steps=10_000):
    # plain SGD, no momentum/decay/clip for exact-equivalence tests
    return InnerOptConfig(
        name="sgd", lr_max=lr, weight_decay=0.0, grad_clip=1e9, warmup_steps=0,
        total_steps=steps, alpha=1.0,
    )


@pytest.fixture
def quad_params():
    return make_params()


@pytest.fixture(scope="session")
def tiny_model():
    """One shared reduced tiny transformer (config, model, params) for tests that
    need a real model but not a particular architecture."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("photon-75m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params
